//! Fig. 5 in your terminal: render the thread-access matrices for Kron
//! and Web as ASCII heat maps and print the §IV-C precomputable
//! diagnostic that predicts whether delay-buffering will help.
//!
//! ```bash
//! cargo run --release --example access_matrix
//! ```

use daig::algorithms::pagerank::{self, PrConfig};
use daig::engine::sim::cost::Machine;
use daig::engine::{EngineConfig, ExecutionMode};
use daig::graph::gap::GapGraph;
use daig::graph::properties;

const SHADES: &[char] = &[' ', '.', ':', '+', '*', '#', '@'];

fn render(matrix: &[Vec<u64>]) {
    let max = *matrix.iter().flatten().max().unwrap_or(&1) as f64;
    for row in matrix {
        let line: String = row
            .iter()
            .map(|&x| {
                let idx =
                    if x == 0 { 0 } else { 1 + ((x as f64 / max).powf(0.35) * (SHADES.len() - 2) as f64) as usize };
                SHADES[idx.min(SHADES.len() - 1)]
            })
            .collect();
        println!("  |{line}|");
    }
}

fn main() {
    let threads = 32;
    let machine = Machine::haswell();
    for g in [GapGraph::Kron, GapGraph::Web] {
        let graph = g.generate(12, 8);
        // Dynamic matrix from one simulated asynchronous run…
        let ecfg = EngineConfig::new(threads, ExecutionMode::Asynchronous);
        let (_, sim) = pagerank::run_sim(&graph, &ecfg, &PrConfig::default(), &machine);
        println!(
            "\n{} — rows: reading thread, cols: owning thread (measured over {} rounds)",
            g.name(),
            sim.result.num_rounds()
        );
        render(&sim.metrics.access_matrix());
        // …and the static precomputation the paper's §V suggests.
        let static_locality = properties::diagonal_locality(&graph, threads);
        println!(
            "  diagonal fraction: measured {:.3} | static precompute {:.3} | rows ≥1/32 local: {}",
            sim.metrics.diagonal_fraction(),
            static_locality,
            sim.metrics.clustered_rows(1.0 / 32.0)
        );
        println!(
            "  => delay-buffering predicted {}",
            if static_locality > 0.5 { "NOT beneficial (web-like clustering)" } else { "beneficial" }
        );
    }
}
