//! Three-layer pipeline demo: the AOT-compiled JAX/Pallas dense-block
//! kernels (L1/L2) driven from the rust coordinator (L3) via PJRT, with
//! numerics cross-checked against the native sparse engine.
//!
//! Requires `make artifacts` (python runs once, never again).
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_pipeline
//! ```

use daig::algorithms::pagerank::{self, PrConfig};
use daig::algorithms::{oracle, sssp};
use daig::engine::{EngineConfig, ExecutionMode};
use daig::graph::gap::GapGraph;
use daig::runtime::{block_backend, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {} | artifacts: jax {}", rt.platform(), rt.manifest().jax_version);
    println!("lowered blocks: {:?}\n", rt.manifest().blocks());

    // --- PageRank through the Pallas kernel ---
    let g = GapGraph::Kron.generate(8, 8); // 256 vertices → 256-block
    let cfg = PrConfig::default();
    let t0 = std::time::Instant::now();
    let dense = block_backend::pagerank(&rt, &g, &cfg, 200)?;
    let dense_time = t0.elapsed();
    let native = pagerank::run_native(&g, &EngineConfig::new(1, ExecutionMode::Synchronous), &cfg);
    let max_err = dense
        .values
        .iter()
        .zip(&native.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "PageRank kron@8 : {} rounds in {:?} (PJRT) | native sync {} rounds | max |Δscore| = {max_err:.2e}",
        dense.rounds,
        dense_time,
        native.run.num_rounds()
    );
    assert!(max_err < 1e-4, "dense/native divergence");

    // --- SSSP through the min-plus kernel ---
    let gw = GapGraph::Twitter.generate_weighted(8, 8);
    let src = sssp::default_source(&gw);
    let dense = block_backend::sssp(&rt, &gw, src, 200)?;
    let got = block_backend::dist_to_u32(&dense.values);
    let want = oracle::dijkstra(&gw, src);
    assert_eq!(got, want, "SSSP mismatch vs Dijkstra");
    println!(
        "SSSP twitter@8  : {} rounds (PJRT min-plus kernel), distances == Dijkstra for all {} vertices",
        dense.rounds,
        gw.num_vertices()
    );

    println!("\nall three layers agree ✓ (Pallas kernel → JAX step → HLO text → PJRT → rust)");
    Ok(())
}
