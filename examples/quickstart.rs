//! Quickstart: run PageRank in all three execution modes on a Kron-style
//! graph and see the paper's trade-off in one screen.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use daig::algorithms::pagerank::{self, PrConfig};
use daig::engine::sim::cost::Machine;
use daig::engine::{EngineConfig, ExecutionMode};
use daig::graph::gap::GapGraph;
use daig::util::fmt;

fn main() {
    // 1. Generate a GAP-analog graph (deterministic for a given scale).
    let g = GapGraph::Kron.generate(12, 8);
    println!("kron@12: {} vertices, {} edges\n", g.num_vertices(), g.num_edges());

    // 2. Run the three modes on the simulated 32-thread Haswell.
    let machine = Machine::haswell();
    println!(
        "{:<12} {:>7} {:>14} {:>14} {:>16}",
        "mode", "rounds", "total (sim)", "avg/round", "invalidations"
    );
    for mode in [
        ExecutionMode::Synchronous,
        ExecutionMode::Asynchronous,
        ExecutionMode::Delayed(256), // the paper's hybrid: δ = 256 elements
    ] {
        let ecfg = EngineConfig::new(32, mode);
        let (res, sim) = pagerank::run_sim(&g, &ecfg, &PrConfig::default(), &machine);
        println!(
            "{:<12} {:>7} {:>14} {:>14} {:>16}",
            mode.label(),
            res.run.num_rounds(),
            fmt::secs(res.run.total_time()),
            fmt::secs(res.run.avg_round_time()),
            fmt::si(sim.metrics.invalidations as f64)
        );
    }

    // 3. The same API runs on real host threads.
    let native = pagerank::run_native(&g, &EngineConfig::new(4, ExecutionMode::Delayed(256)), &PrConfig::default());
    println!(
        "\nnative (4 host threads, δ=256): rounds={} wall={}",
        native.run.num_rounds(),
        fmt::secs(native.run.total_time())
    );
    println!("top-5 vertices by score: {:?}", native.top_k(5));
}
