//! **End-to-end driver** (EXPERIMENTS.md §E2E): runs the full system on a
//! realistic workload — the five-graph GAP-analog suite — and reports the
//! paper's headline metric: hybrid (delayed-async) speedup over both the
//! asynchronous and synchronous baselines, for PageRank and SSSP, on the
//! simulated 112-thread Cascade Lake.
//!
//! All layers compose here: graph generation → degree-balanced
//! partitioning → the three engine modes with delay buffers → coherence
//! simulation → δ selection → report.
//!
//! ```bash
//! cargo run --release --example gap_suite            # scale 13 default
//! DAIG_SCALE=14 cargo run --release --example gap_suite
//! ```

use daig::coordinator::{sweep, Algo};
use daig::engine::sim::cost::Machine;
use daig::engine::ExecutionMode;
use daig::graph::gap::ALL;
use daig::util::fmt;

fn main() {
    let scale: u32 = std::env::var("DAIG_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(13);
    let machine = Machine::cascade_lake();
    let t = machine.threads;
    let t0 = std::time::Instant::now();

    for (algo, title) in [(Algo::PageRank, "PageRank"), (Algo::Sssp, "Bellman-Ford SSSP")] {
        println!("== {title}, simulated {} ({t} threads), scale {scale} ==", machine.name);
        println!(
            "{:<10} {:>7} {:>7} {:>8} {:>12} {:>12} {:>12} {:>10}",
            "graph", "r.sync", "r.hyb", "best δ", "sync", "async", "hybrid", "vs async"
        );
        let mut best_vs_async = f64::MIN;
        let mut best_vs_sync = f64::MIN;
        for g in ALL {
            let graph = if algo.weighted() { g.generate_weighted(scale, 0) } else { g.generate(scale, 0) };
            let pts = sweep::modes(&graph, algo, t, &machine);
            let sync = sweep::find_mode(&pts, ExecutionMode::Synchronous).unwrap();
            let asyn = sweep::find_mode(&pts, ExecutionMode::Asynchronous).unwrap();
            let best = sweep::best_delayed(&pts).unwrap();
            println!(
                "{:<10} {:>7} {:>7} {:>8} {:>12} {:>12} {:>12} {:>10}",
                g.name(),
                sync.rounds,
                best.rounds,
                best.mode.label(),
                fmt::secs(sync.time_s),
                fmt::secs(asyn.time_s),
                fmt::secs(best.time_s),
                fmt::pct_delta(asyn.time_s / best.time_s)
            );
            best_vs_async = best_vs_async.max(asyn.time_s / best.time_s);
            best_vs_sync = best_vs_sync.max(sync.time_s / best.time_s);
        }
        println!(
            "headline: hybrid up to {} over async, {:.2}x over sync\n",
            fmt::pct_delta(best_vs_async),
            best_vs_sync
        );
    }
    println!("(paper: PR hybrid 4.5–19.4% over async at 112t, ≤2.56x over sync; SSSP 1.9–17%)");
    println!("suite completed in {}", fmt::secs(t0.elapsed().as_secs_f64()));
}
