use daig::algorithms::pagerank::{self, PrConfig};
use daig::engine::sim::cost::Machine;
use daig::engine::{EngineConfig, ExecutionMode};
use daig::graph::gap::GapGraph;
fn main() {
    let g = GapGraph::Kron.generate(14, 12);
    let m = Machine::haswell();
    for _ in 0..30 {
        let ecfg = EngineConfig::new(32, ExecutionMode::Delayed(256));
        std::hint::black_box(pagerank::run_sim(&g, &ecfg, &PrConfig::default(), &m));
    }
}
