//! δ-tuning walkthrough (paper §IV): sweep the delay parameter on one
//! graph across thread counts and watch the best δ move — downward as
//! threads increase on Kron (the paper's Fig. 3/4 finding).
//!
//! ```bash
//! cargo run --release --example delta_tuning
//! cargo run --release --example delta_tuning -- urand 12
//! ```

use daig::coordinator::{sweep, Algo};
use daig::engine::sim::cost::Machine;
use daig::engine::ExecutionMode;
use daig::graph::gap::GapGraph;
use daig::util::fmt;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let graph_name = args.first().map(String::as_str).unwrap_or("kron");
    let scale: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let g = GapGraph::from_name(graph_name).expect("graph: kron|urand|twitter|web|road");
    let graph = g.generate(scale, 8);
    let machine = Machine::haswell();

    println!("δ sweep, PageRank on {}@{scale} (simulated Haswell)\n", g.name());
    for threads in [4usize, 8, 16, 32] {
        let pts = sweep::modes(&graph, Algo::PageRank, threads, &machine);
        let asyn = sweep::find_mode(&pts, ExecutionMode::Asynchronous).unwrap();
        let best = sweep::best_delayed(&pts).unwrap();
        print!("{threads:>3} threads: ");
        for p in &pts {
            if let ExecutionMode::Delayed(d) = p.mode {
                let marker = if p.mode == best.mode { '*' } else { ' ' };
                print!("δ{d}={:.2}x{marker} ", asyn.time_s / p.time_s);
            }
        }
        println!(
            "\n             best δ = {} ({} vs async; {} flushes/run)",
            best.mode.label(),
            fmt::pct_delta(asyn.time_s / best.time_s),
            best.flushes
        );
    }
    println!("\n(speedups are relative to asynchronous; * marks the best δ)");
}
