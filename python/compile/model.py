"""Layer-2 JAX model: full per-round step functions over the L1 kernels.

Each step consumes the whole state of one iterative round and returns the
new state *plus* the convergence metric, so the rust coordinator drives
the loop with a single executable call per round:

* :func:`pagerank_step` — new scores and the round's L1 delta.
* :func:`sssp_step` — relaxed distances and the change count.

``xw`` normalization, convergence reduction, and the kernel call are all
in one jitted graph, so XLA fuses them around the Pallas body and nothing
crosses the host boundary mid-round.
"""

import jax
import jax.numpy as jnp

from compile.kernels import pagerank_block, sssp_block


def pagerank_step(m, scores, inv_outdeg, damping, base):
    """One full PageRank round on a dense block.

    Args:
      m: (N, N) f32 pull adjacency (m[i, j] = 1 iff edge j -> i).
      scores: (N, 1) f32 current scores.
      inv_outdeg: (N, 1) f32 reciprocal out-degrees (0 for dangling).
      damping: (1, 1) f32.
      base: (1, 1) f32 = (1 - d)/n.

    Returns:
      (new_scores (N, 1), delta (1, 1)) — delta is the summed |change|,
      compared by the coordinator against the paper's 1e-4 threshold.
    """
    xw = scores * inv_outdeg
    new = pagerank_block.pagerank_block(m, xw, damping, base)
    delta = jnp.sum(jnp.abs(new - scores)).reshape(1, 1)
    return new, delta


def sssp_step(w, dist):
    """One full Bellman-Ford round on a dense block.

    Args:
      w: (N, N) f32 weights, +inf where no edge (w[j, i] = weight j -> i).
      dist: (N, 1) f32 current distances, +inf unreached.

    Returns:
      (new_dist (N, 1), changed (1, 1)) — changed counts updated vertices;
      0 means the paper's SSSP stopping criterion is met.
    """
    new = sssp_block.sssp_block(w, dist)
    changed = jnp.sum((new != dist).astype(jnp.float32)).reshape(1, 1)
    return new, changed


def pagerank_example_args(n):
    """ShapeDtypeStructs for AOT lowering at block size n."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),
        jax.ShapeDtypeStruct((n, 1), f32),
        jax.ShapeDtypeStruct((n, 1), f32),
        jax.ShapeDtypeStruct((1, 1), f32),
        jax.ShapeDtypeStruct((1, 1), f32),
    )


def sssp_example_args(n):
    """ShapeDtypeStructs for AOT lowering at block size n."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),
        jax.ShapeDtypeStruct((n, 1), f32),
    )
