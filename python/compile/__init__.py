# Build-time-only package: JAX/Pallas kernels and AOT lowering.
# Never imported by the runtime path — rust loads the HLO artifacts.
