"""AOT lowering: JAX/Pallas step functions -> HLO text artifacts.

Runs ONCE at build time (`make artifacts`); the rust runtime then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
touches python again.

HLO **text** (not ``lowered.compile().serialize()`` / serialized proto)
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the `xla` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Block sizes to lower. 128 = one MXU tile; 256/512 exercise the grid.
BLOCK_SIZES = (128, 256, 512)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def arg_manifest(example_args):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
    ]


def build(out_dir: str) -> dict:
    """Lower every entry point; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for n in BLOCK_SIZES:
        for name, fn, args in (
            (
                f"pagerank_step_{n}",
                model.pagerank_step,
                model.pagerank_example_args(n),
            ),
            (f"sssp_step_{n}", model.sssp_step, model.sssp_example_args(n)),
        ):
            text = lower_entry(fn, args)
            fname = f"{name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "file": fname,
                    "block": n,
                    "inputs": arg_manifest(args),
                    "outputs": 2,
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                }
            )
            print(f"lowered {name}: {len(text)} chars -> {path}")
    manifest = {
        "format": "hlo-text",
        "jax": jax.__version__,
        "tile_m": 128,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build(args.out_dir)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
