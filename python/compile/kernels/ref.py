"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float tolerance across the shape/dtype sweep in
``python/tests``. They are deliberately written in the most obvious
vectorized form with no tiling tricks.
"""

import jax.numpy as jnp


def pagerank_block(m, xw, damping, base):
    """Dense-block PageRank contribution.

    new[i] = base + damping * sum_j m[i, j] * xw[j]

    Args:
      m: (N, N) f32 — m[i, j] = 1.0 iff edge j -> i (pull orientation).
      xw: (N, 1) f32 — neighbor scores pre-divided by out-degree.
      damping: (1, 1) f32.
      base: (1, 1) f32 — (1 - d) / n_total.

    Returns:
      (N, 1) f32 new scores.
    """
    return base + damping * (m @ xw)


def sssp_block(w, dist):
    """Dense-block min-plus Bellman-Ford relaxation.

    new[i] = min(dist[i], min_j (dist[j] + w[j, i]))

    Args:
      w: (N, N) f32 — w[j, i] = weight of edge j -> i, +inf when absent.
      dist: (N, 1) f32 — current distances (+inf = unreached).

    Returns:
      (N, 1) f32 relaxed distances.
    """
    cand = jnp.min(dist + w, axis=0, keepdims=True).T  # (N, 1)
    return jnp.minimum(dist, cand)


def pagerank_delta(old, new):
    """Round L1 delta — the paper's convergence metric."""
    return jnp.sum(jnp.abs(new - old)).reshape(1, 1)


def sssp_changed(old, new):
    """Number of vertices whose distance changed this round."""
    return jnp.sum((old != new).astype(jnp.float32)).reshape(1, 1)
