"""Layer-1 Pallas kernel: tiled dense-block PageRank update.

Computes ``out = base + damping * (M @ xw)`` over a dense (N, N) pull
adjacency block, tiled along the output (row) dimension.

Hardware adaptation (DESIGN.md §4): the paper targets shared-memory CPUs,
so there is no CUDA idiom to port; on the TPU-shaped stack the natural
mapping of one *partition's* pull sweep is a dense blocked SpMV, which is
MXU work. Tiles are (TM, N) rows of M against the full (N, 1) vector:

* the (TM, N) row tile and (N, 1) vector stream HBM -> VMEM per grid
  step (BlockSpec index_map below) — the analog of the paper's blocked
  partitioning;
* the output tile is written back once per grid step — a δ=TM coalesced
  flush, which is exactly the delay-buffer idea expressed as a VMEM
  write-out schedule;
* the inner contraction is a (TM, N) x (N, 1) matmul on the MXU in f32.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering via interpret mode produces plain HLO that the
rust runtime executes (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 128 matches the MXU systolic dimension; N must be a
# multiple (model.py pads).
TILE_M = 128


def _kernel(m_ref, xw_ref, damping_ref, base_ref, out_ref):
    # One grid step: rows [i*TM, (i+1)*TM) of the block.
    acc = jnp.dot(m_ref[...], xw_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = base_ref[0, 0] + damping_ref[0, 0] * acc


@functools.partial(jax.jit, static_argnames=())
def pagerank_block(m, xw, damping, base):
    """Pallas twin of :func:`compile.kernels.ref.pagerank_block`.

    Args:
      m: (N, N) f32 pull adjacency block (m[i, j] = 1 iff edge j -> i).
      xw: (N, 1) f32 out-degree-normalized scores.
      damping: (1, 1) f32.
      base: (1, 1) f32.

    Returns:
      (N, 1) f32 updated scores.
    """
    n = m.shape[0]
    assert m.shape == (n, n), m.shape
    assert xw.shape == (n, 1), xw.shape
    assert n % TILE_M == 0, f"N={n} must be a multiple of {TILE_M}"
    grid = (n // TILE_M,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # Row tile of M: HBM->VMEM once per grid step.
            pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
            # Full vector: resident across steps.
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(m, xw, damping, base)
