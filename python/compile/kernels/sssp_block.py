"""Layer-1 Pallas kernel: tiled dense-block min-plus SSSP relaxation.

Computes ``out[i] = min(dist[i], min_j (dist[j] + w[j, i]))`` over a
dense (N, N) weight block with +inf for absent edges — one Bellman-Ford
round on a partition, in the (min, +) semiring.

Distances ride in f32: GAP weights are integers in [1, 255] and test
graphs keep shortest paths far below 2^24, so f32 is exact; the rust
side converts its u32 distances at the block boundary (u32::MAX <-> +inf).

Tiling mirrors pagerank_block: (TM, N) column-slices of W^T stream
through VMEM, each grid step reduces over the full source dimension and
writes its (TM, 1) output tile once (the δ=TM coalesced flush analog).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128


def _kernel(wt_ref, dist_ref, self_ref, out_ref):
    # wt tile: (TM, N) where wt[i, j] = w[j, i]; dist: (N, 1).
    cand = wt_ref[...] + dist_ref[...].reshape(1, -1)  # (TM, N)
    best = jnp.min(cand, axis=1, keepdims=True)  # (TM, 1)
    out_ref[...] = jnp.minimum(self_ref[...], best)


@functools.partial(jax.jit, static_argnames=())
def sssp_block(w, dist):
    """Pallas twin of :func:`compile.kernels.ref.sssp_block`.

    Args:
      w: (N, N) f32 — w[j, i] = weight of edge j -> i, +inf if absent.
      dist: (N, 1) f32 current distances.

    Returns:
      (N, 1) f32 relaxed distances.
    """
    n = w.shape[0]
    assert w.shape == (n, n), w.shape
    assert dist.shape == (n, 1), dist.shape
    assert n % TILE_M == 0, f"N={n} must be a multiple of {TILE_M}"
    wt = w.T  # (dst, src) layout so output rows are contiguous tiles
    grid = (n // TILE_M,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(wt, dist, dist)
