"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps block sizes (multiples of the 128 tile), densities, and
value magnitudes; every case must match the oracle to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pagerank_block, ref, sssp_block

BLOCKS = st.sampled_from([128, 256, 384, 512])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def rand_adjacency(n, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, n)) < density).astype(np.float32)


def rand_weights(n, density, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 256, size=(n, n)).astype(np.float32)
    mask = rng.random((n, n)) < density
    return np.where(mask, w, np.float32(np.inf))


class TestPageRankKernel:
    @settings(max_examples=12, deadline=None)
    @given(n=BLOCKS, seed=SEEDS, density=st.floats(0.0, 0.3))
    def test_matches_ref(self, n, seed, density):
        m = rand_adjacency(n, density, seed)
        rng = np.random.default_rng(seed + 1)
        xw = rng.random((n, 1)).astype(np.float32)
        damping = jnp.full((1, 1), 0.85, jnp.float32)
        base = jnp.full((1, 1), (1 - 0.85) / n, jnp.float32)
        got = pagerank_block.pagerank_block(m, xw, damping, base)
        want = ref.pagerank_block(m, xw, damping, base)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_zero_matrix_gives_base(self):
        n = 128
        m = np.zeros((n, n), np.float32)
        xw = np.ones((n, 1), np.float32)
        damping = jnp.full((1, 1), 0.85, jnp.float32)
        base = jnp.full((1, 1), 0.125, jnp.float32)
        got = pagerank_block.pagerank_block(m, xw, damping, base)
        np.testing.assert_allclose(got, np.full((n, 1), 0.125), rtol=1e-6)

    def test_identity_scales(self):
        n = 256
        m = np.eye(n, dtype=np.float32)
        xw = np.full((n, 1), 0.5, np.float32)
        damping = jnp.full((1, 1), 0.5, jnp.float32)
        base = jnp.full((1, 1), 0.1, jnp.float32)
        got = pagerank_block.pagerank_block(m, xw, damping, base)
        np.testing.assert_allclose(got, np.full((n, 1), 0.35), rtol=1e-6)

    def test_rejects_unaligned_n(self):
        n = 100
        with pytest.raises(AssertionError):
            pagerank_block.pagerank_block(
                np.zeros((n, n), np.float32),
                np.zeros((n, 1), np.float32),
                jnp.zeros((1, 1)),
                jnp.zeros((1, 1)),
            )


class TestSsspKernel:
    @settings(max_examples=12, deadline=None)
    @given(n=BLOCKS, seed=SEEDS, density=st.floats(0.0, 0.3))
    def test_matches_ref(self, n, seed, density):
        w = rand_weights(n, density, seed)
        rng = np.random.default_rng(seed + 2)
        dist = rng.integers(0, 1000, size=(n, 1)).astype(np.float32)
        # Sprinkle unreached vertices.
        dist[rng.random((n, 1)) < 0.3] = np.inf
        got = sssp_block.sssp_block(w, dist)
        want = ref.sssp_block(w, dist)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_no_edges_keeps_dist(self):
        n = 128
        w = np.full((n, n), np.inf, np.float32)
        dist = np.arange(n, dtype=np.float32).reshape(n, 1)
        got = sssp_block.sssp_block(w, dist)
        np.testing.assert_array_equal(np.asarray(got), dist)

    def test_single_relaxation(self):
        n = 128
        w = np.full((n, n), np.inf, np.float32)
        w[0, 1] = 7.0  # edge 0 -> 1
        dist = np.full((n, 1), np.inf, np.float32)
        dist[0] = 0.0
        got = np.asarray(sssp_block.sssp_block(w, dist))
        assert got[1, 0] == 7.0
        assert got[0, 0] == 0.0
        assert np.isinf(got[2, 0])

    def test_monotone_never_increases(self):
        n = 256
        w = rand_weights(n, 0.05, 9)
        rng = np.random.default_rng(10)
        dist = rng.integers(0, 100, size=(n, 1)).astype(np.float32)
        got = np.asarray(sssp_block.sssp_block(w, dist))
        assert (got <= dist + 1e-6).all()


class TestRefHelpers:
    def test_pagerank_delta(self):
        old = jnp.array([[1.0], [2.0]])
        new = jnp.array([[1.5], [1.0]])
        assert float(ref.pagerank_delta(old, new)[0, 0]) == pytest.approx(1.5)

    def test_sssp_changed(self):
        old = jnp.array([[1.0], [2.0], [3.0]])
        new = jnp.array([[1.0], [1.0], [3.0]])
        assert float(ref.sssp_changed(old, new)[0, 0]) == 1.0
