"""AOT pipeline: artifacts lower to parseable HLO text with a coherent
manifest, and the HLO mentions the expected entry structure."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


class TestAot:
    def test_manifest_entries(self, built):
        out, manifest = built
        names = {e["name"] for e in manifest["entries"]}
        for n in aot.BLOCK_SIZES:
            assert f"pagerank_step_{n}" in names
            assert f"sssp_step_{n}" in names
        assert manifest["format"] == "hlo-text"

    def test_files_exist_and_parse_shape(self, built):
        out, manifest = built
        for e in manifest["entries"]:
            path = os.path.join(out, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert text.startswith("HloModule"), e["name"]
            assert "ENTRY" in text

    def test_manifest_json_roundtrip(self, built):
        out, _ = built
        m = json.load(open(os.path.join(out, "manifest.json")))
        assert len(m["entries"]) == 2 * len(aot.BLOCK_SIZES)
        for e in m["entries"]:
            assert len(e["sha256"]) == 64
            assert e["block"] in aot.BLOCK_SIZES

    def test_input_shapes_recorded(self, built):
        _, manifest = built
        pr = next(
            e for e in manifest["entries"] if e["name"] == "pagerank_step_128"
        )
        assert pr["inputs"][0]["shape"] == [128, 128]
        assert pr["inputs"][1]["shape"] == [128, 1]
        assert all(i["dtype"] == "float32" for i in pr["inputs"])


class TestLowering:
    def test_hlo_text_deterministic(self):
        args = model.sssp_example_args(128)
        a = aot.lower_entry(model.sssp_step, args)
        b = aot.lower_entry(model.sssp_step, args)
        assert a == b

    def test_pagerank_lowers_with_dot(self):
        args = model.pagerank_example_args(128)
        text = aot.lower_entry(model.pagerank_step, args)
        # The Pallas matmul must survive lowering as a dot (or fused conv).
        assert "dot(" in text or "dot " in text
