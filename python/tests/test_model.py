"""L2 correctness: full step functions against hand-built expectations,
including a tiny end-to-end PageRank power iteration in pure python."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def dense_cycle(n):
    """Directed cycle 0 -> 1 -> ... -> 0 as pull adjacency (m[i,j]=j->i)."""
    m = np.zeros((n, n), np.float32)
    for j in range(n):
        m[(j + 1) % n, j] = 1.0
    return m


class TestPagerankStep:
    def test_cycle_converges_to_uniform(self):
        n = 128
        m = dense_cycle(n)
        scores = np.random.default_rng(0).random((n, 1)).astype(np.float32)
        scores /= scores.sum()
        inv = np.ones((n, 1), np.float32)  # outdeg = 1 everywhere
        damping = jnp.full((1, 1), 0.85, jnp.float32)
        base = jnp.full((1, 1), 0.15 / n, jnp.float32)
        for _ in range(200):
            scores, delta = model.pagerank_step(m, scores, inv, damping, base)
            if float(delta[0, 0]) < 1e-6:
                break
        np.testing.assert_allclose(
            np.asarray(scores), np.full((n, 1), 1.0 / n), atol=1e-5
        )

    def test_delta_decreases(self):
        n = 128
        rng = np.random.default_rng(3)
        m = (rng.random((n, n)) < 0.05).astype(np.float32)
        outdeg = m.sum(axis=0, keepdims=True).T  # col j sums = outdeg(j)
        inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0).astype(
            np.float32
        )
        scores = np.full((n, 1), 1.0 / n, np.float32)
        damping = jnp.full((1, 1), 0.85, jnp.float32)
        base = jnp.full((1, 1), 0.15 / n, jnp.float32)
        deltas = []
        for _ in range(10):
            scores, d = model.pagerank_step(m, scores, inv, damping, base)
            deltas.append(float(d[0, 0]))
        assert deltas[-1] < deltas[0]

    def test_mass_preserved_on_cycle(self):
        n = 128
        m = dense_cycle(n)
        scores = np.full((n, 1), 1.0 / n, np.float32)
        inv = np.ones((n, 1), np.float32)
        damping = jnp.full((1, 1), 0.85, jnp.float32)
        base = jnp.full((1, 1), 0.15 / n, jnp.float32)
        new, _ = model.pagerank_step(m, scores, inv, damping, base)
        assert float(jnp.sum(new)) == pytest.approx(1.0, abs=1e-5)


class TestSsspStep:
    def test_chain_relaxes_one_hop_per_round(self):
        n = 128
        w = np.full((n, n), np.inf, np.float32)
        for j in range(n - 1):
            w[j, j + 1] = 2.0  # j -> j+1
        dist = np.full((n, 1), np.inf, np.float32)
        dist[0] = 0.0
        for r in range(1, 5):
            dist, changed = model.sssp_step(w, dist)
            dist = np.asarray(dist)
            assert float(changed[0, 0]) == 1.0
            assert dist[r, 0] == 2.0 * r
            assert np.isinf(dist[r + 1, 0])

    def test_changed_zero_at_fixed_point(self):
        n = 128
        w = np.full((n, n), np.inf, np.float32)
        w[0, 1] = 1.0
        dist = np.full((n, 1), np.inf, np.float32)
        dist[0], dist[1] = 0.0, 1.0
        _, changed = model.sssp_step(w, dist)
        assert float(changed[0, 0]) == 0.0


class TestExampleArgs:
    def test_shapes(self):
        args = model.pagerank_example_args(256)
        assert [tuple(a.shape) for a in args] == [
            (256, 256),
            (256, 1),
            (256, 1),
            (1, 1),
            (1, 1),
        ]
        args = model.sssp_example_args(128)
        assert [tuple(a.shape) for a in args] == [(128, 128), (128, 1)]
