//! Cache-line-aligned storage.
//!
//! The paper's delay buffer must start on a cache-line boundary so that a
//! flush of `δ` elements (δ a multiple of [`crate::VALUES_PER_LINE`])
//! dirties exactly `δ / 16` lines and permits aligned vector stores.

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ops::{Deref, DerefMut};

use crate::CACHE_LINE_BYTES;

/// A fixed-capacity `Vec<u32>`-like buffer whose backing storage is
/// 64-byte aligned. Only `u32`-sized elements are supported because every
/// vertex value type in this crate (f32 scores, u32 distances/labels) is
/// 32 bits — exactly as in the paper's evaluation.
pub struct AlignedBuf {
    ptr: *mut u32,
    len: usize,
    cap: usize,
}

// SAFETY: AlignedBuf owns its allocation exclusively; sending it between
// threads transfers ownership of the raw allocation like Vec.
unsafe impl Send for AlignedBuf {}

impl AlignedBuf {
    /// Allocate a zeroed buffer holding `cap` u32 elements, 64-B aligned.
    /// `cap` may be zero (no allocation performed).
    pub fn zeroed(cap: usize) -> Self {
        if cap == 0 {
            return Self { ptr: std::ptr::NonNull::<u32>::dangling().as_ptr(), len: 0, cap: 0 };
        }
        let layout = Self::layout(cap);
        // SAFETY: layout has non-zero size (cap > 0).
        let ptr = unsafe { alloc_zeroed(layout) } as *mut u32;
        assert!(!ptr.is_null(), "allocation failure for AlignedBuf({cap})");
        Self { ptr, len: cap, cap }
    }

    /// Allocate with capacity `cap` but length 0 (for push-style use).
    pub fn with_capacity(cap: usize) -> Self {
        let mut b = Self::zeroed(cap);
        b.len = 0;
        b
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * 4, CACHE_LINE_BYTES).expect("AlignedBuf layout")
    }

    /// Number of elements currently stored.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no elements are stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated capacity in elements.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Append an element. Panics if full (delay buffers are flushed by the
    /// engine *before* overflow, so this is a logic-error guard).
    #[inline]
    pub fn push(&mut self, v: u32) {
        assert!(self.len < self.cap, "AlignedBuf overflow");
        // SAFETY: len < cap, so the slot is in-bounds and allocated.
        unsafe { self.ptr.add(self.len).write(v) };
        self.len += 1;
    }

    /// Reset length to zero without touching contents.
    #[inline]
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// True if `len == cap`.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// The raw base pointer (64-byte aligned).
    #[inline]
    pub fn as_ptr(&self) -> *const u32 {
        self.ptr
    }
}

impl Deref for AlignedBuf {
    type Target = [u32];
    #[inline]
    fn deref(&self) -> &[u32] {
        // SAFETY: `len` elements starting at `ptr` are initialized
        // (zeroed at alloc or written by push).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl DerefMut for AlignedBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [u32] {
        // SAFETY: as above; exclusive access via &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.cap != 0 {
            // SAFETY: allocated with the identical layout in `zeroed`.
            unsafe { dealloc(self.ptr as *mut u8, Self::layout(self.cap)) };
        }
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf(len={}, cap={})", self.len, self.cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_64b() {
        for cap in [16, 64, 1024, 32768] {
            let b = AlignedBuf::zeroed(cap);
            assert_eq!(b.as_ptr() as usize % CACHE_LINE_BYTES, 0);
        }
    }

    #[test]
    fn zeroed_contents() {
        let b = AlignedBuf::zeroed(128);
        assert!(b.iter().all(|&x| x == 0));
        assert_eq!(b.len(), 128);
    }

    #[test]
    fn push_and_clear() {
        let mut b = AlignedBuf::with_capacity(4);
        assert!(b.is_empty());
        b.push(1);
        b.push(2);
        assert_eq!(&b[..], &[1, 2]);
        assert!(!b.is_full());
        b.push(3);
        b.push(4);
        assert!(b.is_full());
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn push_overflow_panics() {
        let mut b = AlignedBuf::with_capacity(1);
        b.push(0);
        b.push(1);
    }

    #[test]
    fn zero_capacity_ok() {
        let b = AlignedBuf::zeroed(0);
        assert_eq!(b.len(), 0);
        assert!(b.is_empty());
    }

    #[test]
    fn mutate_through_deref() {
        let mut b = AlignedBuf::zeroed(8);
        b[3] = 99;
        assert_eq!(b[3], 99);
    }
}
