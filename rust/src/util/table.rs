//! Plain-text / markdown / CSV table rendering for experiment reports.
//!
//! Every experiment driver in [`crate::coordinator::experiments`] emits its
//! results through this module so that the console view, the CSV for
//! plotting, and the markdown for EXPERIMENTS.md all agree.

/// A simple rectangular table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch in table '{}'", self.title);
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Render as CSV (RFC-4180 quoting where needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["graph", "rounds"]);
        t.row(vec!["kron".into(), "7".into()]);
        t.row(vec!["road,x".into(), "39".into()]);
        t
    }

    #[test]
    fn text_is_aligned() {
        let txt = sample().to_text();
        assert!(txt.contains("graph"));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| graph | rounds |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn csv_quotes_commas() {
        let csv = sample().to_csv();
        assert!(csv.contains("\"road,x\""));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
