//! In-tree substrates: the offline build environment ships no third-party
//! crates beyond `xla`/`anyhow`, so the small utilities a project would
//! normally pull from crates.io are implemented here from scratch.

pub mod aligned;
pub mod bench;
pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod table;
