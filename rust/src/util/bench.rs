//! Self-timed micro-benchmark harness (criterion is unavailable in this
//! offline environment). Used by the `rust/benches/*.rs` targets
//! (`harness = false`).
//!
//! Methodology: warmup iterations, then `samples` timed iterations;
//! reports min / median / mean. Black-boxes the closure result so the
//! optimizer cannot elide the work.

use std::hint::black_box;
use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub samples: usize,
}

/// Time `f`, returning the summary (warmup 2 + `samples` runs).
pub fn time<T>(samples: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..2 {
        black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        samples,
    }
}

/// Run + report one named case.
pub fn case<T>(name: &str, samples: usize, f: impl FnMut() -> T) -> Sample {
    let s = time(samples, f);
    println!(
        "{name:<52} min {:>12}  median {:>12}  mean {:>12}  (n={})",
        super::fmt::secs(s.min_s),
        super::fmt::secs(s.median_s),
        super::fmt::secs(s.mean_s),
        s.samples
    );
    s
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let s = time(5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.min_s > 0.0);
        assert!(s.min_s <= s.median_s);
        assert!(s.samples == 5);
    }
}
