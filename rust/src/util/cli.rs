//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `daig <subcommand> [positional…] [--flag] [--key value]…`.
//! Flags may be written `--key=value` or `--key value`. Unknown flags are
//! an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand, positionals, and `--key value` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining non-flag tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn opt_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; errors if present but unparsable.
    pub fn opt<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    /// Boolean flag (`--quiet` or `--quiet=true`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Error unless every provided option key is in `allowed`.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown option --{k} (allowed: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("run kron extra");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["kron", "extra"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse("run --threads 8 --delta=256");
        assert_eq!(a.opt::<usize>("threads", 1).unwrap(), 8);
        assert_eq!(a.opt::<usize>("delta", 0).unwrap(), 256);
    }

    #[test]
    fn bare_flag() {
        // A non-flag token after `--key` binds as its value…
        let a = parse("run --quiet kron");
        assert_eq!(a.opt_str("quiet", ""), "kron");
        assert!(!a.flag("quiet"));
        // …use `--key=true` to combine a bare flag with positionals.
        let b = parse("run --quiet=true kron");
        assert!(b.flag("quiet"));
        assert_eq!(b.positional, vec!["kron"]);
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt::<u64>("seed", 42).unwrap(), 42);
        assert_eq!(a.opt_str("graph", "kron"), "kron");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn parse_error_reported() {
        let a = parse("run --threads abc");
        assert!(a.opt::<usize>("threads", 1).is_err());
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("run --oops 3");
        assert!(a.reject_unknown(&["threads"]).is_err());
        assert!(a.reject_unknown(&["oops"]).is_ok());
    }
}
