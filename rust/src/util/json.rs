//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`) and for machine-readable experiment
//! results. Implemented in-tree because no serde is available offline.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (not needed for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (important for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document. Returns an error with byte offset on failure.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; emit null (as serde_json
                    // does) so experiment/bench artifacts stay parsable.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (k, x) in xs.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(key, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors (ergonomics for manifest reading) ----

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric payload as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array payload.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).ok_or("surrogate \\u escape unsupported")?);
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"b":[1,2.5,-3],"a":"hi\n","c":{"x":true,"y":null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"name":"pr","n":128,"files":["a","b"]}"#).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("pr"));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(128));
        assert_eq!(v.get("files").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,[2]],[]]").unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(-2.0).to_string(), "-2");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(x).to_string(), "null", "{x}");
        }
        // Round trip: a document containing non-finite values must still
        // come back through the parser as valid JSON.
        let doc = Json::obj(vec![
            ("bad", Json::Num(f64::NAN)),
            ("worse", Json::Arr(vec![Json::Num(f64::INFINITY), Json::Num(2.5)])),
        ]);
        let re = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(re.get("bad"), Some(&Json::Null));
        assert_eq!(re.get("worse").unwrap().as_arr().unwrap()[0], Json::Null);
        assert_eq!(re.get("worse").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
