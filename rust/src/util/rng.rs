//! Deterministic pseudo-random number generation.
//!
//! Everything in this crate that consumes randomness (graph generators,
//! weight assignment, property tests, simulator tie-breaking) goes through
//! [`SplitMix64`] so that every experiment is bit-reproducible from a seed.
//! SplitMix64 (Steele, Lea & Flood 2014) passes BigCrush, has a full 2^64
//! period, and is 3 instructions per draw — ideal for a hot generator loop.

/// SplitMix64 PRNG. `Copy` is deliberately not derived: accidentally
/// duplicating generator state is a classic reproducibility bug.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent child stream (used to give each graph
    /// generator phase / simulated thread its own stream without
    /// sequential coupling).
    pub fn fork(&mut self, tag: u64) -> Self {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self::new(s)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection-free
    /// mapping (tiny bias is irrelevant at our scales but the mapping is
    /// branch-free and fast).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as u32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn known_vector() {
        // Reference value from the published SplitMix64 algorithm, seed 0.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(13) < 13);
        }
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_roughly_uniform() {
        let mut r = SplitMix64::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.index(8)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = SplitMix64::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
