//! Human-readable formatting helpers for reports and logs.

/// Format a count with SI-style suffixes: `1234567` → `"1.23M"`.
pub fn si(x: f64) -> String {
    let (v, suffix) = if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    if suffix.is_empty() {
        format!("{v:.0}")
    } else {
        format!("{v:.2}{suffix}")
    }
}

/// Format a duration in seconds adaptively (`1.23 s`, `45.6 ms`, `789 µs`).
pub fn secs(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3} s")
    } else if t >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if t >= 1e-6 {
        format!("{:.1} µs", t * 1e6)
    } else {
        format!("{:.0} ns", t * 1e9)
    }
}

/// Format a ratio as a percentage delta: 1.194 → `"+19.4%"`.
pub fn pct_delta(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_suffixes() {
        assert_eq!(si(950.0), "950");
        assert_eq!(si(1_234_567.0), "1.23M");
        assert_eq!(si(4_200.0), "4.20k");
        assert_eq!(si(2.5e9), "2.50G");
    }

    #[test]
    fn secs_ranges() {
        assert_eq!(secs(2.5), "2.500 s");
        assert_eq!(secs(0.0456), "45.600 ms");
        assert_eq!(secs(7.89e-4), "789.0 µs");
        assert_eq!(secs(5e-8), "50 ns");
    }

    #[test]
    fn pct() {
        assert_eq!(pct_delta(1.194), "+19.4%");
        assert_eq!(pct_delta(0.95), "-5.0%");
    }
}
