//! `daig` — command-line driver for the Delayed Asynchronous Iterative
//! Graph Algorithms library.
//!
//! ```text
//! daig run        --algo pagerank --graph kron --scale 14 --mode d256 --threads 32 [--engine sim|native] [--schedule dense|frontier|adaptive] [--machine haswell|cascadelake] [--batch k]
//! daig sweep      --algo pagerank --graph kron --scale 14 --threads 32 [--schedule dense] [--machine haswell]
//! daig experiment <table1|table2|fig2|fig3|fig4|fig5|fig6|ablations|schedule|batch|mutate|serve|shard|all> [--out results] [--scale 14]
//! daig mutate     --algo sssp --graph kron --scale 12 --frac 0.01 [--resume] [--engine native|sim] [--mode d256] [--schedule frontier]
//! daig serve      --graph kron --scale 12 --lanes 8 --queries 64 [--clients c | --qps x] [--mutate-every n]
//! daig shard      --connect 127.0.0.1:7700 --id 0 --shards 2 --graph kron --scale 12 [--mode async] [--threads 4] [--halo-delta n]
//! daig route      --listen 127.0.0.1:7700 --shards 2 --graph kron --scale 12 --queries 64 [--lanes 8] [--drill-kill S@Q]
//! daig stats      --graph web --scale 14 | --file graph.daig
//! daig gengraph   --graph kron --scale 14 --out kron.daig [--weighted]
//! daig convert    <in.el|in.mtx|in.daig> <out.dagc> [--symmetrize] [--n N] [--check]
//! daig pjrt-demo  [--graph kron] [--scale 8] [--artifacts artifacts]
//! ```

use anyhow::{bail, Context, Result};

use daig::coordinator::experiments::{self, ExpOptions};
use daig::coordinator::{machine_from_name, run_native, run_sim, sweep, Algo, Workload};
use daig::engine::{EngineConfig, ExecutionMode, RunResult, SchedulePolicy};
use daig::graph::gap::GapGraph;
use daig::graph::{io, properties, CompressedCsr, Csr, GraphStore};
use daig::util::cli::Args;
use daig::util::{fmt, table::Table};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("run") => cmd_run(args),
        Some("sweep") => cmd_sweep(args),
        Some("experiment") => cmd_experiment(args),
        Some("mutate") => cmd_mutate(args),
        Some("serve") => cmd_serve(args),
        Some("shard") => cmd_shard(args),
        Some("route") => cmd_route(args),
        Some("stats") => cmd_stats(args),
        Some("gengraph") => cmd_gengraph(args),
        Some("convert") => cmd_convert(args),
        Some("autotune") => cmd_autotune(args),
        Some("pjrt-demo") => cmd_pjrt_demo(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}' (try `daig help`)"),
    }
}

const HELP: &str = "daig — delayed asynchronous iterative graph algorithms

commands:
  run         run one algorithm/graph/mode configuration
  sweep       sync/async/δ-grid sweep at a fixed thread count
  experiment  regenerate a paper table/figure (table1 table2 fig2..fig6 ablations schedule steal adaptive batch mutate serve shard all)
  mutate      apply a random edge-mutation batch through the versioned
              overlay and recompute — with --resume also incrementally
              from the previous values + dirty frontier (sssp | pagerank;
              --frac F mutated edge fraction, --seed N batch RNG,
              --compact-frac F overlay compaction threshold)
  serve       always-on batched query serving: an SSSP/PPR query stream
              packs into k-lane groups over a resident engine with a
              version-keyed result cache and p50/p99 latency reporting
              (--lanes k, --queries N, --clients c closed loop |
              --qps x open loop, --queue N admission bound, --cache N,
              --ppr-frac F, --mutate-every N --frac F serve-while-mutating,
              --seed N workload RNG)
  shard       one worker process of a sharded cluster: owns a contiguous
              line-aligned vertex range, connects to the router with
              bounded-backoff retry (--connect ADDR, --retries N), runs
              one engine round per Continue, and ships boundary updates
              through per-remote-shard halo delay buffers (--id S
              --shards N; --halo-delta N overrides the mode-derived
              message δ; graph options must match the router's exactly)
  route       router process of a sharded cluster: binds --listen ADDR,
              accepts --shards N workers, draws the serve workload
              (--queries N, --ppr-frac F, --seed N), packs it into lane
              groups (--lanes k, --queue N) and runs each group as one
              scattered job across the shards; --timeout-ms N dead-shard
              detection, --drill-kill S@Q kills shard S after Q served
              queries (the degradation drill — see docs/OPERATIONS.md)
  stats       graph statistics (Table II columns)
  gengraph    generate a GAP-analog graph to a .daig file
  convert     pack an edge list (.el/.txt), MatrixMarket (.mtx), or .daig
              file into the block-compressed .dagc format (--symmetrize,
              --n N explicit vertex count for edge lists, --check full
              decode verification after writing)
  autotune    recommend an execution mode/δ from topology (§V future work)
  pjrt-demo   run PageRank + SSSP through the AOT/PJRT dense-block backend
  help        this text

common options:
  --graph kron|urand|twitter|web|road   --scale N (log2 vertices)
  --ef N (edge factor)                  --algo pagerank|sssp|cc|bfs
  --mode sync|async|dN|adaptive         --threads N
  --engine sim|native                   --machine haswell|cascadelake
  --schedule dense|frontier|adaptive    (which vertices each round sweeps)
  --steal                               (work-stealing round execution)
  --batch k                             (k ∈ 1|2|4|8|16: answer k queries in one
                                         run — SSSP: k sources, PageRank: k
                                         teleport sets — as interleaved value
                                         lanes; see `daig experiment batch`)
  --no-atomics                          (async mode only: owned vertices publish
                                         with plain stores, stolen chunks route
                                         through a one-line delay buffer)
  --prefetch N                          (software-prefetch neighbor values N
                                         neighbors ahead in the gather loop;
                                         0 = off. A pure hint: results are
                                         identical at every distance)
  --store csr|compressed                (run: graph storage tier. compressed =
                                         delta/varint block-compressed rows,
                                         decoded on the fly in the pull sweep;
                                         results identical, memory ~3-4x less)
  --mmap FILE.dagc                      (run: map a converted graph read-only
                                         from disk instead of generating one;
                                         implies the compressed store)
  --numa                                (run: line-align partitions, pin
                                         workers to their socket, and
                                         first-touch each partition's value
                                         pages from its owner. A placement
                                         hint: results are unchanged; no-op
                                         on single-socket hosts. In the sim
                                         engine, charges remote-DRAM cost for
                                         cross-socket cold fills instead)

Build with `--features simd` (nightly toolchain) to run the lane-group
kernels on std::simd vectors; the default scalar build is bit-identical.

`--mode adaptive` runs the online δ controller: each worker resizes its
delay buffer between rounds from flush-contention / frontier-density /
residual telemetry (see `daig experiment adaptive` for its regret vs the
exhaustive static sweep).
";

/// Render the run-headline suffix for the newer engine knobs: the
/// no-atomics publication scheme, a non-zero prefetch distance, and
/// whether this binary was built with the SIMD lane kernels.
fn ecfg_extras(ecfg: &EngineConfig) -> String {
    let mut s = String::new();
    if ecfg.no_atomics {
        s.push_str(", no-atomics");
    }
    if ecfg.prefetch != 0 {
        s.push_str(&format!(", prefetch={}", ecfg.prefetch));
    }
    if daig::engine::kernels::simd_enabled() {
        s.push_str(", simd");
    }
    s
}

/// Parse the `--schedule` option (default dense, the paper's behavior).
/// Unknown labels are a hard error naming the offending input — never a
/// silent fallback.
fn parse_schedule(args: &Args) -> Result<SchedulePolicy> {
    let label = args.opt_str("schedule", "dense");
    SchedulePolicy::from_label(&label)
        .with_context(|| format!("bad --schedule '{label}' (expected dense | frontier | adaptive)"))
}

/// Parse the `--mode` option. `ExecutionMode::from_label` returns `None`
/// for anything it does not recognize; surfacing the rejected label here
/// is what keeps typos like `--mode d256x` from silently running a
/// default configuration.
fn parse_mode(args: &Args, default: &str) -> Result<ExecutionMode> {
    let label = args.opt_str("mode", default);
    ExecutionMode::from_label(&label)
        .with_context(|| format!("bad --mode '{label}' (expected sync | async | dN | adaptive)"))
}

/// Elide a long per-round series in the middle (shared by the
/// active-vertex and adaptive-δ trajectories).
fn fmt_series(a: &[u64]) -> String {
    let shown: Vec<String> = if a.len() <= 12 {
        a.iter().map(u64::to_string).collect()
    } else {
        let mut s: Vec<String> = a[..6].iter().map(u64::to_string).collect();
        s.push("…".into());
        s.extend(a[a.len() - 5..].iter().map(u64::to_string));
        s
    };
    format!("[{}]", shown.join(", "))
}

/// Render the per-round active-vertex trajectory, elided in the middle
/// for long runs — the visible evidence that sparse scheduling engages.
fn fmt_actives(r: &RunResult) -> String {
    fmt_series(&r.active_counts())
}

/// Render thread 0's per-round δ trajectory — the visible evidence that
/// the adaptive controller engages (empty trace = non-adaptive run).
fn fmt_deltas(r: &RunResult) -> String {
    let t0: Vec<u64> = r.delta_trace_of(0).into_iter().map(|d| d as u64).collect();
    fmt_series(&t0)
}

/// The storage tiers `daig run` can execute on. Every engine entry point
/// is generic over [`GraphStore`], so the two arms run the identical
/// round machinery — this enum only exists to pick the monomorphization
/// at the CLI boundary.
enum AnyStore {
    Csr(Csr),
    Compressed(CompressedCsr),
}

impl AnyStore {
    fn num_vertices(&self) -> usize {
        match self {
            AnyStore::Csr(g) => g.num_vertices(),
            AnyStore::Compressed(c) => c.num_vertices(),
        }
    }

    fn num_edges(&self) -> usize {
        match self {
            AnyStore::Csr(g) => g.num_edges(),
            AnyStore::Compressed(c) => c.num_edges(),
        }
    }
}

/// Resolve `--store csr|compressed` / `--mmap FILE.dagc` on top of the
/// usual workload options. `--mmap` skips generation entirely and maps
/// the converted file read-only; `--store compressed` packs the
/// generated (or `--file`-loaded) graph in RAM. The returned string
/// describes the source for the run headline.
fn parse_store(args: &Args) -> Result<(Workload, AnyStore, String)> {
    if let Some(file) = args.options.get("mmap") {
        let algo = Algo::from_name(&args.opt_str("algo", "pagerank")).context("bad --algo")?;
        let g = CompressedCsr::open_mmap(std::path::Path::new(file))?;
        if algo.weighted() && !g.is_weighted() {
            bail!("--algo {} needs edge weights but {file} is unweighted (convert a weighted graph)", algo.name());
        }
        let w = Workload { algo, graph: GapGraph::Kron, scale: 0, edge_factor: 0 };
        return Ok((w, AnyStore::Compressed(g), format!("{file} (mmap)")));
    }
    let (w, g) = parse_workload(args)?;
    let name = args.opt_str("graph", "kron");
    match args.opt_str("store", "csr").as_str() {
        "csr" => Ok((w, AnyStore::Csr(g), name)),
        "compressed" => {
            let c = CompressedCsr::from_csr(&g);
            let desc = format!("{name} (compressed, {:.2} B/edge)", c.bytes_per_edge());
            Ok((w, AnyStore::Compressed(c), desc))
        }
        other => bail!("unknown --store '{other}' (csr | compressed)"),
    }
}

fn parse_workload(args: &Args) -> Result<(Workload, Csr)> {
    let algo = Algo::from_name(&args.opt_str("algo", "pagerank")).context("bad --algo")?;
    if let Some(file) = args.options.get("file") {
        let g = io::read_binary(std::path::Path::new(file))?;
        return Ok((Workload { algo, graph: GapGraph::Kron, scale: 0, edge_factor: 0 }, g));
    }
    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let w = Workload { algo, graph, scale: args.opt("scale", 14)?, edge_factor: args.opt("ef", 0)? };
    let g = w.build_graph();
    Ok((w, g))
}

fn cmd_run(args: &Args) -> Result<()> {
    let (w, store, desc) = parse_store(args)?;
    let mode = parse_mode(args, "d256")?;
    let threads: usize = args.opt("threads", 32)?;
    let schedule = parse_schedule(args)?;
    let mut ecfg = EngineConfig::new(threads, mode).with_schedule(schedule);
    if args.flag("local-reads") {
        ecfg = ecfg.with_local_reads();
    }
    if args.flag("steal") {
        ecfg = ecfg.with_stealing();
    }
    if args.flag("numa") {
        ecfg = ecfg.with_numa();
    }
    if args.flag("no-atomics") {
        if mode != ExecutionMode::Asynchronous {
            bail!(
                "--no-atomics requires --mode async (got {}): sync publishes through the \
                 double buffer and delayed/adaptive already publish through sized buffers",
                mode.label()
            );
        }
        ecfg = ecfg.with_no_atomics();
    }
    ecfg = ecfg.with_prefetch(args.opt("prefetch", 0)?);
    // Anything but the default single-query batch goes through the
    // batched path — including illegal values like 0, which it rejects
    // with a clear error instead of silently running one query.
    let batch: usize = args.opt("batch", 1)?;
    if batch != 1 {
        return match &store {
            AnyStore::Csr(g) => cmd_run_batched(args, &w, g, &desc, &ecfg, batch),
            AnyStore::Compressed(c) => cmd_run_batched(args, &w, c, &desc, &ecfg, batch),
        };
    }
    println!(
        "{} on {} (n={}, m={}), mode={}, schedule={}, threads={}{}{}",
        w.algo.name(),
        desc,
        store.num_vertices(),
        store.num_edges(),
        mode.label(),
        schedule.label(),
        threads,
        if ecfg.stealing { ", stealing" } else { "" },
        ecfg_extras(&ecfg)
    );
    match args.opt_str("engine", "sim").as_str() {
        "native" => {
            let r = match &store {
                AnyStore::Csr(g) => run_native(g, w.algo, &ecfg),
                AnyStore::Compressed(c) => run_native(c, w.algo, &ecfg),
            };
            println!(
                "rounds={} total={} avg/round={} updates={} steals={} converged={}",
                r.num_rounds(),
                fmt::secs(r.total_time()),
                fmt::secs(r.avg_round_time()),
                fmt::si(r.total_active() as f64),
                r.total_steals(),
                r.converged
            );
            if schedule != SchedulePolicy::Dense {
                println!("active/round = {}", fmt_actives(&r));
            }
            if mode == ExecutionMode::Adaptive {
                println!(
                    "δ/round (t0) = {} (final median δ = {})",
                    fmt_deltas(&r),
                    r.final_delta_median().unwrap_or(0)
                );
            }
        }
        "sim" => {
            let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
            let s = match &store {
                AnyStore::Csr(g) => run_sim(g, w.algo, &ecfg, &machine),
                AnyStore::Compressed(c) => run_sim(c, w.algo, &ecfg, &machine),
            };
            println!(
                "rounds={} total={} avg/round={} cycles={} invalidations={} flushes={} updates={} steals={} converged={}",
                s.result.num_rounds(),
                fmt::secs(s.result.total_time()),
                fmt::secs(s.result.avg_round_time()),
                fmt::si(s.total_cycles() as f64),
                fmt::si(s.metrics.invalidations as f64),
                s.result.total_flushes(),
                fmt::si(s.result.total_active() as f64),
                s.result.total_steals(),
                s.result.converged
            );
            if schedule != SchedulePolicy::Dense {
                println!("active/round = {}", fmt_actives(&s.result));
            }
            if mode == ExecutionMode::Adaptive {
                println!(
                    "δ/round (t0) = {} (final median δ = {})",
                    fmt_deltas(&s.result),
                    s.result.final_delta_median().unwrap_or(0)
                );
            }
        }
        other => bail!("unknown engine '{other}'"),
    }
    Ok(())
}

/// `daig run --batch k`: answer k independent queries in one
/// lane-batched engine run (SSSP: the k top-degree sources; PageRank: k
/// singleton teleport sets on the same hubs). Reports the serving
/// headline — queries/sec — plus when each query's lane settled.
fn cmd_run_batched<G: GraphStore>(
    args: &Args,
    w: &Workload,
    g: &G,
    desc: &str,
    ecfg: &EngineConfig,
    k: usize,
) -> Result<()> {
    use daig::algorithms::{pagerank, sssp};
    use daig::engine::lanes;
    if !lanes::valid_lane_count(k) {
        bail!("bad --batch {k} (expected 1, 2, 4, 8, or 16: lane groups must divide a cache line)");
    }
    if k > g.num_vertices() {
        bail!("--batch {k} needs at least {k} vertices for distinct queries (graph has {})", g.num_vertices());
    }
    println!(
        "{} x{k} batched on {} (n={}, m={}), mode={}, schedule={}, threads={}{}{}",
        w.algo.name(),
        desc,
        g.num_vertices(),
        g.num_edges(),
        ecfg.mode.label(),
        ecfg.schedule.label(),
        ecfg.threads,
        if ecfg.stealing { ", stealing" } else { "" },
        ecfg_extras(ecfg)
    );
    let engine = args.opt_str("engine", "sim");
    let run: RunResult = match (w.algo, engine.as_str()) {
        (Algo::Sssp, "native") => sssp::run_native_batch(g, &sssp::default_sources(g, k), ecfg).run,
        (Algo::Sssp, "sim") => {
            let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
            sssp::run_sim_batch(g, &sssp::default_sources(g, k), ecfg, &machine).0.run
        }
        (Algo::PageRank, "native") => {
            let teleports = pagerank::default_teleports(g, k);
            pagerank::run_native_batch(g, &teleports, ecfg, &pagerank::PrConfig::default()).run
        }
        (Algo::PageRank, "sim") => {
            let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
            let teleports = pagerank::default_teleports(g, k);
            pagerank::run_sim_batch(g, &teleports, ecfg, &pagerank::PrConfig::default(), &machine).0.run
        }
        (algo, "sim" | "native") => bail!("--batch supports sssp | pagerank (got {})", algo.name()),
        (_, other) => bail!("unknown engine '{other}'"),
    };
    let total = run.total_time();
    println!(
        "rounds={} total={} queries/s={:.1} updates={} flushes={} steals={} converged={}",
        run.num_rounds(),
        fmt::secs(total),
        if total > 0.0 { k as f64 / total } else { 0.0 },
        fmt::si(run.total_active() as f64),
        run.total_flushes(),
        run.total_steals(),
        run.converged
    );
    // Per-query drop-out: the round after which each lane went quiet.
    let settle: Vec<String> = (0..k).map(|l| format!("q{l}:{}", run.lane_settle_round(l))).collect();
    println!("lane settle rounds = [{}]", settle.join(", "));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let (w, g) = parse_workload(args)?;
    let threads: usize = args.opt("threads", 32)?;
    let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
    let schedule = parse_schedule(args)?;
    let mut base = EngineConfig::new(threads, ExecutionMode::Synchronous).with_schedule(schedule);
    if args.flag("steal") {
        base = base.with_stealing();
    }
    let pts = sweep::modes_base(&g, w.algo, &machine, &base);
    let sync_t = sweep::find_mode(&pts, ExecutionMode::Synchronous).unwrap().time_s;
    let mut t = Table::new(
        &format!(
            "{} δ-sweep, {} threads, {} schedule{}, {}",
            w.algo.name(),
            threads,
            schedule.label(),
            if base.stealing { ", stealing" } else { "" },
            machine.name
        ),
        &["mode", "rounds", "total", "avg/round", "invalidations", "flushes", "updates", "steals", "speedup vs sync"],
    );
    for p in &pts {
        t.row(vec![
            p.mode.label(),
            p.rounds.to_string(),
            fmt::secs(p.time_s),
            fmt::secs(p.avg_round_s),
            fmt::si(p.invalidations as f64),
            p.flushes.to_string(),
            fmt::si(p.active_total as f64),
            p.steals.to_string(),
            format!("{:.3}x", sync_t / p.time_s),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = match args.positional.first() {
        Some(x) => x.clone(),
        None => bail!("usage: daig experiment <id> [--out results]"),
    };
    let mut opts = ExpOptions::to_dir(&args.opt_str("out", "results"))?;
    opts.scale = args.opt("scale", 14)?;
    opts.edge_factor = args.opt("ef", 0)?;
    let t0 = std::time::Instant::now();
    experiments::run(&id, &opts)?;
    println!("experiment {id} done in {}", fmt::secs(t0.elapsed().as_secs_f64()));
    Ok(())
}

/// `daig mutate`: wrap the workload graph in a [`VersionedGraph`]
/// overlay, converge once, apply a deterministic random edge-mutation
/// batch, and recompute from scratch on the mutated graph. With
/// `--resume`, also warm-start from the converged values + the
/// algorithm's reset/dirty rule and report the update-to-fresh-result
/// comparison.
fn cmd_mutate(args: &Args) -> Result<()> {
    use daig::algorithms::{pagerank, sssp};
    use daig::engine::sim::cost::Machine;
    use daig::graph::VersionedGraph;

    let (w, g) = parse_workload(args)?;
    if !matches!(w.algo, Algo::Sssp | Algo::PageRank) {
        bail!("mutate supports sssp | pagerank (got {}): cc/bfs have no resume rule yet", w.algo.name());
    }
    let mode = parse_mode(args, "d256")?;
    let threads: usize = args.opt("threads", 8)?;
    // Frontier default: the dirty-set warm start is the point of the
    // command, and it only prunes work under a sparse schedule.
    let label = args.opt_str("schedule", "frontier");
    let schedule = SchedulePolicy::from_label(&label)
        .with_context(|| format!("bad --schedule '{label}' (expected dense | frontier | adaptive)"))?;
    let frac: f64 = args.opt("frac", 0.01)?;
    let seed: u64 = args.opt("seed", 42)?;
    let engine = args.opt_str("engine", "native");
    let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
    let ecfg = EngineConfig::new(threads, mode).with_schedule(schedule);

    // The query is pinned before mutating: the batch may change which
    // vertex is the top-degree hub, but it must not change the question.
    let source = sssp::default_source(&g);
    let (n, m) = (g.num_vertices(), g.num_edges());
    let mut vg = VersionedGraph::new(g).with_compaction_threshold(args.opt("compact-frac", 0.25)?);

    fn one(
        vg: &VersionedGraph,
        algo: Algo,
        source: u32,
        ecfg: &EngineConfig,
        engine: &str,
        machine: &Machine,
    ) -> Result<RunResult> {
        Ok(match (algo, engine) {
            (Algo::Sssp, "native") => sssp::run_native(vg, source, ecfg).run,
            (Algo::Sssp, "sim") => sssp::run_sim(vg, source, ecfg, machine).0.run,
            (Algo::PageRank, "native") => pagerank::run_native(vg, ecfg, &pagerank::PrConfig::default()).run,
            (Algo::PageRank, "sim") => pagerank::run_sim(vg, ecfg, &pagerank::PrConfig::default(), machine).0.run,
            (_, other) => bail!("unknown engine '{other}' (native | sim)"),
        })
    }

    println!(
        "{} on {} (n={n}, m={m}), mode={}, schedule={}, threads={threads}, engine={engine}",
        w.algo.name(),
        args.opt_str("graph", "kron"),
        mode.label(),
        schedule.label(),
    );
    let before = one(&vg, w.algo, source, &ecfg, &engine, &machine)?;
    println!(
        "converged  : rounds={} total={} updates={} (version {})",
        before.num_rounds(),
        fmt::secs(before.total_time()),
        fmt::si(before.total_active() as f64),
        vg.version().0
    );

    let batch = vg.random_batch(frac, seed);
    let receipt = vg.apply_batch(&batch)?;
    println!(
        "mutated    : +{} -{} edges ({}% of m, seed {seed}) -> version {}{}",
        receipt.inserted.len(),
        receipt.deleted.len(),
        frac * 100.0,
        receipt.version.0,
        if receipt.compacted { ", compacted" } else { "" }
    );

    let full = one(&vg, w.algo, source, &ecfg, &engine, &machine)?;
    println!(
        "full       : rounds={} total={} updates={} converged={}",
        full.num_rounds(),
        fmt::secs(full.total_time()),
        fmt::si(full.total_active() as f64),
        full.converged
    );

    if args.flag("resume") {
        let rseed = match w.algo {
            Algo::Sssp => sssp::resume_seed(&vg, source, &before, &batch),
            _ => pagerank::resume_seed(&vg, &before, &batch),
        };
        let dirty = rseed.dirty.len();
        let resumed = one(&vg, w.algo, source, &ecfg.clone().with_resume(rseed), &engine, &machine)?;
        let max_diff = full
            .values
            .iter()
            .zip(&resumed.values)
            .map(|(&a, &b)| (f32::from_bits(a) - f32::from_bits(b)).abs())
            .fold(0.0f32, f32::max);
        println!(
            "resumed    : rounds={} total={} updates={} converged={} (dirty {dirty}/{n})",
            resumed.num_rounds(),
            fmt::secs(resumed.total_time()),
            fmt::si(resumed.total_active() as f64),
            resumed.converged
        );
        let agree = match w.algo {
            // Bellman-Ford's fixed point is unique: bit equality.
            Algo::Sssp => full.values == resumed.values,
            // PageRank iterates stop within ε of the fixed point.
            _ => max_diff < 1e-3,
        };
        println!(
            "incremental: {:.2}x fewer updates, {:.2}x time speedup, results {}",
            full.total_active() as f64 / resumed.total_active().max(1) as f64,
            full.total_time() / resumed.total_time().max(f64::MIN_POSITIVE),
            if agree { "agree" } else { "DISAGREE" }
        );
        if !agree {
            bail!("resumed run disagrees with full recompute (max |diff| {max_diff})");
        }
    }
    Ok(())
}

/// `daig serve`: start the always-on query server over the workload
/// graph (weighted — the mixed stream includes SSSP), drive it with a
/// deterministic closed- or open-loop load, and report throughput,
/// backpressure, cache behavior, and the p50/p99 latency SLO line.
fn cmd_serve(args: &Args) -> Result<()> {
    use daig::graph::VersionedGraph;
    use daig::serve::{loadgen, LoadSpec, QueryServer, ServeConfig};

    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let scale: u32 = args.opt("scale", 12)?;
    let ef: usize = args.opt("ef", 0)?;
    let g = graph.generate_weighted(scale, ef);
    let (n, m) = (g.num_vertices(), g.num_edges());

    let lanes: usize = args.opt("lanes", 8)?;
    if !daig::engine::lanes::valid_lane_count(lanes) {
        bail!("bad --lanes {lanes} (expected 1, 2, 4, 8, or 16: lane groups must divide a cache line)");
    }
    let mode = parse_mode(args, "async")?;
    let threads: usize = args.opt("threads", 8)?;
    let schedule = parse_schedule(args)?;
    let mut ecfg = EngineConfig::new(threads, mode).with_schedule(schedule);
    if args.flag("steal") {
        ecfg = ecfg.with_stealing();
    }
    ecfg = ecfg.with_prefetch(args.opt("prefetch", 0)?);

    let mut cfg = ServeConfig::new(lanes, ecfg);
    cfg.queue_capacity = args.opt("queue", cfg.queue_capacity)?;
    cfg.cache_capacity = args.opt("cache", cfg.cache_capacity)?;

    let queries: usize = args.opt("queries", 64)?;
    let seed: u64 = args.opt("seed", 42)?;
    let mut spec = match args.options.get("qps") {
        Some(q) => {
            let qps: f64 = q.parse().map_err(|_| anyhow::anyhow!("--qps: cannot parse '{q}'"))?;
            LoadSpec::open(qps, queries, seed)
        }
        None => LoadSpec::closed(args.opt("clients", 2 * lanes)?, queries, seed),
    };
    spec.ppr_frac = args.opt("ppr-frac", 0.25)?;
    let mutate_every: usize = args.opt("mutate-every", 0)?;
    if mutate_every > 0 {
        spec = spec.with_mutations(mutate_every, args.opt("frac", 0.01)?);
    }

    let loop_desc = match spec.mode {
        daig::serve::LoadMode::Closed { clients } => format!("closed loop, {clients} clients"),
        daig::serve::LoadMode::Open { qps } => format!("open loop, {qps} qps offered"),
    };
    println!(
        "serve on {} (n={n}, m={m}), lanes={lanes}, mode={}, schedule={}, threads={threads}, \
         queue={}, cache={}, {loop_desc}, {queries} queries{}",
        args.opt_str("graph", "kron"),
        mode.label(),
        schedule.label(),
        cfg.queue_capacity,
        cfg.cache_capacity,
        if mutate_every > 0 { format!(", mutate every {mutate_every}") } else { String::new() },
    );

    let server = QueryServer::start(VersionedGraph::new(g), cfg);
    let report = loadgen::run(&server, n, &spec);
    let stats = server.shutdown();

    println!(
        "served={} ({} cached) rejected={} mutations={} elapsed={} queries/s={:.1}",
        report.served,
        report.cached,
        report.rejected,
        report.mutations,
        fmt::secs(report.elapsed_s),
        report.qps
    );
    println!(
        "latency    : p50={} p90={} p99={} max={} (n={}, dropped={})",
        fmt::secs(report.hist.percentile_secs(0.50)),
        fmt::secs(report.hist.percentile_secs(0.90)),
        fmt::secs(report.hist.percentile_secs(0.99)),
        fmt::secs(report.hist.max() as f64 / 1e9),
        report.hist.count(),
        report.hist.dropped()
    );
    println!(
        "server     : engine-served={} cache-served={} hits/misses={}/{} evictions={} invalidated={} (version {})",
        stats.served_engine,
        stats.served_cached,
        stats.cache.hits,
        stats.cache.misses,
        stats.cache.evictions,
        stats.cache.invalidated,
        stats.version.0
    );
    // One machine-greppable line for the CI smoke: the job asserts a
    // query was served and the process exited cleanly.
    println!("serve ok: {} served, clean shutdown", report.served);
    Ok(())
}

fn cmd_shard(args: &Args) -> Result<()> {
    use daig::shard::{serve_loop, SocketTransport, WorkerCfg};

    let addr = args.opt_str("connect", "127.0.0.1:7700");
    let id: u32 = args.opt("id", 0)?;
    let shards: usize = args.opt("shards", 2)?;
    if (id as usize) >= shards {
        bail!("--id {id} out of range for --shards {shards}");
    }
    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let scale: u32 = args.opt("scale", 12)?;
    let ef: usize = args.opt("ef", 0)?;
    let g = graph.generate_weighted(scale, ef);

    let mode = parse_mode(args, "async")?;
    let threads: usize = args.opt("threads", 4)?;
    let schedule = parse_schedule(args)?;
    let mut ecfg = EngineConfig::new(threads, mode).with_schedule(schedule);
    if args.flag("steal") {
        ecfg = ecfg.with_stealing();
    }
    // None defers to the mode-derived δ (shard::halo_delta) per job.
    let halo_delta = args
        .options
        .get("halo-delta")
        .map(|v| v.parse::<usize>().map_err(|_| anyhow::anyhow!("--halo-delta: cannot parse '{v}'")))
        .transpose()?;
    let retries: u32 = args.opt("retries", 30)?;

    println!(
        "shard {id}/{shards} on {} (n={}, m={}), mode={}, schedule={}, threads={threads}, connecting to {addr}",
        args.opt_str("graph", "kron"),
        g.num_vertices(),
        g.num_edges(),
        mode.label(),
        schedule.label(),
    );
    let mut t = SocketTransport::connect_retry(&addr, retries, std::time::Duration::from_millis(100))?;
    let cfg = WorkerCfg { shard: id, shards, ecfg, halo_delta };
    let served = serve_loop(&mut t, &g, &cfg)?;
    // One machine-greppable line per worker for the CI socket smoke.
    println!("shard {id} ok: {served} jobs served, clean shutdown");
    Ok(())
}

fn cmd_route(args: &Args) -> Result<()> {
    use daig::serve::{loadgen, BatchFormer, LatencyHistogram, Query, QueryClass, QueueFull};
    use daig::shard::{JobClass, Router, ShardError, SocketListener};
    use daig::util::rng::SplitMix64;
    use std::time::{Duration, Instant};

    let addr = args.opt_str("listen", "127.0.0.1:7700");
    let shards: usize = args.opt("shards", 2)?;
    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let scale: u32 = args.opt("scale", 12)?;
    let ef: usize = args.opt("ef", 0)?;
    let g = graph.generate_weighted(scale, ef);
    let n = g.num_vertices();

    let lanes: usize = args.opt("lanes", 8)?;
    if !daig::engine::lanes::valid_lane_count(lanes) {
        bail!("bad --lanes {lanes} (expected 1, 2, 4, 8, or 16: lane groups must divide a cache line)");
    }
    let queries: usize = args.opt("queries", 64)?;
    let queue: usize = args.opt("queue", 256)?;
    let ppr_frac: f64 = args.opt("ppr-frac", 0.25)?;
    let seed: u64 = args.opt("seed", 42)?;
    let timeout_ms: u64 = args.opt("timeout-ms", 30_000)?;
    // --drill-kill S@Q: kill shard S once Q queries have been served.
    let drill: Option<(u32, usize)> = match args.options.get("drill-kill") {
        None => None,
        Some(v) => {
            let parsed = v
                .split_once('@')
                .and_then(|(s, q)| Some((s.parse::<u32>().ok()?, q.parse::<usize>().ok()?)));
            Some(parsed.ok_or_else(|| anyhow::anyhow!("--drill-kill: expected S@Q, got '{v}'"))?)
        }
    };

    let listener = SocketListener::bind(&addr)?;
    println!(
        "route on {} (n={n}, m={}): listening on {addr}, waiting for {shards} shards",
        args.opt_str("graph", "kron"),
        g.num_edges(),
    );
    let mut transports = Vec::with_capacity(shards);
    for _ in 0..shards {
        transports.push(listener.accept()?);
    }
    let mut router = Router::new(&g, transports);
    router.timeout = Duration::from_millis(timeout_ms);
    router.handshake()?;
    println!("route: {shards} shards connected, serving {queries} queries, lanes={lanes}");

    let mut rng = SplitMix64::new(seed);
    let mut former: BatchFormer<Query> = BatchFormer::new(lanes, queue);
    let mut hist = LatencyHistogram::new();
    let (mut issued, mut served, mut failed, mut degraded) = (0usize, 0usize, 0usize, 0usize);
    let (mut jobs, mut halo_msgs, mut halo_entries) = (0u64, 0u64, 0u64);
    let mut killed = false;
    while served + failed < queries {
        while issued < queries {
            let q = loadgen::next_query(&mut rng, n, ppr_frac);
            match former.admit(q.class(), q) {
                Ok(()) => issued += 1,
                Err(QueueFull(_)) => break,
            }
        }
        let Some(batch) = former.form() else {
            bail!("route: no batch formable with {} pending queries", former.pending());
        };
        let class = match batch.class {
            QueryClass::Sssp => JobClass::Sssp {
                sources: batch
                    .items
                    .iter()
                    .map(|q| match q {
                        Query::Sssp { source } => *source,
                        Query::Ppr { .. } => unreachable!("batch class is Sssp"),
                    })
                    .collect(),
            },
            QueryClass::Ppr => JobClass::Ppr {
                teleports: batch
                    .items
                    .iter()
                    .map(|q| match q {
                        Query::Ppr { teleports } => teleports.clone(),
                        Query::Sssp { .. } => unreachable!("batch class is Ppr"),
                    })
                    .collect(),
                damping: 0.85,
                epsilon: 1e-3,
            },
        };
        let t0 = Instant::now();
        match router.run_job(&class) {
            Ok(res) => {
                let dt = t0.elapsed().as_secs_f64();
                for _ in 0..batch.items.len() {
                    hist.record_secs(dt);
                }
                served += batch.items.len();
                if res.degraded {
                    degraded += batch.items.len();
                }
                jobs += 1;
                halo_msgs += res.halo_msgs;
                halo_entries += res.halo_entries;
            }
            Err(ShardError::NoLiveShards) => bail!("route: every shard is dead, aborting"),
            Err(e) => {
                // Typed degradation: the query's parameters land on a
                // dead shard (or one died mid-job). The job fails; the
                // cluster keeps serving everything else.
                failed += batch.items.len();
                eprintln!("route: job failed ({} queries): {e}", batch.items.len());
            }
        }
        former.release(&batch.lanes);
        if let Some((s, after)) = drill {
            if !killed && served >= after {
                router.drill_kill(s);
                killed = true;
                println!("route: drill-killed shard {s} after {served} served");
            }
        }
    }
    let live = router.live();
    router.shutdown();

    println!(
        "served={served} failed={failed} degraded={degraded} jobs={jobs} live-shards={live}/{shards} \
         halo: {halo_msgs} msgs / {halo_entries} entries",
    );
    println!(
        "latency    : p50={} p90={} p99={} max={} (n={}, dropped={})",
        fmt::secs(hist.percentile_secs(0.50)),
        fmt::secs(hist.percentile_secs(0.90)),
        fmt::secs(hist.percentile_secs(0.99)),
        fmt::secs(hist.max() as f64 / 1e9),
        hist.count(),
        hist.dropped()
    );
    // One machine-greppable line for the CI smoke and degradation drill.
    println!("route ok: {served} served, {failed} failed, clean shutdown");
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let (_, g) = parse_workload(args)?;
    let s = properties::stats(&g);
    println!("{s:#?}");
    Ok(())
}

fn cmd_gengraph(args: &Args) -> Result<()> {
    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let scale: u32 = args.opt("scale", 14)?;
    let ef: usize = args.opt("ef", 0)?;
    let g = if args.flag("weighted") { graph.generate_weighted(scale, ef) } else { graph.generate(scale, ef) };
    let out = args.opt_str("out", &format!("{}_{}.daig", graph.name(), scale));
    io::write_binary(&g, std::path::Path::new(&out))?;
    println!("wrote {} (n={}, m={})", out, g.num_vertices(), g.num_edges());
    Ok(())
}

/// `daig convert`: pack an edge-list / MatrixMarket / `.daig` graph into
/// the block-compressed on-disk `.dagc` format that `--mmap` maps and
/// `--store compressed` holds in RAM. Input format is picked by
/// extension (`.mtx` → MatrixMarket, `.daig` → binary CSR, anything
/// else → whitespace edge list).
fn cmd_convert(args: &Args) -> Result<()> {
    let (input, output) = match (args.positional.first(), args.positional.get(1)) {
        (Some(i), Some(o)) => (i.clone(), o.clone()),
        _ => bail!("usage: daig convert <in.el|in.mtx|in.daig> <out.dagc> [--symmetrize] [--n N] [--check]"),
    };
    let inp = std::path::Path::new(&input);
    let n = match args.options.get("n") {
        Some(s) => Some(s.parse::<usize>().map_err(|_| anyhow::anyhow!("--n: cannot parse '{s}'"))?),
        None => None,
    };
    let g = match inp.extension().and_then(|e| e.to_str()) {
        Some("mtx") => io::read_matrix_market(inp)?,
        Some("daig") => io::read_binary(inp)?,
        _ => io::read_edge_list(inp, n, args.flag("symmetrize"))?,
    };
    let c = CompressedCsr::from_csr(&g);
    c.write(std::path::Path::new(&output))?;
    if args.flag("check") {
        // Re-open what we just wrote and decode every row: catches both
        // encode bugs and a bad disk write before anyone maps the file.
        let back = CompressedCsr::open_in_ram(std::path::Path::new(&output))?;
        back.verify_decode()?;
        if back.to_csr() != g {
            bail!("post-write verification failed: decoded graph differs from the input");
        }
        println!("verified: full decode matches the input graph");
    }
    // Raw CSR footprint for the same graph: u64 offsets, u32 sources
    // (+ u32 weights), u32 out-degrees.
    let raw = 8 * (g.num_vertices() + 1)
        + 4 * g.num_edges() * if g.is_weighted() { 2 } else { 1 }
        + 4 * g.num_vertices();
    println!(
        "wrote {output}: n={}, m={}, {} bytes ({:.2} B/edge; raw csr arrays {} bytes)",
        c.num_vertices(),
        c.num_edges(),
        c.image().len(),
        c.bytes_per_edge(),
        raw,
    );
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let (w, g) = parse_workload(args)?;
    let threads: usize = args.opt("threads", 32)?;
    let rec = daig::coordinator::autotune::recommend(&g, w.algo, threads);
    println!(
        "workload : {} on {} (n={}, m={}), {} threads",
        w.algo.name(),
        args.opt_str("graph", "kron"),
        g.num_vertices(),
        g.num_edges(),
        threads
    );
    println!("recommend: {}", rec.mode.label());
    println!("locality : {:.3}", rec.locality);
    println!("reason   : {}", rec.reason);
    if args.flag("validate") {
        let machine = machine_from_name(&args.opt_str("machine", "haswell"))?;
        let rec_pt = sweep::point(&g, w.algo, threads, &machine, rec.mode);
        let pts = sweep::modes(&g, w.algo, threads, &machine);
        let best = pts
            .iter()
            .filter(|p| p.mode != ExecutionMode::Synchronous)
            .min_by(|a, b| a.time_s.partial_cmp(&b.time_s).unwrap())
            .unwrap();
        println!(
            "validate : recommended {} = {}, sweep best {} = {} (regret {})",
            rec.mode.label(),
            fmt::secs(rec_pt.time_s),
            best.mode.label(),
            fmt::secs(best.time_s),
            fmt::pct_delta(rec_pt.time_s / best.time_s)
        );
    }
    Ok(())
}

fn cmd_pjrt_demo(args: &Args) -> Result<()> {
    use daig::runtime::{block_backend, Runtime};
    let scale: u32 = args.opt("scale", 8)?;
    let graph = GapGraph::from_name(&args.opt_str("graph", "kron")).context("bad --graph")?;
    let dir = args.opt_str("artifacts", "artifacts");
    let rt = Runtime::load(std::path::Path::new(&dir))?;
    println!("PJRT platform: {}", rt.platform());

    let g = graph.generate(scale, 8);
    println!("dense-block PageRank on {} (n={})", graph.name(), g.num_vertices());
    let pr = block_backend::pagerank(&rt, &g, &Default::default(), 200)?;
    println!("  rounds={} converged={} mass={:.4}", pr.rounds, pr.converged, pr.values.iter().sum::<f32>());

    let gw = graph.generate_weighted(scale, 8);
    let src = daig::algorithms::sssp::default_source(&gw);
    let ss = block_backend::sssp(&rt, &gw, src, 200)?;
    let reached = ss.values.iter().filter(|d| d.is_finite()).count();
    println!("dense-block SSSP: rounds={} converged={} reached={}", ss.rounds, ss.converged, reached);
    Ok(())
}
