//! Direction-optimizing BFS (Beamer, Asanović & Patterson 2012) — the
//! other classical hybrid the paper cites (§II-B) as design precedent:
//! unlike the paper's δ (a continuous blend), DO-BFS *switches
//! discretely* between top-down (push) and bottom-up (pull) per
//! iteration using a frontier-size heuristic. Implemented as a baseline
//! so the two hybridization styles can be compared on the same graphs
//! (`rust/tests/integration.rs::dobfs_matches_engine_bfs`).

use crate::graph::{Csr, VertexId};

/// Unreached marker (matches [`crate::algorithms::bfs::UNREACHED`]).
pub const UNREACHED: u32 = u32::MAX;

/// Heuristic parameters from the DO-BFS paper: switch to bottom-up when
/// the frontier's out-edges exceed `1/alpha` of the unexplored edges,
/// back to top-down when the frontier shrinks below `n / beta`.
#[derive(Debug, Clone, Copy)]
pub struct DoBfsParams {
    pub alpha: usize,
    pub beta: usize,
}

impl Default for DoBfsParams {
    fn default() -> Self {
        Self { alpha: 14, beta: 24 }
    }
}

/// Per-round direction decisions (exposed for tests/inspection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    TopDown,
    BottomUp,
}

/// BFS levels from `source` with direction optimization. Works on the
/// pull representation: bottom-up scans in-neighbors directly; top-down
/// uses the transpose built once up front.
pub fn run(g: &Csr, source: VertexId, p: DoBfsParams) -> (Vec<u32>, Vec<Direction>) {
    let n = g.num_vertices();
    // Transpose (out-edges) for the push direction — the Csr's shared
    // out-edge view, also used by the engine's frontier scheduling.
    g.ensure_out_edges();

    let mut level = vec![UNREACHED; n];
    level[source as usize] = 0;
    let mut frontier: Vec<VertexId> = vec![source];
    let mut directions = Vec::new();
    let mut depth = 0u32;
    let mut unexplored_edges: usize = g.num_edges();

    while !frontier.is_empty() {
        let frontier_edges: usize = frontier.iter().map(|&v| g.out_degree(v) as usize).sum();
        let dir = if frontier_edges * p.alpha > unexplored_edges {
            Direction::BottomUp
        } else {
            Direction::TopDown
        };
        directions.push(dir);
        unexplored_edges = unexplored_edges.saturating_sub(frontier_edges);
        depth += 1;

        let mut next = Vec::new();
        match dir {
            Direction::TopDown => {
                for &u in &frontier {
                    for &v in g.out_neighbors(u) {
                        if level[v as usize] == UNREACHED {
                            level[v as usize] = depth;
                            next.push(v);
                        }
                    }
                }
            }
            Direction::BottomUp => {
                // Every unvisited vertex checks whether any in-neighbor
                // is on the current frontier level.
                for v in 0..n as VertexId {
                    if level[v as usize] == UNREACHED
                        && g.in_neighbors(v).iter().any(|&u| level[u as usize] == depth - 1)
                    {
                        level[v as usize] = depth;
                        next.push(v);
                    }
                }
            }
        }
        frontier = next;
        // Switch back to top-down for small frontiers (beta heuristic) is
        // implicit: the alpha test above re-evaluates every round.
        let _ = p.beta;
    }
    (level, directions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::graph::gap::{GapGraph, ALL};

    #[test]
    fn matches_queue_bfs_on_suite() {
        for gg in ALL {
            let g = gg.generate(9, 0);
            let (levels, _) = run(&g, 0, DoBfsParams::default());
            assert_eq!(levels, oracle::bfs_levels(&g, 0), "{}", gg.name());
        }
    }

    #[test]
    fn uses_bottom_up_on_dense_frontier() {
        // Kron's hub frontier explodes: bottom-up must engage.
        let g = GapGraph::Kron.generate(11, 0);
        let hub = (0..g.num_vertices() as u32).max_by_key(|&v| g.in_degree(v)).unwrap();
        let (_, dirs) = run(&g, hub, DoBfsParams::default());
        assert!(dirs.contains(&Direction::BottomUp), "{dirs:?}");
    }

    #[test]
    fn starts_top_down_on_road() {
        // Road frontiers grow slowly from a corner: the early search must
        // stay top-down (bottom-up may legitimately engage once the
        // unexplored remainder shrinks below α × frontier edges).
        let g = GapGraph::Road.generate(10, 0);
        let (_, dirs) = run(&g, 0, DoBfsParams::default());
        assert!(dirs.len() > 16, "road BFS should take many rounds");
        assert!(dirs[..8].iter().all(|&d| d == Direction::TopDown), "{dirs:?}");
    }

    #[test]
    fn matches_engine_iterative_bfs() {
        use crate::engine::{EngineConfig, ExecutionMode};
        let g = GapGraph::Urand.generate(9, 0);
        let engine = crate::algorithms::bfs::run_native(&g, 0, &EngineConfig::new(4, ExecutionMode::Synchronous));
        let (levels, _) = run(&g, 0, DoBfsParams::default());
        assert_eq!(levels, engine.levels);
    }
}
