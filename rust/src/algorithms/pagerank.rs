//! Pull-style PageRank.
//!
//! `PR(v) = (1-d)/N + d · Σ_{u ∈ in(v)} PR(u) / outdeg(u)`
//!
//! Scores are f32 (stored as raw bits in the 32-bit value array), damping
//! d = 0.85, and the convergence criterion matches the paper exactly:
//! stop when the summed |ΔPR| of a round falls below 1e-4.
//!
//! **Dangling vertices** (outdeg 0): the GAP reference iteration leaks
//! their rank, so raw scores sum below 1 on graphs with sinks. The
//! decoded results here redistribute that mass exactly, via the closed
//! form rather than a per-round global sum: with `P` the column-
//! stochastic-on-non-dangling pull matrix and `s` the teleport
//! distribution, the redistributed fixed point solves
//! `x = c·s + d·P·x` for the scalar `c = (1-d) + d·(dangling mass of
//! x)`, while the leaky iterate solves `y = (1-d)·s + d·P·y` — the same
//! linear system up to the scalar on `s`, so `x = y / ‖y‖₁` exactly
//! (and `‖x‖₁ = 1` by construction). [`PrResult`]/[`MultiPrResult`]
//! apply that normalization when decoding, which redistributes each
//! round's leaked mass without adding a global reduction to the
//! engine's hot loop.
//!
//! **Batched personalization** ([`MultiPageRank`]): k teleport sets run
//! as k value lanes per vertex (`crate::engine::lanes`), so one
//! neighbor read feeds all still-live queries and converged queries
//! drop out of the sweep early.

use crate::engine::kernels;
use crate::engine::lanes::{self, LaneReader};
use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, ResumeSeed, RunResult};
use crate::graph::{EdgeMutation, GraphStore, VertexId};

/// PageRank hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrConfig {
    /// Damping factor d.
    pub damping: f32,
    /// Round-sum |Δ| threshold.
    ///
    /// The paper stops when the total |Δscore| falls below 1e-4 — on
    /// graphs of 10^8+ vertices, which those runs reach within 5–40
    /// rounds. At this library's default test scale (10^4–10^5 vertices)
    /// the same *absolute* threshold runs deep into the asymptotic tail,
    /// a regime dominated by a slow Gauss-Seidel mode that the paper's
    /// machines never enter (DESIGN.md §3, EXPERIMENTS.md "regime
    /// matching"). The default 1e-3 lands small graphs in the paper's
    /// 5–40-round regime; set 1e-4 to use the paper's absolute value.
    pub epsilon: f64,
}

impl Default for PrConfig {
    fn default() -> Self {
        Self { damping: 0.85, epsilon: 1e-3 }
    }
}

/// The vertex program. Holds reciprocal out-degrees so the hot loop is a
/// multiply, not a divide.
pub struct PageRank<'g, G> {
    g: &'g G,
    inv_outdeg: Vec<f32>,
    base: f32,
    damping: f32,
    epsilon: f64,
    init: f32,
    prefetch: usize,
}

impl<'g, G: GraphStore> PageRank<'g, G> {
    /// Build for a graph.
    pub fn new(g: &'g G, cfg: &PrConfig) -> Self {
        let n = g.num_vertices().max(1) as f32;
        let inv_outdeg = g.out_degrees().iter().map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 }).collect();
        Self {
            g,
            inv_outdeg,
            base: (1.0 - cfg.damping) / n,
            damping: cfg.damping,
            epsilon: cfg.epsilon,
            init: 1.0 / n,
            prefetch: 0,
        }
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl<G: GraphStore> VertexProgram for PageRank<'_, G> {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, _v: VertexId) -> u32 {
        self.init.to_bits()
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let ns = self.g.in_neighbor_hint(v);
        let mut acc = 0.0f32;
        for (i, u) in self.g.in_neighbors(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            acc += f32::from_bits(r.read(u)) * self.inv_outdeg[u as usize];
        }
        (self.base + self.damping * acc).to_bits()
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (f32::from_bits(new) - f32::from_bits(old)).abs() as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta < self.epsilon
    }
}

/// Batched personalized PageRank: lane `l` solves
/// `PR_l(v) = (1-d)·s_l(v) + d · Σ PR_l(u)/outdeg(u)` for teleport
/// distribution `s_l` (uniform over the l-th teleport set). One engine
/// run answers every teleport set at once through the lane machinery.
pub struct MultiPageRank<'g, G> {
    g: &'g G,
    inv_outdeg: Vec<f32>,
    damping: f32,
    epsilon: f64,
    k: usize,
    /// Flattened n×k per-lane bases `(1-d)·s_l(v)`.
    base: Vec<f32>,
    /// Flattened n×k per-lane initial scores `s_l(v)`.
    init: Vec<f32>,
    prefetch: usize,
}

impl<'g, G: GraphStore> MultiPageRank<'g, G> {
    /// Build for `teleports.len()` lanes. Panics on an illegal lane
    /// count, an empty teleport set, or an out-of-range vertex.
    pub fn new(g: &'g G, cfg: &PrConfig, teleports: &[Vec<VertexId>]) -> Self {
        let k = teleports.len();
        assert!(
            lanes::valid_lane_count(k),
            "batch size {k} is not a legal lane count (1, 2, 4, 8, or 16)"
        );
        let n = g.num_vertices();
        let inv_outdeg = g.out_degrees().iter().map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 }).collect();
        let mut base = vec![0.0f32; n * k];
        let mut init = vec![0.0f32; n * k];
        for (l, set) in teleports.iter().enumerate() {
            assert!(!set.is_empty(), "teleport set {l} is empty");
            let share = 1.0 / set.len() as f32;
            for &v in set {
                assert!((v as usize) < n, "teleport vertex {v} out of range for n={n}");
                base[v as usize * k + l] += (1.0 - cfg.damping) * share;
                init[v as usize * k + l] += share;
            }
        }
        Self { g, inv_outdeg, damping: cfg.damping, epsilon: cfg.epsilon, k, base, init, prefetch: 0 }
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl<G: GraphStore> VertexProgram for MultiPageRank<'_, G> {
    fn name(&self) -> &'static str {
        "pagerank-batch"
    }

    fn lanes(&self) -> usize {
        self.k
    }

    fn init(&self, v: VertexId) -> u32 {
        self.init_lane(v, 0)
    }

    fn init_lane(&self, v: VertexId, lane: usize) -> u32 {
        self.init[v as usize * self.k + lane].to_bits()
    }

    /// Lane-0 scalar view (the engine uses [`Self::update_lanes`] for
    /// every batch size above 1).
    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let ns = self.g.in_neighbor_hint(v);
        let mut acc = 0.0f32;
        for (i, u) in self.g.in_neighbors(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            acc += f32::from_bits(r.read(u)) * self.inv_outdeg[u as usize];
        }
        (self.base[v as usize * self.k] + self.damping * acc).to_bits()
    }

    #[inline]
    fn update_lanes<R: LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
        // One group read per in-neighbor feeds every live lane. The
        // rank arithmetic runs in the lane-group kernels (SIMD under
        // the `simd` feature, the same scalar loop otherwise — both
        // unfused multiply-then-add, so the builds stay bit-identical);
        // the gather stays out here so both builds touch the same
        // cache lines.
        let k = self.k;
        let mut acc = [0.0f32; lanes::MAX_LANES];
        let mut nb = [0u32; lanes::MAX_LANES];
        let ns = self.g.in_neighbor_hint(v);
        for (i, u) in self.g.in_neighbors(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch_group(a));
            r.read_group(u, &mut nb[..k]);
            kernels::pr_accumulate(&mut acc[..k], &nb[..k], self.inv_outdeg[u as usize], live);
        }
        let vb = v as usize * k;
        kernels::pr_finish(out, &self.base[vb..vb + k], &acc[..k], self.damping, live);
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (f32::from_bits(new) - f32::from_bits(old)).abs() as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta < self.epsilon
    }
}

/// Run on the real-thread executor.
pub fn run_native<G: GraphStore>(g: &G, ecfg: &EngineConfig, cfg: &PrConfig) -> PrResult {
    let p = PageRank::new(g, cfg).with_prefetch(ecfg.prefetch);
    PrResult::from(native::run(g, &p, ecfg))
}

/// Run on the multicore simulator.
pub fn run_sim<G: GraphStore>(g: &G, ecfg: &EngineConfig, cfg: &PrConfig, machine: &Machine) -> (PrResult, SimRun) {
    let p = PageRank::new(g, cfg).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (PrResult::from(sim.result.clone()), sim)
}

/// Run a batched personalized query on the real-thread executor.
pub fn run_native_batch<G: GraphStore>(
    g: &G,
    teleports: &[Vec<VertexId>],
    ecfg: &EngineConfig,
    cfg: &PrConfig,
) -> MultiPrResult {
    let p = MultiPageRank::new(g, cfg, teleports).with_prefetch(ecfg.prefetch);
    MultiPrResult::from(native::run(g, &p, ecfg))
}

/// Run a batched personalized query on the multicore simulator.
pub fn run_sim_batch<G: GraphStore>(
    g: &G,
    teleports: &[Vec<VertexId>],
    ecfg: &EngineConfig,
    cfg: &PrConfig,
    machine: &Machine,
) -> (MultiPrResult, SimRun) {
    let p = MultiPageRank::new(g, cfg, teleports).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (MultiPrResult::from(sim.result.clone()), sim)
}

/// Deterministic batch of `k` teleport sets: singletons on the `k`
/// highest out-degree hubs (the personalized-PageRank analog of
/// [`super::sssp::default_sources`]).
pub fn default_teleports<G: GraphStore>(g: &G, k: usize) -> Vec<Vec<VertexId>> {
    super::sssp::default_sources(g, k).into_iter().map(|v| vec![v]).collect()
}

/// Build a warm-start seed for re-running PageRank after `batch` mutated
/// the graph (DESIGN.md §10).
///
/// Scores are carried over verbatim — unlike SSSP there is no
/// monotonicity trap, since the pull update recomputes a vertex's score
/// from scratch each sweep. What *does* need care is the dirty set: an
/// edge mutation at `(src, dst)` changes `dst`'s in-list **and** `src`'s
/// out-degree, and `1/outdeg(src)` feeds every one of `src`'s
/// out-neighbors. The dirty set is therefore every mutation destination
/// plus all post-mutation out-neighbors of every mutation source; the
/// re-accumulated deltas then propagate outward through frontier
/// activation exactly like Maiter-style delta iteration.
///
/// `g` is the **post-mutation** graph, `prev` a converged single-lane
/// run on the pre-mutation graph (raw leaky iterates — decode still
/// happens at [`PrResult`] construction).
pub fn resume_seed<G: GraphStore>(g: &G, prev: &RunResult, batch: &[EdgeMutation]) -> ResumeSeed {
    let n = g.num_vertices();
    let mut seed = prev.resume_from(&[]);
    assert_eq!(seed.values.len(), n, "previous run has {} values for n={n}", seed.values.len());
    let mut dirty: Vec<VertexId> = Vec::new();
    for m in batch {
        let (EdgeMutation::Insert { src, dst, .. } | EdgeMutation::Delete { src, dst }) = *m;
        dirty.push(dst);
        // src's out-degree changed, so its rank contribution to every
        // reader changed even where the edge set did not.
        for w in g.out_neighbors(src) {
            dirty.push(w);
        }
    }
    dirty.sort_unstable();
    dirty.dedup();
    seed.dirty = dirty;
    seed
}

/// Divide by the L1 mass — the exact dangling-vertex redistribution
/// (see the module docs for why the normalized leaky fixed point *is*
/// the redistributed one). Crate-visible so the dense-block PJRT
/// backend decodes identically.
pub(crate) fn redistribute_dangling(scores: &mut [f32]) {
    let mass: f64 = scores.iter().map(|&x| x as f64).sum();
    if mass > 0.0 {
        let inv = (1.0 / mass) as f32;
        for s in scores {
            *s *= inv;
        }
    }
}

/// Decoded PageRank result.
#[derive(Debug, Clone)]
pub struct PrResult {
    /// Scores per vertex; dangling mass redistributed, so they sum to
    /// 1 ± fp error on every graph (sinks included).
    pub values: Vec<f32>,
    pub run: RunResult,
}

impl From<RunResult> for PrResult {
    fn from(run: RunResult) -> Self {
        let mut values = run.values_f32();
        redistribute_dangling(&mut values);
        Self { values, run }
    }
}

/// Decoded batched personalized PageRank result.
#[derive(Debug, Clone)]
pub struct MultiPrResult {
    /// `values[l][v]` = lane l's score of v, per-lane mass-normalized
    /// like [`PrResult::values`].
    pub values: Vec<Vec<f32>>,
    pub run: RunResult,
}

impl From<RunResult> for MultiPrResult {
    fn from(run: RunResult) -> Self {
        let values = (0..run.lanes)
            .map(|l| {
                let mut lane: Vec<f32> = run.lane_values(l).into_iter().map(f32::from_bits).collect();
                redistribute_dangling(&mut lane);
                lane
            })
            .collect();
        Self { values, run }
    }
}

impl PrResult {
    /// Sum of scores (exactly 1 up to fp error: dangling mass is
    /// redistributed at decode).
    pub fn total_mass(&self) -> f64 {
        self.values.iter().map(|&x| x as f64).sum()
    }

    /// Indices of the top-k scores, descending.
    pub fn top_k(&self, k: usize) -> Vec<VertexId> {
        let mut idx: Vec<VertexId> = (0..self.values.len() as VertexId).collect();
        idx.sort_by(|&a, &b| {
            self.values[b as usize].partial_cmp(&self.values[a as usize]).unwrap().then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn cycle_graph_uniform_scores() {
        // Directed 4-cycle: perfectly symmetric, all scores = 1/4.
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3), (3, 0)]).build();
        let r = run_native(&g, &EngineConfig::new(1, ExecutionMode::Synchronous), &PrConfig::default());
        assert!(r.run.converged);
        for &s in &r.values {
            assert!((s - 0.25).abs() < 1e-4, "score {s}");
        }
    }

    #[test]
    fn mass_conserved_on_every_topology() {
        // The dangling-mass redistribution must hold scores at 1 ± ε on
        // symmetric graphs (isolated vertices are sinks), directed
        // graphs with organic sinks (web), and a generated digraph where
        // every path funnels into an absorbing sink.
        let mut sink_heavy = crate::graph::GraphBuilder::new(64);
        for v in 0..63u32 {
            sink_heavy.push(v, v + 1, 1); // chain ending in sink 63
            sink_heavy.push(v, 63, 1); // every vertex also feeds the sink
        }
        let graphs = [GapGraph::Kron.generate(9, 8), GapGraph::Web.generate(9, 4), sink_heavy.build()];
        for (i, g) in graphs.iter().enumerate() {
            let r = run_native(g, &EngineConfig::new(4, ExecutionMode::Asynchronous), &PrConfig::default());
            assert!(r.run.converged, "graph {i}");
            assert!((r.total_mass() - 1.0).abs() < 1e-3, "graph {i}: mass {}", r.total_mass());
        }
    }

    #[test]
    fn hub_ranks_highest() {
        // Star: everything points at 0.
        let es: Vec<(u32, u32)> = (1..20).map(|s| (s, 0u32)).collect();
        let g = GraphBuilder::new(20).edges(&es).symmetrize().build();
        let r = run_native(&g, &EngineConfig::new(2, ExecutionMode::Delayed(16)), &PrConfig::default());
        assert_eq!(r.top_k(1), vec![0]);
    }

    #[test]
    fn modes_agree_on_scores() {
        let g = GapGraph::Web.generate(9, 4);
        let cfg = PrConfig { damping: 0.85, epsilon: 1e-6 };
        let sync = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        let asyn = run_native(&g, &EngineConfig::new(4, ExecutionMode::Asynchronous), &cfg);
        let del = run_native(&g, &EngineConfig::new(4, ExecutionMode::Delayed(64)), &cfg);
        // 2e-4: the dangling redistribution divides by the leaked mass,
        // which amplifies per-vertex async noise by up to ~1/mass.
        for v in 0..g.num_vertices() {
            assert!((sync.values[v] - asyn.values[v]).abs() < 2e-4, "v{v}");
            assert!((sync.values[v] - del.values[v]).abs() < 2e-4, "v{v}");
        }
    }

    #[test]
    fn async_converges_in_fewer_or_equal_rounds() {
        let g = GapGraph::Road.generate(10, 0);
        let cfg = PrConfig::default();
        let sync = run_native(&g, &EngineConfig::new(2, ExecutionMode::Synchronous), &cfg);
        let asyn = run_native(&g, &EngineConfig::new(2, ExecutionMode::Asynchronous), &cfg);
        assert!(
            asyn.run.num_rounds() <= sync.run.num_rounds(),
            "async {} sync {}",
            asyn.run.num_rounds(),
            sync.run.num_rounds()
        );
    }

    #[test]
    fn frontier_schedule_bitexact_in_sync_mode() {
        use crate::engine::SchedulePolicy;
        // PageRank is a pure pull function of neighbor scores, so the
        // frontier schedule reproduces dense Jacobi bit-for-bit.
        let g = GapGraph::Road.generate(9, 0);
        let cfg = PrConfig::default();
        let dense = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
            let r = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(sched), &cfg);
            assert_eq!(r.run.values, dense.run.values, "{sched:?}");
            assert_eq!(r.run.num_rounds(), dense.run.num_rounds(), "{sched:?}");
        }
    }

    #[test]
    fn sim_matches_native_sync_bitexact() {
        let g = GapGraph::Kron.generate(8, 8);
        let cfg = PrConfig::default();
        let nat = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        let (sim, _) = run_sim(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg, &Machine::haswell());
        assert_eq!(nat.run.values, sim.run.values);
        assert_eq!(nat.run.num_rounds(), sim.run.num_rounds());
    }

    #[test]
    fn uniform_batch_lane_matches_classic_pagerank() {
        // A k=1 "batch" whose teleport set is every vertex is exactly
        // classic PageRank: same base, same init, same float ops.
        let g = GapGraph::Kron.generate(8, 8);
        let cfg = PrConfig::default();
        let all: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
        let classic = run_native(&g, &EngineConfig::new(1, ExecutionMode::Synchronous), &cfg);
        let batched = run_native_batch(&g, &[all], &EngineConfig::new(1, ExecutionMode::Synchronous), &cfg);
        assert_eq!(batched.run.values, classic.run.values, "bit-identical raw iterates");
        assert_eq!(batched.values[0], classic.values);
    }

    #[test]
    fn batched_teleports_match_independent_runs() {
        let g = GapGraph::Web.generate(9, 4);
        // Tight epsilon: personalized scores concentrate at the teleport
        // hub, so the async-vs-sync residual must be driven well below
        // the comparison tolerance.
        let cfg = PrConfig { damping: 0.85, epsilon: 1e-6 };
        let teleports = default_teleports(&g, 4);
        let ecfg = EngineConfig::new(4, ExecutionMode::Delayed(64));
        let batched = run_native_batch(&g, &teleports, &ecfg, &cfg);
        assert!(batched.run.converged);
        for (l, t) in teleports.iter().enumerate() {
            let single = run_native_batch(&g, std::slice::from_ref(t), &ecfg, &cfg);
            assert!((mass(&batched.values[l]) - 1.0).abs() < 1e-3, "lane {l} mass");
            for v in 0..g.num_vertices() {
                assert!(
                    (batched.values[l][v] - single.values[0][v]).abs() < 2e-4,
                    "lane {l} v{v}: {} vs {}",
                    batched.values[l][v],
                    single.values[0][v]
                );
            }
        }
    }

    fn mass(scores: &[f32]) -> f64 {
        scores.iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn batched_sync_is_bitexact_with_independent_runs() {
        // In sync mode each lane's Jacobi iterates are bit-identical to
        // its independent run's, and a converged lane freezes at exactly
        // the value its single run stops at.
        let g = GapGraph::Web.generate(9, 4);
        let cfg = PrConfig::default();
        let teleports = default_teleports(&g, 4);
        let ecfg = EngineConfig::new(4, ExecutionMode::Synchronous);
        let batched = run_native_batch(&g, &teleports, &ecfg, &cfg);
        for (l, t) in teleports.iter().enumerate() {
            let single = run_native_batch(&g, std::slice::from_ref(t), &ecfg, &cfg);
            assert_eq!(batched.run.lane_values(l), single.run.values, "lane {l} raw bits");
        }
    }

    #[test]
    fn prefetch_distance_does_not_change_scores() {
        // A prefetch is a pure hint: any look-ahead distance must give
        // bit-identical raw iterates in sync mode.
        let g = GapGraph::Web.generate(9, 4);
        let cfg = PrConfig::default();
        let teleports = default_teleports(&g, 8);
        let base = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        let bb = run_native_batch(&g, &teleports, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        for dist in [1usize, 4, 16, 1024] {
            let ecfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_prefetch(dist);
            assert_eq!(run_native(&g, &ecfg, &cfg).run.values, base.run.values, "prefetch={dist}");
            let b = run_native_batch(&g, &teleports, &ecfg, &cfg);
            assert_eq!(b.run.values, bb.run.values, "batched prefetch={dist}");
        }
    }

    #[test]
    fn batched_every_lane_count_converges_and_conserves_mass() {
        // Covers k=2 (newly exposed in LANE_COUNTS) and the kernel
        // vector widths 4/8/16.
        let g = GapGraph::Web.generate(8, 4);
        for k in crate::engine::lanes::LANE_COUNTS {
            let teleports = default_teleports(&g, k);
            let r = run_native_batch(&g, &teleports, &EngineConfig::new(2, ExecutionMode::Asynchronous), &PrConfig::default());
            assert!(r.run.converged, "k={k}");
            for (l, lane) in r.values.iter().enumerate() {
                assert!((mass(lane) - 1.0).abs() < 1e-3, "k={k} lane {l} mass {}", mass(lane));
            }
        }
    }

    #[test]
    fn resumed_run_tracks_scratch_after_mutations() {
        use crate::engine::SchedulePolicy;
        use crate::graph::VersionedGraph;
        let g = GapGraph::Web.generate(9, 4);
        let cfg = PrConfig { damping: 0.85, epsilon: 1e-6 };
        let ecfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier);
        let before = run_native(&g, &ecfg, &cfg);
        assert!(before.run.converged);

        let mut vg = VersionedGraph::new(g);
        let batch = vg.random_batch(0.01, 0x9E37);
        vg.apply_batch(&batch).unwrap();

        let scratch = run_native(&vg, &ecfg, &cfg);
        let seed = resume_seed(&vg, &before.run, &batch);
        let resumed = run_native(&vg, &ecfg.clone().with_resume(seed), &cfg);
        assert!(resumed.run.converged);
        assert!(
            resumed.run.num_rounds() < scratch.run.num_rounds(),
            "warm start must save rounds: resumed {} vs scratch {}",
            resumed.run.num_rounds(),
            scratch.run.num_rounds()
        );
        for v in 0..scratch.values.len() {
            assert!(
                (resumed.values[v] - scratch.values[v]).abs() < 2e-4,
                "v{v}: {} vs {}",
                resumed.values[v],
                scratch.values[v]
            );
        }
    }

    #[test]
    fn personalized_scores_concentrate_near_teleport() {
        // Star pointing at the hub: a teleport set pinned on a leaf must
        // rank that leaf above every other leaf.
        let es: Vec<(u32, u32)> = (1..16).map(|s| (s, 0u32)).collect();
        let g = GraphBuilder::new(16).edges(&es).symmetrize().build();
        let ecfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        let r = run_native_batch(&g, &[vec![5u32]], &ecfg, &PrConfig::default());
        let scores = &r.values[0];
        for leaf in (1..16).filter(|&v| v != 5) {
            assert!(scores[5] > scores[leaf], "teleport leaf must outrank leaf {leaf}");
        }
    }
}
