//! Pull-style PageRank.
//!
//! `PR(v) = (1-d)/N + d · Σ_{u ∈ in(v)} PR(u) / outdeg(u)`
//!
//! Scores are f32 (stored as raw bits in the 32-bit value array), damping
//! d = 0.85, and the convergence criterion matches the paper exactly:
//! stop when the summed |ΔPR| of a round falls below 1e-4.
//! Dangling vertices (outdeg 0) leak rank as in the GAP reference
//! implementation — acceptable because scores are compared across
//! execution modes, not against an external ranking.

use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, RunResult};
use crate::graph::{Csr, VertexId};

/// PageRank hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrConfig {
    /// Damping factor d.
    pub damping: f32,
    /// Round-sum |Δ| threshold.
    ///
    /// The paper stops when the total |Δscore| falls below 1e-4 — on
    /// graphs of 10^8+ vertices, which those runs reach within 5–40
    /// rounds. At this library's default test scale (10^4–10^5 vertices)
    /// the same *absolute* threshold runs deep into the asymptotic tail,
    /// a regime dominated by a slow Gauss-Seidel mode that the paper's
    /// machines never enter (DESIGN.md §3, EXPERIMENTS.md "regime
    /// matching"). The default 1e-3 lands small graphs in the paper's
    /// 5–40-round regime; set 1e-4 to use the paper's absolute value.
    pub epsilon: f64,
}

impl Default for PrConfig {
    fn default() -> Self {
        Self { damping: 0.85, epsilon: 1e-3 }
    }
}

/// The vertex program. Holds reciprocal out-degrees so the hot loop is a
/// multiply, not a divide.
pub struct PageRank<'g> {
    g: &'g Csr,
    inv_outdeg: Vec<f32>,
    base: f32,
    damping: f32,
    epsilon: f64,
    init: f32,
}

impl<'g> PageRank<'g> {
    /// Build for a graph.
    pub fn new(g: &'g Csr, cfg: &PrConfig) -> Self {
        let n = g.num_vertices().max(1) as f32;
        let inv_outdeg = g.out_degrees().iter().map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 }).collect();
        Self {
            g,
            inv_outdeg,
            base: (1.0 - cfg.damping) / n,
            damping: cfg.damping,
            epsilon: cfg.epsilon,
            init: 1.0 / n,
        }
    }
}

impl VertexProgram for PageRank<'_> {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, _v: VertexId) -> u32 {
        self.init.to_bits()
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let mut acc = 0.0f32;
        for &u in self.g.in_neighbors(v) {
            acc += f32::from_bits(r.read(u)) * self.inv_outdeg[u as usize];
        }
        (self.base + self.damping * acc).to_bits()
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (f32::from_bits(new) - f32::from_bits(old)).abs() as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta < self.epsilon
    }
}

/// Run on the real-thread executor.
pub fn run_native(g: &Csr, ecfg: &EngineConfig, cfg: &PrConfig) -> PrResult {
    let p = PageRank::new(g, cfg);
    PrResult::from(native::run(g, &p, ecfg))
}

/// Run on the multicore simulator.
pub fn run_sim(g: &Csr, ecfg: &EngineConfig, cfg: &PrConfig, machine: &Machine) -> (PrResult, SimRun) {
    let p = PageRank::new(g, cfg);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (PrResult::from(sim.result.clone()), sim)
}

/// Decoded PageRank result.
#[derive(Debug, Clone)]
pub struct PrResult {
    /// Scores per vertex.
    pub values: Vec<f32>,
    pub run: RunResult,
}

impl From<RunResult> for PrResult {
    fn from(run: RunResult) -> Self {
        Self { values: run.values_f32(), run }
    }
}

impl PrResult {
    /// Sum of scores (≈1 up to dangling-vertex leakage and fp error).
    pub fn total_mass(&self) -> f64 {
        self.values.iter().map(|&x| x as f64).sum()
    }

    /// Indices of the top-k scores, descending.
    pub fn top_k(&self, k: usize) -> Vec<VertexId> {
        let mut idx: Vec<VertexId> = (0..self.values.len() as VertexId).collect();
        idx.sort_by(|&a, &b| {
            self.values[b as usize].partial_cmp(&self.values[a as usize]).unwrap().then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn cycle_graph_uniform_scores() {
        // Directed 4-cycle: perfectly symmetric, all scores = 1/4.
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3), (3, 0)]).build();
        let r = run_native(&g, &EngineConfig::new(1, ExecutionMode::Synchronous), &PrConfig::default());
        assert!(r.run.converged);
        for &s in &r.values {
            assert!((s - 0.25).abs() < 1e-4, "score {s}");
        }
    }

    #[test]
    fn mass_conserved_without_dangling() {
        // Symmetric graphs have no dangling vertices unless isolated.
        let g = GapGraph::Kron.generate(9, 8);
        let r = run_native(&g, &EngineConfig::new(4, ExecutionMode::Asynchronous), &PrConfig::default());
        assert!(r.run.converged);
        // Isolated vertices (RMAT leaves many) keep only base rank, so
        // total mass dips below 1; it must stay in a sane band.
        assert!(r.total_mass() > 0.6 && r.total_mass() <= 1.001, "mass {}", r.total_mass());
    }

    #[test]
    fn hub_ranks_highest() {
        // Star: everything points at 0.
        let es: Vec<(u32, u32)> = (1..20).map(|s| (s, 0u32)).collect();
        let g = GraphBuilder::new(20).edges(&es).symmetrize().build();
        let r = run_native(&g, &EngineConfig::new(2, ExecutionMode::Delayed(16)), &PrConfig::default());
        assert_eq!(r.top_k(1), vec![0]);
    }

    #[test]
    fn modes_agree_on_scores() {
        let g = GapGraph::Web.generate(9, 4);
        let cfg = PrConfig { damping: 0.85, epsilon: 1e-6 };
        let sync = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        let asyn = run_native(&g, &EngineConfig::new(4, ExecutionMode::Asynchronous), &cfg);
        let del = run_native(&g, &EngineConfig::new(4, ExecutionMode::Delayed(64)), &cfg);
        for v in 0..g.num_vertices() {
            assert!((sync.values[v] - asyn.values[v]).abs() < 1e-4, "v{v}");
            assert!((sync.values[v] - del.values[v]).abs() < 1e-4, "v{v}");
        }
    }

    #[test]
    fn async_converges_in_fewer_or_equal_rounds() {
        let g = GapGraph::Road.generate(10, 0);
        let cfg = PrConfig::default();
        let sync = run_native(&g, &EngineConfig::new(2, ExecutionMode::Synchronous), &cfg);
        let asyn = run_native(&g, &EngineConfig::new(2, ExecutionMode::Asynchronous), &cfg);
        assert!(
            asyn.run.num_rounds() <= sync.run.num_rounds(),
            "async {} sync {}",
            asyn.run.num_rounds(),
            sync.run.num_rounds()
        );
    }

    #[test]
    fn frontier_schedule_bitexact_in_sync_mode() {
        use crate::engine::SchedulePolicy;
        // PageRank is a pure pull function of neighbor scores, so the
        // frontier schedule reproduces dense Jacobi bit-for-bit.
        let g = GapGraph::Road.generate(9, 0);
        let cfg = PrConfig::default();
        let dense = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
            let r = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(sched), &cfg);
            assert_eq!(r.run.values, dense.run.values, "{sched:?}");
            assert_eq!(r.run.num_rounds(), dense.run.num_rounds(), "{sched:?}");
        }
    }

    #[test]
    fn sim_matches_native_sync_bitexact() {
        let g = GapGraph::Kron.generate(8, 8);
        let cfg = PrConfig::default();
        let nat = run_native(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg);
        let (sim, _) = run_sim(&g, &EngineConfig::new(4, ExecutionMode::Synchronous), &cfg, &Machine::haswell());
        assert_eq!(nat.run.values, sim.run.values);
        assert_eq!(nat.run.num_rounds(), sim.run.num_rounds());
    }
}
