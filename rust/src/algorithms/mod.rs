//! Pull-style iterative graph algorithms expressed as
//! [`crate::engine::VertexProgram`]s.
//!
//! [`pagerank`] and [`sssp`] are the paper's two evaluation workloads;
//! [`cc`] (label-propagation components) and [`bfs`] (level propagation)
//! implement the §V future-work extension to "other pull-style
//! algorithms, including where updates may only be conditionally
//! written". [`oracle`] holds serial reference implementations used by
//! the test suites. [`delta_stepping`] and [`dobfs`] are the two
//! classical hybrid baselines the paper cites as design precedent
//! (§II-B): Δ-stepping blends Dijkstra↔Bellman-Ford continuously like
//! the paper's δ; DO-BFS switches push↔pull discretely.

pub mod bfs;
pub mod cc;
pub mod delta_stepping;
pub mod dobfs;
pub mod oracle;
pub mod pagerank;
pub mod sssp;
