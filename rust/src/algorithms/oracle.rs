//! Serial reference implementations — the test suites' ground truth.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::algorithms::sssp::INF;
use crate::graph::{Csr, VertexId};

/// Dijkstra over the pull representation.
///
/// The engine computes `dist(v) = min over in-edges (u→v)`; Dijkstra
/// needs out-edges, so this builds the transpose adjacency on the fly
/// (`O(m)` extra memory — fine for test-sized graphs).
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    // Transpose: out[u] = list of (v, w) with edge u→v.
    let mut out: Vec<Vec<(VertexId, u32)>> = vec![Vec::new(); n];
    for v in 0..n as VertexId {
        for (u, w) in g.in_neighbors_weighted(v) {
            out[u as usize].push((v, w));
        }
    }
    let mut dist = vec![INF; n];
    let mut heap: BinaryHeap<Reverse<(u32, VertexId)>> = BinaryHeap::new();
    dist[source as usize] = 0;
    heap.push(Reverse((0, source)));
    while let Some(Reverse((d, u))) = heap.pop() {
        if d > dist[u as usize] {
            continue;
        }
        for &(v, w) in &out[u as usize] {
            let nd = d.saturating_add(w);
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd, v)));
            }
        }
    }
    dist
}

/// Serial double-buffered (Jacobi) PageRank. The iterates match the
/// engine's synchronous mode bit-for-bit when summation order is
/// identical; like the engine's decoder, the returned scores are
/// L1-normalized — the exact dangling-vertex mass redistribution (see
/// `algorithms::pagerank` module docs), so they sum to 1 ± fp error on
/// every graph.
pub fn pagerank(g: &Csr, damping: f32, epsilon: f64, max_rounds: usize) -> (Vec<f32>, usize) {
    let n = g.num_vertices();
    let nf = n.max(1) as f32;
    let base = (1.0 - damping) / nf;
    let inv: Vec<f32> = g.out_degrees().iter().map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 }).collect();
    let mut front = vec![1.0f32 / nf; n];
    let mut back = vec![0.0f32; n];
    for round in 1..=max_rounds {
        let mut delta = 0.0f64;
        for v in 0..n {
            let mut acc = 0.0f32;
            for &u in g.in_neighbors(v as VertexId) {
                acc += front[u as usize] * inv[u as usize];
            }
            back[v] = base + damping * acc;
            delta += (back[v] - front[v]).abs() as f64;
        }
        std::mem::swap(&mut front, &mut back);
        if delta < epsilon {
            normalize_mass(&mut front);
            return (front, round);
        }
    }
    normalize_mass(&mut front);
    (front, max_rounds)
}

/// Serial Jacobi personalized PageRank: teleport distribution uniform
/// over `teleport` instead of over all vertices. Scores L1-normalized
/// like [`pagerank`]. The ground truth for the batched
/// `MultiPageRank` lanes.
pub fn personalized_pagerank(
    g: &Csr,
    damping: f32,
    epsilon: f64,
    teleport: &[VertexId],
    max_rounds: usize,
) -> (Vec<f32>, usize) {
    let n = g.num_vertices();
    assert!(!teleport.is_empty(), "teleport set must be non-empty");
    let share = 1.0f32 / teleport.len() as f32;
    let mut base = vec![0.0f32; n];
    let mut front = vec![0.0f32; n];
    for &v in teleport {
        base[v as usize] += (1.0 - damping) * share;
        front[v as usize] += share;
    }
    let inv: Vec<f32> = g.out_degrees().iter().map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f32 }).collect();
    let mut back = vec![0.0f32; n];
    for round in 1..=max_rounds {
        let mut delta = 0.0f64;
        for v in 0..n {
            let mut acc = 0.0f32;
            for &u in g.in_neighbors(v as VertexId) {
                acc += front[u as usize] * inv[u as usize];
            }
            back[v] = base[v] + damping * acc;
            delta += (back[v] - front[v]).abs() as f64;
        }
        std::mem::swap(&mut front, &mut back);
        if delta < epsilon {
            normalize_mass(&mut front);
            return (front, round);
        }
    }
    normalize_mass(&mut front);
    (front, max_rounds)
}

/// The engine decoder's exact dangling-mass redistribution — one shared
/// implementation so the oracle can never drift from what
/// `PrResult`/`MultiPrResult`/the PJRT backend apply.
fn normalize_mass(scores: &mut [f32]) {
    crate::algorithms::pagerank::redistribute_dangling(scores);
}

/// Connected components via repeated min-label flooding (undirected
/// graphs). Serial, O(diameter · m).
pub fn components(g: &Csr) -> Vec<u32> {
    let n = g.num_vertices();
    let mut label: Vec<u32> = (0..n as u32).collect();
    loop {
        let mut changed = false;
        for v in 0..n as VertexId {
            let mut best = label[v as usize];
            for &u in g.in_neighbors(v) {
                best = best.min(label[u as usize]);
            }
            if best < label[v as usize] {
                label[v as usize] = best;
                changed = true;
            }
        }
        if !changed {
            return label;
        }
    }
}

/// BFS levels from `source` following in-edges as undirected hops is NOT
/// what the engine computes; this follows edges u→v (using the transpose
/// like [`dijkstra`]), i.e. forward BFS. `u32::MAX` = unreachable.
pub fn bfs_levels(g: &Csr, source: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    let mut out: Vec<Vec<VertexId>> = vec![Vec::new(); n];
    for v in 0..n as VertexId {
        for &u in g.in_neighbors(v) {
            out[u as usize].push(v);
        }
    }
    let mut level = vec![u32::MAX; n];
    let mut q = std::collections::VecDeque::new();
    level[source as usize] = 0;
    q.push_back(source);
    while let Some(u) = q.pop_front() {
        for &v in &out[u as usize] {
            if level[v as usize] == u32::MAX {
                level[v as usize] = level[u as usize] + 1;
                q.push_back(v);
            }
        }
    }
    level
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn dijkstra_small() {
        // 0 -5-> 1 -1-> 2 ; 0 -10-> 2
        let g = GraphBuilder::new(3).weighted_edges(&[(0, 1, 5), (1, 2, 1), (0, 2, 10)]).build();
        assert_eq!(dijkstra(&g, 0), vec![0, 5, 6]);
    }

    #[test]
    fn pagerank_cycle_uniform() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2), (2, 0)]).build();
        let (scores, rounds) = pagerank(&g, 0.85, 1e-6, 1000);
        assert!(rounds < 1000);
        for &s in &scores {
            assert!((s - 1.0 / 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn pagerank_mass_is_one_with_sinks() {
        // Chain into an absorbing sink: without redistribution the sink
        // leaks every round; the oracle must still sum to 1.
        let g = GraphBuilder::new(5).edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]).build();
        let (scores, _) = pagerank(&g, 0.85, 1e-8, 10_000);
        let mass: f64 = scores.iter().map(|&s| s as f64).sum();
        assert!((mass - 1.0).abs() < 1e-5, "mass {mass}");
    }

    #[test]
    fn personalized_pagerank_concentrates_and_conserves() {
        // Symmetric path: teleporting onto vertex 0 must rank it highest
        // and keep unit mass.
        let g = GraphBuilder::new(6).edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]).symmetrize().build();
        let (scores, rounds) = personalized_pagerank(&g, 0.85, 1e-8, &[0], 10_000);
        assert!(rounds < 10_000);
        let mass: f64 = scores.iter().map(|&s| s as f64).sum();
        assert!((mass - 1.0).abs() < 1e-5, "mass {mass}");
        for v in 1..6 {
            assert!(scores[0] > scores[v], "teleport vertex must rank highest (v{v})");
        }
        // Uniform teleport over every vertex reproduces classic PageRank.
        let all: Vec<u32> = (0..6).collect();
        let (uni, _) = personalized_pagerank(&g, 0.85, 1e-8, &all, 10_000);
        let (classic, _) = pagerank(&g, 0.85, 1e-8, 10_000);
        for v in 0..6 {
            assert!((uni[v] - classic[v]).abs() < 1e-6, "v{v}");
        }
    }

    #[test]
    fn components_two_islands() {
        let g = GraphBuilder::new(5).edges(&[(0, 1), (3, 4)]).symmetrize().build();
        let c = components(&g);
        assert_eq!(c[0], c[1]);
        assert_eq!(c[3], c[4]);
        assert_ne!(c[0], c[3]);
        assert_eq!(c[2], 2); // isolated keeps own label
    }

    #[test]
    fn bfs_line() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, 2, 3]);
        assert_eq!(bfs_levels(&g, 3), vec![u32::MAX, u32::MAX, u32::MAX, 0]);
    }
}
