//! Delta-stepping SSSP (Meyer & Sanders 2003) — the classical hybrid the
//! paper holds up as precedent (§II-B): Δ = 0 degenerates to Dijkstra,
//! Δ = ∞ to Bellman-Ford, exactly as the paper's δ spans synchronous to
//! asynchronous execution. Implemented as the comparison baseline for
//! the engine's Bellman-Ford (bench `bench_micro`, example
//! `delta_tuning` discussion).
//!
//! Bucket-based sequential formulation over the pull graph's transpose:
//! light edges (w ≤ Δ) are relaxed within a bucket until it empties,
//! heavy edges once per bucket settlement.

use crate::algorithms::sssp::INF;
use crate::graph::{Csr, VertexId};

/// Run delta-stepping from `source` with bucket width `delta` (panics if
/// `delta == 0`; use [`crate::algorithms::oracle::dijkstra`] for that
/// limit). Returns distances with [`INF`] for unreachable vertices.
pub fn run(g: &Csr, source: VertexId, delta: u32) -> Vec<u32> {
    assert!(g.is_weighted(), "delta-stepping requires weights");
    assert!(delta > 0, "Δ=0 is Dijkstra; use oracle::dijkstra");
    let n = g.num_vertices();

    // Out-edges (transpose of the pull lists), split light/heavy.
    let mut light: Vec<Vec<(VertexId, u32)>> = vec![Vec::new(); n];
    let mut heavy: Vec<Vec<(VertexId, u32)>> = vec![Vec::new(); n];
    for v in 0..n as VertexId {
        for (u, w) in g.in_neighbors_weighted(v) {
            if w <= delta {
                light[u as usize].push((v, w));
            } else {
                heavy[u as usize].push((v, w));
            }
        }
    }

    let mut dist = vec![INF; n];
    // Buckets as a growable vec of vecs; bucket of d = d / delta.
    let mut buckets: Vec<Vec<VertexId>> = Vec::new();
    let in_bucket = |buckets: &mut Vec<Vec<VertexId>>, v: VertexId, d: u32| {
        let b = (d / delta) as usize;
        if buckets.len() <= b {
            buckets.resize(b + 1, Vec::new());
        }
        buckets[b].push(v);
    };

    dist[source as usize] = 0;
    in_bucket(&mut buckets, source, 0);

    let mut i = 0usize;
    while i < buckets.len() {
        let mut settled: Vec<VertexId> = Vec::new();
        // Phase 1: drain bucket i, relaxing light edges (may re-insert).
        while !buckets[i].is_empty() {
            let frontier = std::mem::take(&mut buckets[i]);
            for &u in &frontier {
                let du = dist[u as usize];
                // Stale entry (vertex moved to an earlier bucket) — skip.
                if (du / delta) as usize != i {
                    continue;
                }
                settled.push(u);
                for &(v, w) in &light[u as usize] {
                    let nd = du.saturating_add(w);
                    if nd < dist[v as usize] {
                        dist[v as usize] = nd;
                        in_bucket(&mut buckets, v, nd);
                    }
                }
            }
        }
        // Phase 2: heavy edges once from everything settled in bucket i.
        for &u in &settled {
            let du = dist[u as usize];
            for &(v, w) in &heavy[u as usize] {
                let nd = du.saturating_add(w);
                if nd < dist[v as usize] {
                    dist[v as usize] = nd;
                    in_bucket(&mut buckets, v, nd);
                }
            }
        }
        i += 1;
    }
    dist
}

/// The customary Δ heuristic: Δ ≈ max weight / average degree (Meyer &
/// Sanders suggest Θ(1/max-degree · max-weight); this variant works well
/// on the GAP weight range).
pub fn default_delta(g: &Csr) -> u32 {
    let avg_deg = g.avg_degree().max(1.0);
    ((crate::graph::weights::MAX_WEIGHT as f64 / avg_deg).ceil() as u32).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::graph::gap::{GapGraph, ALL};
    use crate::graph::GraphBuilder;

    #[test]
    fn line_graph() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 5), (1, 2, 3), (2, 3, 200)]).build();
        assert_eq!(run(&g, 0, 64), vec![0, 5, 8, 208]);
    }

    #[test]
    fn matches_dijkstra_on_suite() {
        for gg in ALL {
            let g = gg.generate_weighted(9, 0);
            let src = crate::algorithms::sssp::default_source(&g);
            let want = oracle::dijkstra(&g, src);
            for delta in [1u32, 17, 64, 255, 10_000] {
                assert_eq!(run(&g, src, delta), want, "{} Δ={delta}", gg.name());
            }
        }
    }

    #[test]
    fn default_delta_reasonable() {
        let g = GapGraph::Kron.generate_weighted(10, 0);
        let d = default_delta(&g);
        assert!(d >= 1 && d <= 255, "Δ={d}");
    }

    #[test]
    fn matches_engine_bellman_ford() {
        use crate::engine::{EngineConfig, ExecutionMode};
        let g = GapGraph::Twitter.generate_weighted(9, 0);
        let src = crate::algorithms::sssp::default_source(&g);
        let bf = crate::algorithms::sssp::run_native(&g, src, &EngineConfig::new(4, ExecutionMode::Delayed(32)));
        assert_eq!(run(&g, src, default_delta(&g)), bf.dist);
    }

    #[test]
    #[should_panic(expected = "Dijkstra")]
    fn zero_delta_panics() {
        let g = GraphBuilder::new(2).weighted_edges(&[(0, 1, 1)]).build();
        run(&g, 0, 0);
    }
}
