//! Connected components by min-label propagation — the first §V
//! future-work extension ("extend the idea of buffering to other
//! pull-style algorithms, including where updates may only be
//! conditionally written").
//!
//! Each vertex repeatedly takes the minimum label among itself and its
//! in-neighbors; on symmetric graphs labels converge to the component
//! minimum. Like SSSP, most rounds update few vertices, so this is a
//! second data point for the paper's sparse-update regime.

use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, RunResult};
use crate::graph::{GraphStore, VertexId};

/// Min-label propagation program over any [`GraphStore`] backend.
pub struct Components<'g, G> {
    g: &'g G,
    conditional: bool,
}

impl<'g, G: GraphStore> Components<'g, G> {
    /// Program for a (preferably symmetric) graph.
    pub fn new(g: &'g G) -> Self {
        Self { g, conditional: false }
    }

    /// Enable conditional writes.
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }
}

impl<G: GraphStore> VertexProgram for Components<'_, G> {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn init(&self, v: VertexId) -> u32 {
        v
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let mut best = r.read(v);
        for u in self.g.in_neighbors(v) {
            best = best.min(r.read(u));
        }
        best
    }

    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Run on the real-thread executor.
pub fn run_native<G: GraphStore>(g: &G, ecfg: &EngineConfig) -> CcResult {
    CcResult::from(native::run(g, &Components::new(g), ecfg))
}

/// Run on the simulator.
pub fn run_sim<G: GraphStore>(g: &G, ecfg: &EngineConfig, machine: &Machine) -> (CcResult, SimRun) {
    let sim = crate::engine::sim::run(g, &Components::new(g), ecfg, machine);
    (CcResult::from(sim.result.clone()), sim)
}

/// Decoded result.
#[derive(Debug, Clone)]
pub struct CcResult {
    /// Component label per vertex (= min vertex id in the component).
    pub labels: Vec<u32>,
    pub run: RunResult,
}

impl From<RunResult> for CcResult {
    fn from(run: RunResult) -> Self {
        Self { labels: run.values.clone(), run }
    }
}

impl CcResult {
    /// Number of distinct components.
    pub fn num_components(&self) -> usize {
        let mut ls = self.labels.clone();
        ls.sort_unstable();
        ls.dedup();
        ls.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn islands() {
        let g = GraphBuilder::new(6).edges(&[(0, 1), (1, 2), (4, 5)]).symmetrize().build();
        let r = run_native(&g, &EngineConfig::new(2, ExecutionMode::Asynchronous));
        assert_eq!(r.labels[..3], [0, 0, 0]);
        assert_eq!(r.labels[3], 3);
        assert_eq!(r.labels[4], 4);
        assert_eq!(r.labels[5], 4);
        assert_eq!(r.num_components(), 3);
    }

    #[test]
    fn matches_oracle_all_modes() {
        let g = GapGraph::Road.generate(10, 0);
        let want = oracle::components(&g);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Delayed(16)] {
            let r = run_native(&g, &EngineConfig::new(4, mode));
            assert_eq!(r.labels, want, "{mode:?}");
        }
    }

    #[test]
    fn conditional_matches_unconditional() {
        let g = GapGraph::Urand.generate(9, 8);
        let base = run_native(&g, &EngineConfig::new(4, ExecutionMode::Delayed(32)));
        let p = Components::new(&g).conditional();
        let cond = native::run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(32)));
        assert_eq!(base.labels, cond.values);
    }

    #[test]
    fn frontier_schedule_matches_and_shrinks() {
        use crate::engine::SchedulePolicy;
        // Label propagation on a high-diameter graph: the frontier
        // collapses fast — the showcase workload for sparse scheduling.
        let g = GapGraph::Road.generate(10, 0);
        let n = g.num_vertices() as u64;
        let want = oracle::components(&g);
        let dense = run_native(&g, &EngineConfig::new(4, ExecutionMode::Delayed(32)));
        let fcfg = EngineConfig::new(4, ExecutionMode::Delayed(32)).with_schedule(SchedulePolicy::Frontier);
        let fr = run_native(&g, &fcfg);
        assert_eq!(fr.labels, want);
        assert_eq!(fr.run.active_counts()[0], n, "round 0 dense");
        assert!(fr.run.total_active() < dense.run.total_active());
    }

    #[test]
    fn sim_agrees() {
        let g = GapGraph::Kron.generate(8, 8);
        let want = oracle::components(&g);
        let (r, _) = run_sim(&g, &EngineConfig::new(8, ExecutionMode::Delayed(16)), &Machine::haswell());
        assert_eq!(r.labels, want);
    }
}
