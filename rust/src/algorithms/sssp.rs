//! Pull-style Bellman-Ford single-source shortest paths.
//!
//! `dist(v) = min(dist(v), min_{u ∈ in(v)} dist(u) + w(u,v))`
//!
//! Distances are u32 (∞ = `u32::MAX`), weights are the GAP-style uniform
//! integers from [`crate::graph::weights`]. Convergence is the paper's:
//! "no update was generated in the last iteration".
//!
//! The paper stores updates **unconditionally** ("same runtime
//! conditions … unconditionally storing updates"); [`Sssp::conditional`]
//! flips on the §V future-work variant where unchanged distances are not
//! written.

use crate::engine::kernels;
use crate::engine::lanes::{self, LaneReader};
use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, RunResult};
use crate::graph::{Csr, VertexId};

/// Unreachable marker.
pub const INF: u32 = u32::MAX;

/// Bellman-Ford vertex program.
pub struct Sssp<'g> {
    g: &'g Csr,
    source: VertexId,
    conditional: bool,
    prefetch: usize,
}

impl<'g> Sssp<'g> {
    /// Program computing distances from `source`. Panics if `g` is
    /// unweighted.
    pub fn new(g: &'g Csr, source: VertexId) -> Self {
        assert!(g.is_weighted(), "SSSP requires a weighted graph");
        Self { g, source, conditional: false, prefetch: 0 }
    }

    /// Enable conditional writes (§V extension).
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl VertexProgram for Sssp<'_> {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, v: VertexId) -> u32 {
        if v == self.source {
            0
        } else {
            INF
        }
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let mut best = r.read(v);
        // `in_neighbors` and `in_neighbors_weighted` walk the same
        // lo..hi slice, so index-based look-ahead lines up exactly.
        let ns = self.g.in_neighbors(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            let du = r.read(u);
            if du != INF {
                best = best.min(du.saturating_add(w));
            }
        }
        best
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Batched multi-source Bellman-Ford: one engine run answers `k`
/// independent SSSP queries through the lane machinery
/// ([`crate::engine::lanes`]). Lane `l` computes distances from
/// `sources[l]`; each neighbor lane-group read and each delay-buffer
/// flush is shared by all still-live queries, and a query whose lane
/// produced no update in a round drops out of subsequent sweeps.
pub struct MultiSssp<'g> {
    g: &'g Csr,
    sources: Vec<VertexId>,
    conditional: bool,
    prefetch: usize,
}

impl<'g> MultiSssp<'g> {
    /// Program computing distances from each of `sources` (one lane per
    /// source). Panics if `g` is unweighted, a source is out of range,
    /// or the source count is not a legal lane count.
    pub fn new(g: &'g Csr, sources: &[VertexId]) -> Self {
        assert!(g.is_weighted(), "SSSP requires a weighted graph");
        assert!(
            lanes::valid_lane_count(sources.len()),
            "batch size {} is not a legal lane count (1, 2, 4, 8, or 16)",
            sources.len()
        );
        let n = g.num_vertices() as VertexId;
        for &s in sources {
            assert!(s < n, "source {s} out of range for n={n}");
        }
        Self { g, sources: sources.to_vec(), conditional: false, prefetch: 0 }
    }

    /// Enable conditional writes (§V extension): a vertex none of whose
    /// live lanes changed stages nothing.
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl VertexProgram for MultiSssp<'_> {
    fn name(&self) -> &'static str {
        "sssp-batch"
    }

    fn lanes(&self) -> usize {
        self.sources.len()
    }

    fn init(&self, v: VertexId) -> u32 {
        self.init_lane(v, 0)
    }

    fn init_lane(&self, v: VertexId, lane: usize) -> u32 {
        if v == self.sources[lane] {
            0
        } else {
            INF
        }
    }

    /// Lane-0 scalar view (the engine uses [`Self::update_lanes`] for
    /// every batch size above 1).
    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let ns = self.g.in_neighbors(v);
        let mut best = r.read(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            let du = r.read(u);
            if du != INF {
                best = best.min(du.saturating_add(w));
            }
        }
        best
    }

    #[inline]
    fn update_lanes<R: LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
        // One group read per in-neighbor feeds every live lane — the
        // lane amortization this batching exists for. The relax itself
        // runs in the lane-group kernel (SIMD when built with the
        // `simd` feature, bit-identical scalar loop otherwise); the
        // gather stays out here so both builds touch the same lines.
        let k = self.sources.len();
        let mut nb = [0u32; lanes::MAX_LANES];
        let ns = self.g.in_neighbors(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch_group(a));
            r.read_group(u, &mut nb[..k]);
            kernels::sssp_relax(out, &nb[..k], w, live);
        }
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Decoded multi-source SSSP result: one distance vector per query.
#[derive(Debug, Clone)]
pub struct MultiSsspResult {
    /// `dist[l][v]` = distance of `v` from the l-th source.
    pub dist: Vec<Vec<u32>>,
    pub run: RunResult,
}

impl From<RunResult> for MultiSsspResult {
    fn from(run: RunResult) -> Self {
        let dist = (0..run.lanes).map(|l| run.lane_values(l)).collect();
        Self { dist, run }
    }
}

/// Run a batched multi-source query on the real-thread executor.
pub fn run_native_batch(g: &Csr, sources: &[VertexId], ecfg: &EngineConfig) -> MultiSsspResult {
    let p = MultiSssp::new(g, sources).with_prefetch(ecfg.prefetch);
    MultiSsspResult::from(native::run(g, &p, ecfg))
}

/// Run a batched multi-source query on the multicore simulator.
pub fn run_sim_batch(
    g: &Csr,
    sources: &[VertexId],
    ecfg: &EngineConfig,
    machine: &Machine,
) -> (MultiSsspResult, SimRun) {
    let p = MultiSssp::new(g, sources).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (MultiSsspResult::from(sim.result.clone()), sim)
}

/// Deterministic batch of `k` "interesting" sources: the `k` highest
/// out-degree vertices (distinct; ties to the higher id so that lane 0
/// is exactly [`default_source`]) — hubs keep small graphs mostly
/// reachable.
pub fn default_sources(g: &Csr, k: usize) -> Vec<VertexId> {
    let mut by_degree: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    by_degree.sort_by_key(|&v| (std::cmp::Reverse(g.out_degree(v)), std::cmp::Reverse(v)));
    by_degree.truncate(k);
    assert_eq!(by_degree.len(), k, "graph has fewer than {k} vertices");
    by_degree
}

/// Decoded SSSP result.
#[derive(Debug, Clone)]
pub struct SsspResult {
    /// Distance per vertex ([`INF`] = unreachable).
    pub dist: Vec<u32>,
    pub run: RunResult,
}

impl From<RunResult> for SsspResult {
    fn from(run: RunResult) -> Self {
        Self { dist: run.values.clone(), run }
    }
}

impl SsspResult {
    /// Number of reachable vertices.
    pub fn reached(&self) -> usize {
        self.dist.iter().filter(|&&d| d != INF).count()
    }
}

/// Run on the real-thread executor.
pub fn run_native(g: &Csr, source: VertexId, ecfg: &EngineConfig) -> SsspResult {
    let p = Sssp::new(g, source).with_prefetch(ecfg.prefetch);
    SsspResult::from(native::run(g, &p, ecfg))
}

/// Run on the multicore simulator.
pub fn run_sim(g: &Csr, source: VertexId, ecfg: &EngineConfig, machine: &Machine) -> (SsspResult, SimRun) {
    let p = Sssp::new(g, source).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (SsspResult::from(sim.result.clone()), sim)
}

/// Deterministic "interesting" source: highest out-degree vertex (GAP
/// uses random sources; a hub makes small graphs mostly reachable).
pub fn default_source(g: &Csr) -> VertexId {
    (0..g.num_vertices() as VertexId).max_by_key(|&v| g.out_degree(v)).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn line_graph_distances() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 5), (1, 2, 3), (2, 3, 2)]).build();
        let r = run_native(&g, 0, &EngineConfig::new(2, ExecutionMode::Asynchronous));
        assert_eq!(r.dist, vec![0, 5, 8, 10]);
        assert!(r.run.converged);
    }

    #[test]
    fn unreachable_stays_inf() {
        let g = GraphBuilder::new(3).weighted_edges(&[(0, 1, 1)]).build();
        let r = run_native(&g, 0, &EngineConfig::new(1, ExecutionMode::Synchronous));
        assert_eq!(r.dist[2], INF);
        assert_eq!(r.reached(), 2);
    }

    #[test]
    fn matches_dijkstra_all_modes() {
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            let r = run_native(&g, src, &EngineConfig::new(4, mode));
            assert_eq!(r.dist, want, "{mode:?}");
        }
    }

    #[test]
    fn conditional_variant_matches() {
        let g = GapGraph::Twitter.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        let p = Sssp::new(&g, src).conditional();
        let r = native::run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert_eq!(r.values, want);
    }

    #[test]
    fn frontier_schedule_matches_dijkstra() {
        use crate::engine::SchedulePolicy;
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                let r = run_native(&g, src, &EngineConfig::new(4, mode).with_schedule(sched));
                assert_eq!(r.dist, want, "{mode:?}/{sched:?}");
            }
        }
        // Conditional-write variant composes with sparse sweeps.
        let p = Sssp::new(&g, src).conditional();
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64)).with_schedule(SchedulePolicy::Frontier);
        assert_eq!(native::run(&g, &p, &cfg).values, want);
    }

    #[test]
    fn sim_matches_dijkstra() {
        let g = GapGraph::Road.generate_weighted(9, 0);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        let (r, _) = run_sim(&g, src, &EngineConfig::new(8, ExecutionMode::Delayed(16)), &Machine::haswell());
        assert_eq!(r.dist, want);
    }

    #[test]
    #[should_panic(expected = "weighted")]
    fn unweighted_rejected() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let _ = Sssp::new(&g, 0);
    }

    #[test]
    fn batched_matches_dijkstra_per_lane() {
        let g = GapGraph::Kron.generate_weighted(9, 8);
        for k in [1usize, 4, 8] {
            let sources = default_sources(&g, k);
            let r = run_native_batch(&g, &sources, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
            assert!(r.run.converged, "k={k}");
            assert_eq!(r.run.lanes, k);
            for (l, &src) in sources.iter().enumerate() {
                assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "k={k} lane {l}");
            }
        }
    }

    #[test]
    fn batched_sim_bit_matches_independent_runs() {
        let g = GapGraph::Road.generate_weighted(9, 0);
        let sources = default_sources(&g, 4);
        let m = Machine::haswell();
        let ecfg = EngineConfig::new(8, ExecutionMode::Delayed(32));
        let (batched, _) = run_sim_batch(&g, &sources, &ecfg, &m);
        for (l, &src) in sources.iter().enumerate() {
            let (single, _) = run_sim(&g, src, &ecfg, &m);
            assert_eq!(batched.dist[l], single.dist, "lane {l} vs independent sim run");
        }
    }

    #[test]
    fn batched_conditional_variant_matches() {
        let g = GapGraph::Twitter.generate_weighted(9, 8);
        let sources = default_sources(&g, 4);
        let p = MultiSssp::new(&g, &sources).conditional();
        let r = MultiSsspResult::from(native::run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64))));
        for (l, &src) in sources.iter().enumerate() {
            assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "lane {l}");
        }
    }

    #[test]
    fn default_sources_are_distinct_hubs() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let s = default_sources(&g, 8);
        assert_eq!(s.len(), 8);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 8, "sources must be distinct: {s:?}");
        assert_eq!(s[0], default_source(&g), "lane 0 is the single-query default source");
    }

    #[test]
    #[should_panic(expected = "not a legal lane count")]
    fn bad_batch_size_rejected() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 1)]).build();
        let _ = MultiSssp::new(&g, &[0, 1, 2]);
    }

    #[test]
    fn prefetch_distance_does_not_change_distances() {
        // A prefetch is a pure hint: any look-ahead distance must give
        // bit-identical distances (single-lane and batched).
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let sources = default_sources(&g, 4);
        let base = run_native(&g, src, &EngineConfig::new(4, ExecutionMode::Synchronous));
        let base_batch = run_native_batch(&g, &sources, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        for dist in [1usize, 4, 16, 1024] {
            let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_prefetch(dist);
            assert_eq!(run_native(&g, src, &cfg).dist, base.dist, "prefetch={dist}");
            let bcfg = EngineConfig::new(4, ExecutionMode::Delayed(64)).with_prefetch(dist);
            let b = run_native_batch(&g, &sources, &bcfg);
            assert_eq!(b.dist, base_batch.dist, "batched prefetch={dist}");
        }
    }

    #[test]
    fn batched_every_lane_count_matches_dijkstra() {
        // Covers the k=2 lane count (satellite: LANE_COUNTS now lists
        // it) and the kernel-dispatched widths 4/8/16 in one sweep.
        let g = GapGraph::Kron.generate_weighted(8, 8);
        for k in crate::engine::lanes::LANE_COUNTS {
            let sources = default_sources(&g, k);
            let r = run_native_batch(&g, &sources, &EngineConfig::new(2, ExecutionMode::Asynchronous));
            for (l, &src) in sources.iter().enumerate() {
                assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "k={k} lane {l}");
            }
        }
    }
}
