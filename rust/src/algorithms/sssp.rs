//! Pull-style Bellman-Ford single-source shortest paths.
//!
//! `dist(v) = min(dist(v), min_{u ∈ in(v)} dist(u) + w(u,v))`
//!
//! Distances are u32 (∞ = `u32::MAX`), weights are the GAP-style uniform
//! integers from [`crate::graph::weights`]. Convergence is the paper's:
//! "no update was generated in the last iteration".
//!
//! The paper stores updates **unconditionally** ("same runtime
//! conditions … unconditionally storing updates"); [`Sssp::conditional`]
//! flips on the §V future-work variant where unchanged distances are not
//! written.

use crate::engine::kernels;
use crate::engine::lanes::{self, LaneReader};
use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, ResumeSeed, RunResult};
use crate::graph::{EdgeMutation, GraphStore, VertexId};

/// Unreachable marker.
pub const INF: u32 = u32::MAX;

/// Bellman-Ford vertex program over any [`GraphStore`] backend.
pub struct Sssp<'g, G> {
    g: &'g G,
    source: VertexId,
    conditional: bool,
    prefetch: usize,
}

impl<'g, G: GraphStore> Sssp<'g, G> {
    /// Program computing distances from `source`. Panics if `g` is
    /// unweighted.
    pub fn new(g: &'g G, source: VertexId) -> Self {
        assert!(g.is_weighted(), "SSSP requires a weighted graph");
        Self { g, source, conditional: false, prefetch: 0 }
    }

    /// Enable conditional writes (§V extension).
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl<G: GraphStore> VertexProgram for Sssp<'_, G> {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, v: VertexId) -> u32 {
        if v == self.source {
            0
        } else {
            INF
        }
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let mut best = r.read(v);
        // The hint slice walks the same lo..hi base row the weighted
        // iterator starts from, so index-based look-ahead lines up
        // exactly on CSR (on overlays it is a prefix hint).
        let ns = self.g.in_neighbor_hint(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            let du = r.read(u);
            if du != INF {
                best = best.min(du.saturating_add(w));
            }
        }
        best
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Batched multi-source Bellman-Ford: one engine run answers `k`
/// independent SSSP queries through the lane machinery
/// ([`crate::engine::lanes`]). Lane `l` computes distances from
/// `sources[l]`; each neighbor lane-group read and each delay-buffer
/// flush is shared by all still-live queries, and a query whose lane
/// produced no update in a round drops out of subsequent sweeps.
pub struct MultiSssp<'g, G> {
    g: &'g G,
    sources: Vec<VertexId>,
    conditional: bool,
    prefetch: usize,
}

impl<'g, G: GraphStore> MultiSssp<'g, G> {
    /// Program computing distances from each of `sources` (one lane per
    /// source). Panics if `g` is unweighted, a source is out of range,
    /// or the source count is not a legal lane count.
    pub fn new(g: &'g G, sources: &[VertexId]) -> Self {
        assert!(g.is_weighted(), "SSSP requires a weighted graph");
        assert!(
            lanes::valid_lane_count(sources.len()),
            "batch size {} is not a legal lane count (1, 2, 4, 8, or 16)",
            sources.len()
        );
        let n = g.num_vertices() as VertexId;
        for &s in sources {
            assert!(s < n, "source {s} out of range for n={n}");
        }
        Self { g, sources: sources.to_vec(), conditional: false, prefetch: 0 }
    }

    /// Enable conditional writes (§V extension): a vertex none of whose
    /// live lanes changed stages nothing.
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }

    /// Set the software-prefetch look-ahead distance (in neighbors; 0
    /// disables). Results are distance-invariant: a prefetch is a hint.
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }
}

impl<G: GraphStore> VertexProgram for MultiSssp<'_, G> {
    fn name(&self) -> &'static str {
        "sssp-batch"
    }

    fn lanes(&self) -> usize {
        self.sources.len()
    }

    fn init(&self, v: VertexId) -> u32 {
        self.init_lane(v, 0)
    }

    fn init_lane(&self, v: VertexId, lane: usize) -> u32 {
        if v == self.sources[lane] {
            0
        } else {
            INF
        }
    }

    /// Lane-0 scalar view (the engine uses [`Self::update_lanes`] for
    /// every batch size above 1).
    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let ns = self.g.in_neighbor_hint(v);
        let mut best = r.read(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch(a));
            let du = r.read(u);
            if du != INF {
                best = best.min(du.saturating_add(w));
            }
        }
        best
    }

    #[inline]
    fn update_lanes<R: LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
        // One group read per in-neighbor feeds every live lane — the
        // lane amortization this batching exists for. The relax itself
        // runs in the lane-group kernel (SIMD when built with the
        // `simd` feature, bit-identical scalar loop otherwise); the
        // gather stays out here so both builds touch the same lines.
        let k = self.sources.len();
        let mut nb = [0u32; lanes::MAX_LANES];
        let ns = self.g.in_neighbor_hint(v);
        for (i, (u, w)) in self.g.in_neighbors_weighted(v).enumerate() {
            kernels::prefetch_ahead(ns, i, self.prefetch, |a| r.prefetch_group(a));
            r.read_group(u, &mut nb[..k]);
            kernels::sssp_relax(out, &nb[..k], w, live);
        }
    }

    #[inline]
    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Decoded multi-source SSSP result: one distance vector per query.
#[derive(Debug, Clone)]
pub struct MultiSsspResult {
    /// `dist[l][v]` = distance of `v` from the l-th source.
    pub dist: Vec<Vec<u32>>,
    pub run: RunResult,
}

impl From<RunResult> for MultiSsspResult {
    fn from(run: RunResult) -> Self {
        let dist = (0..run.lanes).map(|l| run.lane_values(l)).collect();
        Self { dist, run }
    }
}

/// Run a batched multi-source query on the real-thread executor.
pub fn run_native_batch<G: GraphStore>(g: &G, sources: &[VertexId], ecfg: &EngineConfig) -> MultiSsspResult {
    let p = MultiSssp::new(g, sources).with_prefetch(ecfg.prefetch);
    MultiSsspResult::from(native::run(g, &p, ecfg))
}

/// Run a batched multi-source query on the multicore simulator.
pub fn run_sim_batch<G: GraphStore>(
    g: &G,
    sources: &[VertexId],
    ecfg: &EngineConfig,
    machine: &Machine,
) -> (MultiSsspResult, SimRun) {
    let p = MultiSssp::new(g, sources).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (MultiSsspResult::from(sim.result.clone()), sim)
}

/// Deterministic batch of `k` "interesting" sources: the `k` highest
/// out-degree vertices (distinct; ties to the higher id so that lane 0
/// is exactly [`default_source`]) — hubs keep small graphs mostly
/// reachable.
pub fn default_sources<G: GraphStore>(g: &G, k: usize) -> Vec<VertexId> {
    let mut by_degree: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    by_degree.sort_by_key(|&v| (std::cmp::Reverse(g.out_degree(v)), std::cmp::Reverse(v)));
    by_degree.truncate(k);
    assert_eq!(by_degree.len(), k, "graph has fewer than {k} vertices");
    by_degree
}

/// Decoded SSSP result.
#[derive(Debug, Clone)]
pub struct SsspResult {
    /// Distance per vertex ([`INF`] = unreachable).
    pub dist: Vec<u32>,
    pub run: RunResult,
}

impl From<RunResult> for SsspResult {
    fn from(run: RunResult) -> Self {
        Self { dist: run.values.clone(), run }
    }
}

impl SsspResult {
    /// Number of reachable vertices.
    pub fn reached(&self) -> usize {
        self.dist.iter().filter(|&&d| d != INF).count()
    }
}

/// Run on the real-thread executor.
pub fn run_native<G: GraphStore>(g: &G, source: VertexId, ecfg: &EngineConfig) -> SsspResult {
    let p = Sssp::new(g, source).with_prefetch(ecfg.prefetch);
    SsspResult::from(native::run(g, &p, ecfg))
}

/// Run on the multicore simulator.
pub fn run_sim<G: GraphStore>(g: &G, source: VertexId, ecfg: &EngineConfig, machine: &Machine) -> (SsspResult, SimRun) {
    let p = Sssp::new(g, source).with_prefetch(ecfg.prefetch);
    let sim = crate::engine::sim::run(g, &p, ecfg, machine);
    (SsspResult::from(sim.result.clone()), sim)
}

/// Deterministic "interesting" source: highest out-degree vertex (GAP
/// uses random sources; a hub makes small graphs mostly reachable).
pub fn default_source<G: GraphStore>(g: &G) -> VertexId {
    (0..g.num_vertices() as VertexId).max_by_key(|&v| g.out_degree(v)).unwrap_or(0)
}

/// Build a warm-start seed for re-running SSSP after `batch` mutated the
/// graph, applying the **delete-monotonicity reset rule** (DESIGN.md
/// §10).
///
/// Bellman-Ford's pull update takes a min that includes the vertex's own
/// value, so distances can only decrease across a run: any carried-over
/// value *below* the new true distance would survive as a wrong answer.
/// Deletions can raise true distances, so every vertex whose old
/// distance is no longer *supported* must be reset to [`INF`] before
/// resuming. Support is checked by worklist propagation seeded from the
/// deleted edges' destinations: `v` is supported iff some post-mutation
/// in-edge `(u, w)` from a non-suspect `u` proves
/// `dist[u] + w <= dist[v]`. Mutual support between two stale vertices
/// is impossible (it would need a zero-weight cycle, and
/// [`crate::graph::VersionedGraph::apply_batch`] rejects zero weights),
/// so every surviving value is an achievable path length — an upper
/// bound min-relaxation then tightens to the new fixed point.
///
/// `g` is the **post-mutation** graph, `prev` a converged single-lane
/// run from `source` on the pre-mutation graph. The returned dirty set
/// is the reset vertices plus every mutation destination.
pub fn resume_seed<G: GraphStore>(
    g: &G,
    source: VertexId,
    prev: &RunResult,
    batch: &[EdgeMutation],
) -> ResumeSeed {
    use std::collections::VecDeque;
    let n = g.num_vertices();
    let mut seed = prev.resume_from(&[]);
    assert_eq!(seed.values.len(), n, "previous run has {} values for n={n}", seed.values.len());
    assert!((source as usize) < n, "source {source} out of range for n={n}");

    let mut suspect = vec![false; n];
    let mut queued = vec![false; n];
    let mut work: VecDeque<VertexId> = VecDeque::new();
    for m in batch {
        if let EdgeMutation::Delete { dst, .. } = *m {
            if !queued[dst as usize] {
                queued[dst as usize] = true;
                work.push_back(dst);
            }
        }
    }
    while let Some(v) = work.pop_front() {
        queued[v as usize] = false;
        if suspect[v as usize] || v == source || seed.values[v as usize] == INF {
            continue;
        }
        let dv = seed.values[v as usize];
        let supported = g.in_neighbors_weighted(v).any(|(u, w)| {
            !suspect[u as usize] && seed.values[u as usize] != INF && seed.values[u as usize].saturating_add(w) <= dv
        });
        if !supported {
            suspect[v as usize] = true;
            // Readers of v may have leaned on it — re-examine them.
            for w2 in g.out_neighbors(v) {
                if !suspect[w2 as usize] && !queued[w2 as usize] {
                    queued[w2 as usize] = true;
                    work.push_back(w2);
                }
            }
        }
    }

    let mut dirty: Vec<VertexId> = Vec::new();
    for (v, &s) in suspect.iter().enumerate() {
        if s {
            seed.values[v] = INF;
            dirty.push(v as VertexId);
        }
    }
    for m in batch {
        let (EdgeMutation::Insert { dst, .. } | EdgeMutation::Delete { dst, .. }) = *m;
        dirty.push(dst);
    }
    dirty.sort_unstable();
    dirty.dedup();
    seed.dirty = dirty;
    seed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn line_graph_distances() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 5), (1, 2, 3), (2, 3, 2)]).build();
        let r = run_native(&g, 0, &EngineConfig::new(2, ExecutionMode::Asynchronous));
        assert_eq!(r.dist, vec![0, 5, 8, 10]);
        assert!(r.run.converged);
    }

    #[test]
    fn unreachable_stays_inf() {
        let g = GraphBuilder::new(3).weighted_edges(&[(0, 1, 1)]).build();
        let r = run_native(&g, 0, &EngineConfig::new(1, ExecutionMode::Synchronous));
        assert_eq!(r.dist[2], INF);
        assert_eq!(r.reached(), 2);
    }

    #[test]
    fn matches_dijkstra_all_modes() {
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            let r = run_native(&g, src, &EngineConfig::new(4, mode));
            assert_eq!(r.dist, want, "{mode:?}");
        }
    }

    #[test]
    fn conditional_variant_matches() {
        let g = GapGraph::Twitter.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        let p = Sssp::new(&g, src).conditional();
        let r = native::run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert_eq!(r.values, want);
    }

    #[test]
    fn frontier_schedule_matches_dijkstra() {
        use crate::engine::SchedulePolicy;
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                let r = run_native(&g, src, &EngineConfig::new(4, mode).with_schedule(sched));
                assert_eq!(r.dist, want, "{mode:?}/{sched:?}");
            }
        }
        // Conditional-write variant composes with sparse sweeps.
        let p = Sssp::new(&g, src).conditional();
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64)).with_schedule(SchedulePolicy::Frontier);
        assert_eq!(native::run(&g, &p, &cfg).values, want);
    }

    #[test]
    fn sim_matches_dijkstra() {
        let g = GapGraph::Road.generate_weighted(9, 0);
        let src = default_source(&g);
        let want = oracle::dijkstra(&g, src);
        let (r, _) = run_sim(&g, src, &EngineConfig::new(8, ExecutionMode::Delayed(16)), &Machine::haswell());
        assert_eq!(r.dist, want);
    }

    #[test]
    #[should_panic(expected = "weighted")]
    fn unweighted_rejected() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let _ = Sssp::new(&g, 0);
    }

    #[test]
    fn batched_matches_dijkstra_per_lane() {
        let g = GapGraph::Kron.generate_weighted(9, 8);
        for k in [1usize, 4, 8] {
            let sources = default_sources(&g, k);
            let r = run_native_batch(&g, &sources, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
            assert!(r.run.converged, "k={k}");
            assert_eq!(r.run.lanes, k);
            for (l, &src) in sources.iter().enumerate() {
                assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "k={k} lane {l}");
            }
        }
    }

    #[test]
    fn batched_sim_bit_matches_independent_runs() {
        let g = GapGraph::Road.generate_weighted(9, 0);
        let sources = default_sources(&g, 4);
        let m = Machine::haswell();
        let ecfg = EngineConfig::new(8, ExecutionMode::Delayed(32));
        let (batched, _) = run_sim_batch(&g, &sources, &ecfg, &m);
        for (l, &src) in sources.iter().enumerate() {
            let (single, _) = run_sim(&g, src, &ecfg, &m);
            assert_eq!(batched.dist[l], single.dist, "lane {l} vs independent sim run");
        }
    }

    #[test]
    fn batched_conditional_variant_matches() {
        let g = GapGraph::Twitter.generate_weighted(9, 8);
        let sources = default_sources(&g, 4);
        let p = MultiSssp::new(&g, &sources).conditional();
        let r = MultiSsspResult::from(native::run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64))));
        for (l, &src) in sources.iter().enumerate() {
            assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "lane {l}");
        }
    }

    #[test]
    fn default_sources_are_distinct_hubs() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let s = default_sources(&g, 8);
        assert_eq!(s.len(), 8);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 8, "sources must be distinct: {s:?}");
        assert_eq!(s[0], default_source(&g), "lane 0 is the single-query default source");
    }

    #[test]
    #[should_panic(expected = "not a legal lane count")]
    fn bad_batch_size_rejected() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 1)]).build();
        let _ = MultiSssp::new(&g, &[0, 1, 2]);
    }

    #[test]
    fn prefetch_distance_does_not_change_distances() {
        // A prefetch is a pure hint: any look-ahead distance must give
        // bit-identical distances (single-lane and batched).
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let sources = default_sources(&g, 4);
        let base = run_native(&g, src, &EngineConfig::new(4, ExecutionMode::Synchronous));
        let base_batch = run_native_batch(&g, &sources, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        for dist in [1usize, 4, 16, 1024] {
            let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_prefetch(dist);
            assert_eq!(run_native(&g, src, &cfg).dist, base.dist, "prefetch={dist}");
            let bcfg = EngineConfig::new(4, ExecutionMode::Delayed(64)).with_prefetch(dist);
            let b = run_native_batch(&g, &sources, &bcfg);
            assert_eq!(b.dist, base_batch.dist, "batched prefetch={dist}");
        }
    }

    #[test]
    fn resume_seed_resets_unsupported_vertices() {
        use crate::graph::{EdgeMutation, VersionedGraph};
        // 0 →(1) 1 →(1) 2 with a weight-10 bypass 0 →(10) 2. Deleting
        // (0,1) strands 1 and invalidates 2's distance through it.
        let g = GraphBuilder::new(3).weighted_edges(&[(0, 1, 1), (1, 2, 1), (0, 2, 10)]).build();
        let cfg = EngineConfig::new(1, ExecutionMode::Asynchronous);
        let before = run_native(&g, 0, &cfg);
        assert_eq!(before.dist, vec![0, 1, 2]);

        let mut vg = VersionedGraph::new(g);
        let batch = vec![EdgeMutation::Delete { src: 0, dst: 1 }];
        vg.apply_batch(&batch).unwrap();
        let seed = resume_seed(&vg, 0, &before.run, &batch);
        assert_eq!(seed.values, vec![0, INF, INF], "1 and its dependent 2 are reset");
        assert_eq!(seed.dirty, vec![1, 2]);

        let after = run_native(&vg, 0, &cfg.clone().with_resume(seed));
        assert_eq!(after.dist, vec![0, INF, 10]);
    }

    #[test]
    fn resumed_run_matches_oracle_after_random_mutations() {
        use crate::engine::SchedulePolicy;
        use crate::graph::VersionedGraph;
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let src = default_source(&g);
        let cfg = EngineConfig::new(4, ExecutionMode::Asynchronous).with_schedule(SchedulePolicy::Frontier);
        let before = run_native(&g, src, &cfg);
        assert!(before.run.converged);

        let mut vg = VersionedGraph::new(g);
        let batch = vg.random_batch(0.01, 0xBEEF);
        vg.apply_batch(&batch).unwrap();
        let seed = resume_seed(&vg, src, &before.run, &batch);
        let after = run_native(&vg, src, &cfg.clone().with_resume(seed));
        assert!(after.run.converged);
        assert_eq!(after.dist, oracle::dijkstra(&vg.to_csr(), src));
    }

    #[test]
    fn batched_every_lane_count_matches_dijkstra() {
        // Covers the k=2 lane count (satellite: LANE_COUNTS now lists
        // it) and the kernel-dispatched widths 4/8/16 in one sweep.
        let g = GapGraph::Kron.generate_weighted(8, 8);
        for k in crate::engine::lanes::LANE_COUNTS {
            let sources = default_sources(&g, k);
            let r = run_native_batch(&g, &sources, &EngineConfig::new(2, ExecutionMode::Asynchronous));
            for (l, &src) in sources.iter().enumerate() {
                assert_eq!(r.dist[l], oracle::dijkstra(&g, src), "k={k} lane {l}");
            }
        }
    }
}
