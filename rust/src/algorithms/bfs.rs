//! Iterative (level-relaxation) BFS — second §V extension workload.
//!
//! `level(v) = min(level(v), 1 + min_{u ∈ in(v)} level(u))`
//!
//! This is Bellman-Ford with unit weights: a pull-style iterative BFS
//! whose number of rounds equals the eccentricity of the source. It is
//! the extreme sparse-update case (each vertex changes exactly once), so
//! it bounds the regime where the paper's §IV-D analysis predicts
//! buffering is least useful.

use crate::engine::program::{ValueReader, VertexProgram};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{native, EngineConfig, RunResult};
use crate::graph::{GraphStore, VertexId};

/// Unreached marker.
pub const UNREACHED: u32 = u32::MAX;

/// Level-relaxation BFS program over any [`GraphStore`] backend.
pub struct Bfs<'g, G> {
    g: &'g G,
    source: VertexId,
    conditional: bool,
}

impl<'g, G: GraphStore> Bfs<'g, G> {
    /// BFS from `source`.
    pub fn new(g: &'g G, source: VertexId) -> Self {
        Self { g, source, conditional: false }
    }

    /// Enable conditional writes.
    pub fn conditional(mut self) -> Self {
        self.conditional = true;
        self
    }
}

impl<G: GraphStore> VertexProgram for Bfs<'_, G> {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn init(&self, v: VertexId) -> u32 {
        if v == self.source {
            0
        } else {
            UNREACHED
        }
    }

    #[inline]
    fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
        let mut best = r.read(v);
        for u in self.g.in_neighbors(v) {
            let lu = r.read(u);
            if lu != UNREACHED {
                best = best.min(lu + 1);
            }
        }
        best
    }

    fn delta(&self, old: u32, new: u32) -> f64 {
        (old != new) as u32 as f64
    }

    fn converged(&self, round_delta: f64) -> bool {
        round_delta == 0.0
    }

    fn conditional_writes(&self) -> bool {
        self.conditional
    }
}

/// Run on the real-thread executor.
pub fn run_native<G: GraphStore>(g: &G, source: VertexId, ecfg: &EngineConfig) -> BfsResult {
    BfsResult::from(native::run(g, &Bfs::new(g, source), ecfg))
}

/// Run on the simulator.
pub fn run_sim<G: GraphStore>(g: &G, source: VertexId, ecfg: &EngineConfig, machine: &Machine) -> (BfsResult, SimRun) {
    let sim = crate::engine::sim::run(g, &Bfs::new(g, source), ecfg, machine);
    (BfsResult::from(sim.result.clone()), sim)
}

/// Decoded result.
#[derive(Debug, Clone)]
pub struct BfsResult {
    /// Hop count per vertex ([`UNREACHED`] if not reachable).
    pub levels: Vec<u32>,
    pub run: RunResult,
}

impl From<RunResult> for BfsResult {
    fn from(run: RunResult) -> Self {
        Self { levels: run.values.clone(), run }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;

    #[test]
    fn matches_queue_bfs() {
        // Symmetric graph: in-neighbors = out-neighbors, so the pull
        // relaxation equals forward BFS.
        let g = GapGraph::Kron.generate(9, 8);
        let want = oracle::bfs_levels(&g, 0);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            let r = run_native(&g, 0, &EngineConfig::new(4, mode));
            assert_eq!(r.levels, want, "{mode:?}");
        }
    }

    #[test]
    fn road_needs_many_rounds_sync() {
        let g = GapGraph::Road.generate(10, 0);
        let sync = run_native(&g, 0, &EngineConfig::new(2, ExecutionMode::Synchronous));
        let asyn = run_native(&g, 0, &EngineConfig::new(2, ExecutionMode::Asynchronous));
        // Sync needs ~eccentricity rounds; async can cut through within a
        // thread's sweep direction.
        assert!(asyn.run.num_rounds() < sync.run.num_rounds());
    }

    #[test]
    fn frontier_schedule_matches_oracle() {
        use crate::engine::SchedulePolicy;
        let g = GapGraph::Web.generate(9, 4); // directed: exercises the transpose
        let want = oracle::bfs_levels(&g, 3);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(16)] {
            for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                let r = run_native(&g, 3, &EngineConfig::new(4, mode).with_schedule(sched));
                assert_eq!(r.levels, want, "{mode:?}/{sched:?}");
            }
        }
    }

    #[test]
    fn sim_matches_oracle() {
        let g = GapGraph::Web.generate(9, 4);
        // Web is directed: use the transpose-consistent oracle.
        let want = oracle::bfs_levels(&g, 3);
        let (r, _) = run_sim(&g, 3, &EngineConfig::new(8, ExecutionMode::Delayed(16)), &Machine::haswell());
        assert_eq!(r.levels, want);
    }
}
