//! Real-thread executor: `std::thread` workers, one per partition block,
//! barrier-synchronized rounds, with value visibility governed by
//! [`ExecutionMode`] and per-round vertex selection governed by
//! [`SchedulePolicy`].
//!
//! All three modes share the same round structure (the paper counts
//! rounds for the asynchronous version too — threads sweep their range
//! once per round and a barrier separates rounds so convergence can be
//! evaluated globally); only *when* newly computed values become visible
//! differs:
//!
//! * sync — written to the inactive half of a double buffer, visible
//!   next round;
//! * async — stored straight into the shared array;
//! * delayed(δ) — staged in a [`DelayBuffer`] and published every δ
//!   elements.
//!
//! Orthogonally, the schedule decides *which* vertices a round sweeps:
//! `Dense` is the paper's full sweep (and pays zero scheduling cost);
//! `Frontier`/`Adaptive` sweep only vertices activated by a neighbor's
//! change, tracked in shared [`AtomicBitmap`]s with round parity (the
//! current round consumes one map while activations build the other).
//! Sparse sweeps compose with the delay buffer through
//! [`DelayBuffer::seek`], which generalizes the conditional-write
//! `skip()` flush-and-advance so published runs stay contiguous.
//!
//! A third orthogonal dimension is *who* executes a chunk of work:
//! with [`EngineConfig::stealing`] each partition is split into
//! cache-line-aligned chunks in a [`StealGrid`]; a worker drains its own
//! chunks in order (a contiguous sweep, identical to static execution),
//! then steals trailing chunks from the most loaded victim. Stolen
//! chunks are just non-contiguous jumps to the delay buffer — the same
//! `seek` path sparse sweeps already take.

use std::cell::RefCell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::Instant;

use crate::graph::{properties, Csr, VertexId};

use super::controller::{self, DeltaController, Telemetry};
use super::delay_buffer::{round_delta, DelayBuffer};
use super::program::{ValueReader, VertexProgram};
use super::schedule::{AtomicBitmap, SchedulePolicy, ADAPTIVE_SPARSE_DIVISOR};
use super::shared::{SharedValues, SliceReader};
use super::stats::{RoundStats, RunResult};
use super::steal::{StealGrid, DEFAULT_CHUNK};
use super::{EngineConfig, ExecutionMode};

/// Reader for async/delayed modes: global array, optionally patched with
/// the thread's own unflushed values (§III-C local-read variant).
struct AsyncReader<'a> {
    global: &'a SharedValues,
    local: Option<&'a RefCell<DelayBuffer>>,
}

impl ValueReader for AsyncReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        if let Some(buf) = self.local {
            if let Some(bits) = buf.borrow().pending(v) {
                return bits;
            }
        }
        self.global.load(v)
    }
}

/// The frontier pair: `maps[round % 2]` is consumed by round `round`
/// while activations for the next round land in the other map.
struct Frontiers {
    maps: [AtomicBitmap; 2],
}

/// Shared control block for the worker gang.
struct Ctrl {
    barrier: Barrier,
    /// Per-thread round delta (f64 bits), written by owner only.
    deltas: Vec<AtomicU64>,
    /// Per-thread cumulative flush count.
    flushes: Vec<AtomicU64>,
    /// Per-thread vertices swept this round.
    processed: Vec<AtomicU64>,
    /// Per-thread vertices whose stored value changed this round — the
    /// adaptive controller's update-density signal (meaningful under
    /// dense sweeps too, where `processed` is always the full range).
    changed: Vec<AtomicU64>,
    /// Per-thread vertices *newly* activated for the next round.
    activated: Vec<AtomicU64>,
    /// Per-thread chunks stolen this round.
    steals: Vec<AtomicU64>,
    /// Per-thread δ (delay-buffer capacity) in effect this round,
    /// written by the owner only; collected into
    /// [`RoundStats::delta_trace`] under the adaptive controller.
    delta_used: Vec<AtomicU64>,
    /// Whether the next round sweeps sparsely (thread 0 decides between
    /// the barriers; round 0 is always dense).
    sparse_next: AtomicBool,
    /// Set by thread 0 once converged / max rounds hit.
    done: AtomicBool,
}

/// Run `prog` on `g` under `cfg`. Spawns `cfg.threads` OS threads (they
/// live for the whole run). Deterministic for `Synchronous` mode;
/// async/delayed results depend on interleaving but converge to the same
/// fixed point (chaotic relaxation).
pub fn run<P: VertexProgram>(g: &Csr, prog: &P, cfg: &EngineConfig) -> RunResult {
    let n = g.num_vertices();
    let pm = cfg.partition_map(g);
    let t_count = pm.num_parts();
    let init: Vec<u32> = (0..n as VertexId).map(|v| prog.init(v)).collect();

    let global = SharedValues::from_bits(init.iter().copied());
    // Double buffer for sync mode only (async/delayed read+write `global`).
    let back = SharedValues::from_bits(init.iter().copied());

    let frontier_on = cfg.schedule != SchedulePolicy::Dense;
    if frontier_on {
        // Build the transpose once, outside the worker gang (no-op on
        // symmetric graphs).
        g.ensure_out_edges();
    }
    let frontiers = frontier_on.then(|| Frontiers { maps: [AtomicBitmap::new(n), AtomicBitmap::new(n)] });
    let grid = cfg.stealing.then(|| StealGrid::new(&pm, DEFAULT_CHUNK));
    // Adaptive mode: the §IV-C topology gate that seeds every worker's
    // controller is computed once, outside the gang (O(m), like the
    // transpose build above).
    let locality = matches!(cfg.mode, ExecutionMode::Adaptive)
        .then(|| properties::diagonal_locality(g, t_count.max(2)));

    let ctrl = Ctrl {
        barrier: Barrier::new(t_count),
        deltas: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        flushes: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        processed: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        changed: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        activated: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        steals: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        delta_used: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        sparse_next: AtomicBool::new(false),
        done: AtomicBool::new(false),
    };
    // Written by thread 0 only (between barriers); Mutex for Sync-ness.
    let rounds_out: Mutex<Vec<RoundStats>> = Mutex::new(Vec::new());
    let converged_out = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for t in 0..t_count {
            let range = pm.range(t);
            let ctrl = &ctrl;
            let global = &global;
            let back = &back;
            let frontiers = frontiers.as_ref();
            let grid = grid.as_ref();
            let rounds_out = &rounds_out;
            let converged_out = &converged_out;
            let handle = move || {
                worker(
                    t, range, g, prog, cfg, locality, ctrl, global, back, frontiers, grid, rounds_out,
                    converged_out,
                );
            };
            if t == t_count - 1 {
                // Run the last worker on the caller thread: saves one
                // spawn and keeps thread 0 = a spawned worker symmetric.
                handle();
            } else {
                scope.spawn(handle);
            }
        }
    });

    let rounds = rounds_out.into_inner().unwrap();
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let values = if sync_mode {
        // Round r writes into `back` when r is even (buffers swap roles
        // each round); after `rounds.len()` rounds the freshest buffer is:
        if rounds.len() % 2 == 1 {
            back.to_vec()
        } else {
            global.to_vec()
        }
    } else {
        global.to_vec()
    };

    RunResult {
        values,
        rounds,
        mode: cfg.mode,
        schedule: cfg.schedule,
        threads: t_count,
        converged: converged_out.load(Ordering::SeqCst),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker<P: VertexProgram>(
    t: usize,
    range: Range<VertexId>,
    g: &Csr,
    prog: &P,
    cfg: &EngineConfig,
    locality: Option<f64>,
    ctrl: &Ctrl,
    global: &SharedValues,
    back: &SharedValues,
    frontiers: Option<&Frontiers>,
    grid: Option<&StealGrid>,
    rounds_out: &Mutex<Vec<RoundStats>>,
    converged_out: &AtomicBool,
) {
    let n = g.num_vertices();
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let adaptive = matches!(cfg.mode, ExecutionMode::Adaptive);
    // Stealing can hand this thread chunks anywhere in the graph, so the
    // delayed-mode buffer is capped against n rather than the own range.
    // Sync mode never stages (the double buffer *is* the delay).
    let delta_bound = if grid.is_some() { n } else { range.len() };
    // Adaptive: the controller seeds from the offline rule over this
    // thread's own range (locality was precomputed in `run`) and may
    // resize the buffer between any two rounds within [0, bound].
    let mut ctl: Option<DeltaController> = locality.map(|loc| {
        let max = round_delta(delta_bound);
        DeltaController::new(controller::seed_delta(loc, range.len(), max), max)
    });
    let delta_cap = if sync_mode {
        0
    } else if let Some(c) = &ctl {
        c.delta()
    } else {
        cfg.effective_delta(delta_bound)
    };
    let buf = RefCell::new(DelayBuffer::new(delta_cap));
    if ctl.is_some() {
        // Flush wall time is the controller's contention signal; static
        // modes skip the timing overhead entirely.
        buf.borrow_mut().set_timed(true);
    }
    let conditional = prog.conditional_writes();
    // Telemetry deltas for the controller (cumulative counters → per-round).
    let mut prev_flush_lines = 0u64;
    let mut prev_residual = f64::INFINITY;

    // Sync-mode frontier bookkeeping: the vertices we swept last round.
    // Their fresh value lives only in this round's *read* buffer, so if
    // we skip one this round it must be mirrored into the write buffer
    // to keep the double buffers interchangeable (`None` = a dense round
    // swept everything, so both buffers already agree for skipped ids).
    let mut prev_swept: Option<Vec<VertexId>> = None;

    let mut round = 0usize;
    let mut sparse = false; // round 0 is always dense
    let mut t0 = Instant::now();
    // Per-thread round timer (t0 above belongs to thread 0's RoundStats).
    let mut my_t0 = Instant::now();
    loop {
        let mut delta = 0.0f64;
        let mut processed = 0u64;
        let mut changed = 0u64;
        let mut activated = 0u64;
        let mut steals = 0u64;
        let (cur, nxt) = match frontiers {
            Some(f) => (Some(&f.maps[round % 2]), Some(&f.maps[(round + 1) % 2])),
            None => (None, None),
        };
        // Shared by every sweep variant: a changed vertex re-activates
        // its out-neighbors for the next round, counting newly set bits
        // (thread 0 sums them for the adaptive density decision).
        let activate = |old: u32, new: u32, v: VertexId, activated: &mut u64| {
            if let Some(nx) = nxt {
                if prog.activates(old, new) {
                    for &w in g.out_neighbors(v) {
                        if nx.set(w) {
                            *activated += 1;
                        }
                    }
                }
            }
        };

        // Chunk source for this round's sweep. Static: the whole own range,
        // once. Stealing: own chunks front-to-back (a contiguous sweep,
        // same order as static), then trailing chunks from the most loaded
        // victim until every deque is drained.
        let mut own_done = false;
        let mut served_whole = false;
        let mut next_chunk = |steals: &mut u64| -> Option<Range<VertexId>> {
            match grid {
                Some(gr) => {
                    if !own_done {
                        if let Some(c) = gr.part(t).pop_front() {
                            return Some(c);
                        }
                        own_done = true;
                    }
                    let c = gr.steal(t);
                    if c.is_some() {
                        *steals += 1;
                    }
                    c
                }
                None if served_whole => None,
                None => {
                    served_whole = true;
                    Some(range.clone())
                }
            }
        };

        if sync_mode {
            // Buffers swap roles each round; `front` is read-only here
            // because every writer targets `write` and ranges are disjoint.
            let (front, write) = if round % 2 == 0 { (global, back) } else { (back, global) };
            if sparse {
                let cur = cur.expect("sparse rounds require frontiers");
                // Copy-down: values we computed last round for vertices
                // skipped this round exist only in `front`.
                match &prev_swept {
                    None => {
                        for v in range.clone() {
                            if !cur.get(v) {
                                write.store(v, front.load(v));
                            }
                        }
                    }
                    Some(list) => {
                        for &v in list {
                            if !cur.get(v) {
                                write.store(v, front.load(v));
                            }
                        }
                    }
                }
                let mut swept: Vec<VertexId> = Vec::new();
                while let Some(c) = next_chunk(&mut steals) {
                    cur.for_each_in(c, |v| {
                        let old = front.load(v);
                        let mut rd = SharedReaderShim(front);
                        let new = prog.update(v, &mut rd);
                        delta += prog.delta(old, new);
                        changed += (new != old) as u64;
                        activate(old, new, v, &mut activated);
                        // Sync must carry unchanged values across the swap.
                        write.store(v, if conditional && new == old { old } else { new });
                        swept.push(v);
                    });
                }
                processed = swept.len() as u64;
                prev_swept = Some(swept);
            } else {
                while let Some(c) = next_chunk(&mut steals) {
                    processed += c.len() as u64;
                    for v in c {
                        let old = front.load(v);
                        let mut rd = SharedReaderShim(front);
                        let new = prog.update(v, &mut rd);
                        delta += prog.delta(old, new);
                        changed += (new != old) as u64;
                        activate(old, new, v, &mut activated);
                        write.store(v, if conditional && new == old { old } else { new });
                    }
                }
                prev_swept = None;
            }
        } else {
            buf.borrow_mut().begin(range.start);
            let mut body = |v: VertexId| {
                // No-op on contiguous (dense) sweeps; on sparse sweeps and
                // stolen chunks publishes the pending run before jumping
                // the gap.
                buf.borrow_mut().seek(global, v);
                let old = global.load(v);
                let new = {
                    let mut rd = AsyncReader { global, local: cfg.local_reads.then_some(&buf) };
                    prog.update(v, &mut rd)
                };
                delta += prog.delta(old, new);
                changed += (new != old) as u64;
                activate(old, new, v, &mut activated);
                let mut b = buf.borrow_mut();
                if conditional && new == old {
                    b.skip(global);
                } else {
                    b.push(global, new);
                }
                processed += 1;
            };
            while let Some(c) = next_chunk(&mut steals) {
                match (sparse, cur) {
                    (true, Some(cur)) => cur.for_each_in(c, &mut body),
                    _ => {
                        for v in c {
                            body(v);
                        }
                    }
                }
            }
            buf.borrow_mut().flush(global);
        }

        let my_round_secs = my_t0.elapsed().as_secs_f64();
        ctrl.deltas[t].store(delta.to_bits(), Ordering::Relaxed);
        ctrl.flushes[t].store(buf.borrow().flushes(), Ordering::Relaxed);
        ctrl.processed[t].store(processed, Ordering::Relaxed);
        ctrl.changed[t].store(changed, Ordering::Relaxed);
        ctrl.activated[t].store(activated, Ordering::Relaxed);
        ctrl.steals[t].store(steals, Ordering::Relaxed);
        ctrl.delta_used[t].store(buf.borrow().capacity() as u64, Ordering::Relaxed);

        // ---- barrier 1: all writes of the round done ----
        ctrl.barrier.wait();

        // Between the barriers: cleanup that must not race the sweep.
        // Under stealing another thread may have been reading our slice of
        // the frontier bitmap (or claiming our chunks) right up to the
        // barrier, so consuming-side clears wait until every sweep is done.
        if let Some(cur) = cur {
            // This round's bits are consumed; clear our slice so the map
            // can serve as the round-after-next's activation target.
            // Masked: boundary words are shared with neighboring
            // partitions.
            cur.clear_range(range.clone());
        }
        if let Some(gr) = grid {
            gr.part(t).reset();
        }
        if let Some(c) = ctl.as_mut() {
            // Adaptive δ: digest this round's telemetry and resize the
            // (flushed-empty) buffer before the next round begins. The
            // resize is purely thread-local — no other thread ever touches
            // this buffer, stolen chunks ride the *executing* thread's
            // buffer via `seek` — so racing the steal deque is safe.
            let total_changed: u64 = ctrl.changed.iter().map(|x| x.load(Ordering::Relaxed)).sum();
            let residual: f64 = ctrl.deltas.iter().map(|d| f64::from_bits(d.load(Ordering::Relaxed))).sum();
            let residual_ratio =
                if prev_residual.is_finite() && prev_residual > 0.0 { residual / prev_residual } else { 1.0 };
            prev_residual = residual;
            let mut b = buf.borrow_mut();
            let tel = Telemetry {
                processed,
                flush_lines: b.lines_flushed() - prev_flush_lines,
                flush_cost: b.take_flush_secs(),
                round_cost: my_round_secs,
                density: total_changed as f64 / n.max(1) as f64,
                residual_ratio,
            };
            prev_flush_lines = b.lines_flushed();
            let next = c.observe(&tel);
            if next != b.capacity() {
                b.resize(next);
            }
        }

        if t == 0 {
            let round_delta: f64 = ctrl.deltas.iter().map(|d| f64::from_bits(d.load(Ordering::Relaxed))).sum();
            let total_flushes: u64 = ctrl.flushes.iter().map(|f| f.load(Ordering::Relaxed)).sum();
            let total_active: u64 = ctrl.processed.iter().map(|p| p.load(Ordering::Relaxed)).sum();
            let total_steals: u64 = ctrl.steals.iter().map(|s| s.load(Ordering::Relaxed)).sum();
            let mut rounds = rounds_out.lock().unwrap();
            let prev_flushes: u64 = rounds.iter().map(|r: &RoundStats| r.flushes).sum();
            rounds.push(RoundStats {
                time_s: t0.elapsed().as_secs_f64(),
                delta: round_delta,
                flushes: total_flushes - prev_flushes,
                active: total_active,
                steals: total_steals,
                delta_trace: if adaptive {
                    ctrl.delta_used.iter().map(|d| d.load(Ordering::Relaxed) as usize).collect()
                } else {
                    Vec::new()
                },
            });
            let conv = prog.converged(round_delta);
            if conv || rounds.len() >= cfg.max_rounds {
                ctrl.done.store(true, Ordering::SeqCst);
                converged_out.store(conv, Ordering::SeqCst);
            } else if frontiers.is_some() {
                let next_size: u64 = ctrl.activated.iter().map(|a| a.load(Ordering::Relaxed)).sum();
                let sparse_next = match cfg.schedule {
                    SchedulePolicy::Dense => false,
                    SchedulePolicy::Frontier => true,
                    // DO-BFS-style density switch, re-evaluated per round.
                    SchedulePolicy::Adaptive => (next_size as usize) * ADAPTIVE_SPARSE_DIVISOR < n,
                };
                ctrl.sparse_next.store(sparse_next, Ordering::SeqCst);
            }
        }

        // ---- barrier 2: decision published ----
        ctrl.barrier.wait();
        if ctrl.done.load(Ordering::SeqCst) {
            return;
        }
        sparse = ctrl.sparse_next.load(Ordering::SeqCst);
        if t == 0 {
            t0 = Instant::now();
        }
        my_t0 = Instant::now();
        round += 1;
    }
}

/// Local shim: a reader over `SharedValues` (can't use `SharedReader`
/// because sync mode's front buffer alternates between the two arrays).
struct SharedReaderShim<'a>(&'a SharedValues);

impl ValueReader for SharedReaderShim<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }
}

/// Serial reference executor: single thread, plain Jacobi (sync) sweep.
/// Used as the oracle in tests: `run` with `Synchronous` must match this
/// bit-exactly for any thread count (and, for frontier schedules, any
/// schedule — skipped vertices recompute identically by construction).
pub fn run_serial_sync<P: VertexProgram>(g: &Csr, prog: &P, max_rounds: usize) -> RunResult {
    let n = g.num_vertices();
    let mut front: Vec<u32> = (0..n as VertexId).map(|v| prog.init(v)).collect();
    let mut back = front.clone();
    let mut rounds = Vec::new();
    let mut converged = false;
    while rounds.len() < max_rounds {
        let t0 = Instant::now();
        let mut delta = 0.0;
        for v in 0..n as VertexId {
            let mut rd = SliceReader(&front);
            let new = prog.update(v, &mut rd);
            delta += prog.delta(front[v as usize], new);
            back[v as usize] = new;
        }
        std::mem::swap(&mut front, &mut back);
        rounds.push(RoundStats {
            time_s: t0.elapsed().as_secs_f64(),
            delta,
            flushes: 0,
            active: n as u64,
            steals: 0,
            delta_trace: Vec::new(),
        });
        if prog.converged(delta) {
            converged = true;
            break;
        }
    }
    RunResult {
        values: front,
        rounds,
        mode: ExecutionMode::Synchronous,
        schedule: SchedulePolicy::Dense,
        threads: 1,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::program::ValueReader;
    use crate::graph::gap::GapGraph;

    /// Toy program: each vertex takes max(own, in-neighbors) — converges
    /// to per-component max; easy to verify and sensitive to value
    /// propagation speed (async should need fewer rounds than sync).
    struct MaxProp<'g> {
        g: &'g Csr,
    }

    impl VertexProgram for MaxProp<'_> {
        fn name(&self) -> &'static str {
            "maxprop"
        }
        fn init(&self, v: VertexId) -> u32 {
            v * 7919 % 10007
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    fn fixed_point_serial(g: &Csr) -> Vec<u32> {
        run_serial_sync(g, &MaxProp { g }, 10_000).values
    }

    #[test]
    fn sync_matches_serial_any_thread_count() {
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for t in [1, 2, 4, 7] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(t, ExecutionMode::Synchronous));
            assert!(r.converged);
            assert_eq!(r.values, oracle, "threads={t}");
        }
    }

    #[test]
    fn all_modes_reach_same_fixed_point() {
        let g = GapGraph::Web.generate(9, 4);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Asynchronous, ExecutionMode::Delayed(16), ExecutionMode::Delayed(64)] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, mode));
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values, oracle, "{mode:?}");
        }
    }

    #[test]
    fn frontier_schedules_match_dense_every_mode() {
        // Web is directed (exercises the transpose view); Road is the
        // sparse-frontier showcase.
        for g in [GapGraph::Web.generate(9, 4), GapGraph::Road.generate(9, 0)] {
            let oracle = fixed_point_serial(&g);
            for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
                for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                    let cfg = EngineConfig::new(4, mode).with_schedule(sched);
                    let r = run(&g, &MaxProp { g: &g }, &cfg);
                    assert!(r.converged, "{mode:?}/{sched:?}");
                    assert_eq!(r.values, oracle, "{mode:?}/{sched:?}");
                    assert_eq!(r.schedule, sched);
                }
            }
        }
    }

    #[test]
    fn frontier_sync_round_trajectory_matches_serial() {
        // In sync mode the frontier schedule is bit-identical to dense
        // Jacobi round by round: same round count, same per-round delta.
        let g = GapGraph::Road.generate(9, 0);
        let serial = run_serial_sync(&g, &MaxProp { g: &g }, 10_000);
        let r = run(
            &g,
            &MaxProp { g: &g },
            &EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier),
        );
        assert_eq!(r.num_rounds(), serial.num_rounds());
        for (a, b) in r.rounds.iter().zip(&serial.rounds) {
            assert_eq!(a.delta, b.delta);
        }
        assert_eq!(r.values, serial.values);
    }

    #[test]
    fn frontier_active_counts_shrink() {
        // Synchronous: the frontier trajectory is deterministic and the
        // round count matches dense exactly, so "less total work" is a
        // hard guarantee, not a race-dependent observation.
        let g = GapGraph::Road.generate(10, 0);
        let n = g.num_vertices() as u64;
        let p = MaxProp { g: &g };
        let dense = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous));
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier);
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert_eq!(r.num_rounds(), dense.num_rounds());
        let actives = r.active_counts();
        assert_eq!(actives[0], n, "round 0 is dense");
        assert!(*actives.last().unwrap() < n, "last round must be sparse: {actives:?}");
        // The headline: strictly less total work than the dense schedule.
        assert!(
            r.total_active() < dense.total_active(),
            "frontier {} vs dense {}",
            r.total_active(),
            dense.total_active()
        );
        assert_eq!(dense.total_active(), dense.num_rounds() as u64 * n);
    }

    #[test]
    fn adaptive_starts_dense_then_goes_sparse() {
        let g = GapGraph::Road.generate(10, 0);
        let n = g.num_vertices() as u64;
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Adaptive);
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        assert!(r.converged);
        let actives = r.active_counts();
        assert_eq!(actives[0], n);
        // The convergence tail must trip the density switch.
        assert!(
            actives.iter().any(|&a| a < n / ADAPTIVE_SPARSE_DIVISOR as u64),
            "no sparse round engaged: {actives:?}"
        );
    }

    #[test]
    fn async_never_more_rounds_than_sync_single_thread() {
        // With one thread, async is pure Gauss-Seidel: strictly faster
        // information flow than Jacobi on this monotone program.
        let g = GapGraph::Road.generate(10, 0);
        let p = MaxProp { g: &g };
        let sync = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Synchronous));
        let asyn = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Asynchronous));
        assert!(
            asyn.num_rounds() <= sync.num_rounds(),
            "async {} vs sync {}",
            asyn.num_rounds(),
            sync.num_rounds()
        );
        assert!(asyn.num_rounds() < sync.num_rounds(), "road should show a strict gap");
    }

    #[test]
    fn delayed_flush_counts_reported() {
        let g = GapGraph::Urand.generate(9, 8);
        let p = MaxProp { g: &g };
        let r = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(16)));
        assert!(r.total_flushes() > 0);
        let sync = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous));
        assert_eq!(sync.total_flushes(), 0);
    }

    #[test]
    fn local_reads_variant_converges() {
        let g = GapGraph::Kron.generate(8, 8);
        let oracle = fixed_point_serial(&g);
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(32)).with_local_reads());
        assert_eq!(r.values, oracle);
        let fcfg = EngineConfig::new(4, ExecutionMode::Delayed(32))
            .with_local_reads()
            .with_schedule(SchedulePolicy::Frontier);
        let fr = run(&g, &MaxProp { g: &g }, &fcfg);
        assert_eq!(fr.values, oracle);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        for sched in SchedulePolicy::ALL {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(8, ExecutionMode::Delayed(16)).with_schedule(sched));
            assert!(r.converged, "{sched:?}");
            assert_eq!(r.values.len(), 3, "{sched:?}");
        }
    }

    #[test]
    fn stealing_matches_static_every_mode_and_schedule() {
        // Scale 10 so every partition splits into multiple chunks and the
        // steal path really engages during the parity sweep.
        let g = GapGraph::Web.generate(10, 4);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in SchedulePolicy::ALL {
                let cfg = EngineConfig::new(4, mode).with_schedule(sched).with_stealing();
                let r = run(&g, &MaxProp { g: &g }, &cfg);
                assert!(r.converged, "{mode:?}/{sched:?}");
                assert_eq!(r.values, oracle, "{mode:?}/{sched:?}");
            }
        }
    }

    #[test]
    fn stealing_sync_is_bit_exact_with_serial() {
        // Sync reads only the stable front buffer, so who executes a
        // chunk is invisible: same rounds, same per-round delta (integer
        // counts for MaxProp), same values.
        let g = GapGraph::Road.generate(9, 0);
        let serial = run_serial_sync(&g, &MaxProp { g: &g }, 10_000);
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_stealing();
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        assert_eq!(r.num_rounds(), serial.num_rounds());
        assert_eq!(r.values, serial.values);
        for (a, b) in r.rounds.iter().zip(&serial.rounds) {
            assert_eq!(a.delta, b.delta);
        }
    }

    /// Every vertex points at the first 64: the lowest equal-vertex
    /// partition holds essentially all the pull work, guaranteeing a
    /// straggler whose trailing chunks get stolen.
    fn hub_graph(n: usize) -> Csr {
        let mut b = crate::graph::GraphBuilder::new(n);
        for v in 0..n as VertexId {
            for h in 0..64u32 {
                if v != h {
                    b.push(v, h, 1);
                }
            }
        }
        b.build()
    }

    #[test]
    fn stealing_reports_steals_on_skewed_work() {
        use crate::engine::PartitionStrategy;
        let g = hub_graph(4096);
        let p = MaxProp { g: &g };
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64))
            .with_partition(PartitionStrategy::EqualVertex)
            .with_stealing();
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert!(r.total_steals() > 0, "straggler chunks must be stolen");
        // Static execution of the same config reports zero steals.
        let st = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert_eq!(st.total_steals(), 0);
        assert_eq!(r.values, st.values);
    }

    #[test]
    fn stealing_with_more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(16)] {
            let cfg = EngineConfig::new(8, mode).with_stealing();
            let r = run(&g, &MaxProp { g: &g }, &cfg);
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn adaptive_mode_reaches_fixed_point_every_schedule_and_stealing() {
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for sched in SchedulePolicy::ALL {
            for steal in [false, true] {
                let mut cfg = EngineConfig::new(4, ExecutionMode::Adaptive).with_schedule(sched);
                if steal {
                    cfg = cfg.with_stealing();
                }
                let r = run(&g, &MaxProp { g: &g }, &cfg);
                assert!(r.converged, "{sched:?} steal={steal}");
                assert_eq!(r.values, oracle, "{sched:?} steal={steal}");
                // Every round carries a full per-thread δ trace,
                // cache-line rounded.
                for rs in &r.rounds {
                    assert_eq!(rs.delta_trace.len(), r.threads, "{sched:?} steal={steal}");
                    for &d in &rs.delta_trace {
                        assert_eq!(d % crate::VALUES_PER_LINE, 0, "{sched:?} steal={steal}");
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_trace_seeds_from_offline_rule() {
        // Low-locality graph: round 0's δ equals the offline dense rule
        // over each thread's own range; non-adaptive runs carry no trace.
        let g = GapGraph::Urand.generate(9, 8);
        let cfg = EngineConfig::new(4, ExecutionMode::Adaptive);
        let pm = cfg.partition_map(&g);
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        let loc = properties::diagonal_locality(&g, 4);
        for (t, &d) in r.rounds[0].delta_trace.iter().enumerate() {
            let max = round_delta(pm.len(t));
            assert_eq!(d, controller::seed_delta(loc, pm.len(t), max), "thread {t}");
        }
        let st = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert!(st.rounds.iter().all(|rs| rs.delta_trace.is_empty()), "static runs carry no trace");
    }

    #[test]
    fn adaptive_with_more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(8, ExecutionMode::Adaptive).with_stealing());
        assert!(r.converged);
        assert_eq!(r.values.len(), 3);
    }

    #[test]
    fn max_rounds_respected() {
        struct NeverConverge;
        impl VertexProgram for NeverConverge {
            fn name(&self) -> &'static str {
                "never"
            }
            fn init(&self, _v: VertexId) -> u32 {
                0
            }
            fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
                r.read(v).wrapping_add(1)
            }
            fn delta(&self, _o: u32, _n: u32) -> f64 {
                1.0
            }
            fn converged(&self, _d: f64) -> bool {
                false
            }
        }
        let g = crate::graph::GraphBuilder::new(4).edges(&[(0, 1)]).build();
        let mut cfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        cfg.max_rounds = 5;
        let r = run(&g, &NeverConverge, &cfg);
        assert_eq!(r.num_rounds(), 5);
        assert!(!r.converged);
    }
}
