//! Real-thread executor: `std::thread` workers, one per partition block,
//! barrier-synchronized rounds, with value visibility governed by
//! [`ExecutionMode`] and per-round vertex selection governed by
//! [`SchedulePolicy`].
//!
//! All three modes share the same round structure (the paper counts
//! rounds for the asynchronous version too — threads sweep their range
//! once per round and a barrier separates rounds so convergence can be
//! evaluated globally); only *when* newly computed values become visible
//! differs:
//!
//! * sync — written to the inactive half of a double buffer, visible
//!   next round;
//! * async — stored straight into the shared array;
//! * delayed(δ) — staged in a [`DelayBuffer`] and published every δ
//!   elements.
//!
//! Orthogonally, the schedule decides *which* vertices a round sweeps:
//! `Dense` is the paper's full sweep (and pays zero scheduling cost);
//! `Frontier`/`Adaptive` sweep only vertices activated by a neighbor's
//! change, tracked in shared [`AtomicBitmap`]s with round parity (the
//! current round consumes one map while activations build the other).
//! Sparse sweeps compose with the delay buffer through
//! [`DelayBuffer::seek`], which generalizes the conditional-write
//! `skip()` flush-and-advance so published runs stay contiguous.
//!
//! A third orthogonal dimension is *who* executes a chunk of work:
//! with [`EngineConfig::stealing`] each partition is split into
//! cache-line-aligned chunks in a [`StealGrid`]; a worker drains its own
//! chunks in order (a contiguous sweep, identical to static execution),
//! then steals trailing chunks from the most loaded victim. Stolen
//! chunks are just non-contiguous jumps to the delay buffer — the same
//! `seek` path sparse sweeps already take.

use std::cell::RefCell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::Instant;

use crate::graph::{properties, GraphStore, VertexId};

use super::controller::{self, DeltaController, Telemetry};
use super::delay_buffer::{round_delta, DelayBuffer};
use super::lanes;
use super::program::{ValueReader, VertexProgram};
use super::schedule::{AtomicBitmap, SchedulePolicy, ADAPTIVE_SPARSE_DIVISOR};
use super::shared::{SharedValues, SliceReader};
use super::stats::{RoundStats, RunResult};
use super::steal::{StealGrid, DEFAULT_CHUNK};
use super::{EngineConfig, ExecutionMode};

/// Reader for async/delayed modes: global array, optionally patched with
/// the thread's own unflushed values (§III-C local-read variant).
struct AsyncReader<'a> {
    global: &'a SharedValues,
    local: Option<&'a RefCell<DelayBuffer>>,
}

impl ValueReader for AsyncReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        if let Some(buf) = self.local {
            if let Some(bits) = buf.borrow().pending(v) {
                return bits;
            }
        }
        self.global.load(v)
    }

    #[inline]
    fn prefetch(&mut self, v: VertexId) {
        // Always hint the shared line: even under local reads the
        // pending-patch lookup is a register/L1 affair, while the miss
        // being hidden lives in the global array.
        self.global.prefetch(v as usize);
    }
}

/// Lane-group reader for batched async/delayed modes: the lane twin of
/// [`AsyncReader`], patching each element of the group from the thread's
/// own unflushed run under §III-C local reads.
struct LaneAsyncReader<'a> {
    global: &'a SharedValues,
    local: Option<&'a RefCell<DelayBuffer>>,
    lanes: usize,
}

impl lanes::LaneReader for LaneAsyncReader<'_> {
    #[inline]
    fn read_group(&mut self, v: VertexId, out: &mut [u32]) {
        if let Some(buf) = self.local {
            let b = buf.borrow();
            let e = lanes::group_base(v, self.lanes);
            for (l, o) in out.iter_mut().enumerate() {
                *o = match b.pending(e + l as VertexId) {
                    Some(bits) => bits,
                    None => self.global.load(e + l as VertexId),
                };
            }
        } else {
            self.global.load_group(v, out);
        }
    }

    #[inline]
    fn prefetch_group(&mut self, v: VertexId) {
        // One hint covers the whole group: groups never straddle lines.
        self.global.prefetch(lanes::group_base(v, self.lanes) as usize);
    }
}

/// Lane-group reader over the sync-mode front buffer.
struct LaneFrontReader<'a>(&'a SharedValues);

impl lanes::LaneReader for LaneFrontReader<'_> {
    #[inline]
    fn read_group(&mut self, v: VertexId, out: &mut [u32]) {
        self.0.load_group(v, out);
    }

    #[inline]
    fn prefetch_group(&mut self, v: VertexId) {
        self.0.prefetch(lanes::group_base(v, self.0.lanes()) as usize);
    }
}

/// The frontier pair: `maps[round % 2]` is consumed by round `round`
/// while activations for the next round land in the other map.
struct Frontiers {
    maps: [AtomicBitmap; 2],
}

/// Shared control block for the worker gang.
struct Ctrl {
    barrier: Barrier,
    /// Per-thread round delta (f64 bits), written by owner only.
    deltas: Vec<AtomicU64>,
    /// Per-thread cumulative flush count.
    flushes: Vec<AtomicU64>,
    /// Per-thread vertices swept this round.
    processed: Vec<AtomicU64>,
    /// Per-thread vertices whose stored value changed this round — the
    /// adaptive controller's update-density signal (meaningful under
    /// dense sweeps too, where `processed` is always the full range).
    changed: Vec<AtomicU64>,
    /// Per-thread vertices *newly* activated for the next round.
    activated: Vec<AtomicU64>,
    /// Per-thread chunks stolen this round.
    steals: Vec<AtomicU64>,
    /// Per-thread δ (delay-buffer capacity) in effect this round,
    /// written by the owner only; collected into
    /// [`RoundStats::delta_trace`] under the adaptive controller.
    delta_used: Vec<AtomicU64>,
    /// Batched runs only: per-(thread, lane) round delta (f64 bits),
    /// flattened `t * lanes + l`, written by the owner. Thread 0 sums
    /// per lane to drive per-lane convergence. Empty when `lanes == 1`.
    lane_deltas: Vec<AtomicU64>,
    /// Bitmask of not-yet-converged lanes; thread 0 clears bits between
    /// the barriers as queries finish. Always `1` for single-lane runs.
    live: AtomicU32,
    /// Whether the next round sweeps sparsely (thread 0 decides between
    /// the barriers; round 0 is always dense).
    sparse_next: AtomicBool,
    /// Set by thread 0 once converged / max rounds hit.
    done: AtomicBool,
}

/// Run `prog` on `g` under `cfg`. Spawns `cfg.threads` OS threads (they
/// live for the whole run). Deterministic for `Synchronous` mode;
/// async/delayed results depend on interleaving but converge to the same
/// fixed point (chaotic relaxation).
///
/// Generic over [`GraphStore`] and monomorphized per backend: with
/// `G = Csr` every trait call inlines to the same inherent accessor the
/// pre-trait executor used, so static-CSR runs are unchanged; overlay
/// backends ([`crate::graph::VersionedGraph`]) run the identical round
/// machinery over their composed rows.
pub fn run<G: GraphStore, P: VertexProgram>(g: &G, prog: &P, cfg: &EngineConfig) -> RunResult {
    let n = g.num_vertices();
    if cfg.no_atomics {
        assert!(
            matches!(cfg.mode, ExecutionMode::Asynchronous),
            "no_atomics is an asynchronous-mode variant (got {:?}): sync publishes through the \
             double buffer and delayed/adaptive publish through sized buffers already",
            cfg.mode
        );
    }
    let pm = cfg.partition_map(g);
    let t_count = pm.num_parts();
    let lane_count = prog.lanes();
    assert!(
        lanes::valid_lane_count(lane_count),
        "program reports {lane_count} lanes; lane counts must divide a cache line"
    );
    // Element indices (v·lanes + l) ride in VertexId, so the widened
    // value space must still fit the u32 id range.
    assert!(n * lane_count <= u32::MAX as usize, "{n} vertices x {lane_count} lanes exceeds the u32 element space");
    let init: Vec<u32> = match &cfg.resume {
        // Warm start: carry the previous run's values instead of the
        // program's cold init (incremental recomputation, DESIGN.md §10).
        Some(seed) => {
            // Multi-lane seeds carry whole lane groups (n × lanes,
            // vertex-major) — the sharded round driver resumes batched
            // jobs this way; `dirty` stays vertex-granular either way.
            assert_eq!(
                seed.values.len(),
                n * lane_count,
                "resume seed has {} values for {n} vertices x {lane_count} lanes",
                seed.values.len()
            );
            assert!(
                seed.dirty.iter().all(|&v| (v as usize) < n),
                "resume dirty set contains out-of-range vertices"
            );
            seed.values.clone()
        }
        None => {
            let mut init = Vec::with_capacity(n * lane_count);
            for v in 0..n as VertexId {
                for l in 0..lane_count {
                    init.push(prog.init_lane(v, l));
                }
            }
            init
        }
    };

    // NUMA placement: with `--numa` both value arrays come from untouched
    // demand-paged zero pages, and each pinned worker writes its own
    // partition's initial values in its preamble (extra barrier there) —
    // so every page faults in from the owning socket and its DRAM lands
    // there. Without the flag the caller thread touches everything here,
    // exactly as before.
    // Restricted runs skip the per-partition first-touch path: the
    // worker gang covers only the restricted window, so nobody would
    // write the out-of-window initial values into demand-paged arrays.
    let (global, back) = if cfg.numa && cfg.restrict.is_none() {
        (
            SharedValues::zeroed_lanes_first_touch(init.len(), lane_count),
            SharedValues::zeroed_lanes_first_touch(init.len(), lane_count),
        )
    } else {
        (
            SharedValues::from_bits_lanes(init.iter().copied(), lane_count),
            // Double buffer for sync mode only (async/delayed read+write
            // `global`).
            SharedValues::from_bits_lanes(init.iter().copied(), lane_count),
        )
    };

    let frontier_on = cfg.schedule != SchedulePolicy::Dense;
    if frontier_on {
        // Build the transpose once, outside the worker gang (no-op on
        // symmetric graphs).
        g.ensure_out_edges();
    }
    let frontiers = frontier_on.then(|| Frontiers { maps: [AtomicBitmap::new(n), AtomicBitmap::new(n)] });
    // Resumed sparse schedules start round 0 from the dirty set instead
    // of a dense sweep (the whole point: mutation-touched regions are
    // tiny). Adaptive applies its usual density rule to the dirty size;
    // a cold run (resume = None) keeps the dense round 0 unchanged.
    let start_sparse = match (&cfg.resume, cfg.schedule) {
        (Some(_), SchedulePolicy::Frontier) => true,
        (Some(seed), SchedulePolicy::Adaptive) => seed.dirty.len() * ADAPTIVE_SPARSE_DIVISOR < n,
        _ => false,
    };
    if start_sparse {
        let f = frontiers.as_ref().expect("sparse start requires frontier maps");
        let seed = cfg.resume.as_ref().expect("sparse start requires a resume seed");
        for &v in &seed.dirty {
            f.maps[0].set(v);
        }
    }
    let grid = cfg.stealing.then(|| StealGrid::new(&pm, DEFAULT_CHUNK));
    // Adaptive mode: the §IV-C topology gate that seeds every worker's
    // controller is computed once, outside the gang (O(m), like the
    // transpose build above).
    let locality = matches!(cfg.mode, ExecutionMode::Adaptive)
        .then(|| properties::diagonal_locality(g, t_count.max(2)));

    let ctrl = Ctrl {
        barrier: Barrier::new(t_count),
        deltas: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        flushes: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        processed: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        changed: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        activated: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        steals: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        delta_used: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        lane_deltas: if lane_count > 1 {
            (0..t_count * lane_count).map(|_| AtomicU64::new(0)).collect()
        } else {
            Vec::new()
        },
        live: AtomicU32::new(lanes::full_mask(lane_count)),
        sparse_next: AtomicBool::new(false),
        done: AtomicBool::new(false),
    };
    // Written by thread 0 only (between barriers); Mutex for Sync-ness.
    let rounds_out: Mutex<Vec<RoundStats>> = Mutex::new(Vec::new());
    let converged_out = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for t in 0..t_count {
            let range = pm.range(t);
            let init = init.as_slice();
            let ctrl = &ctrl;
            let global = &global;
            let back = &back;
            let frontiers = frontiers.as_ref();
            let grid = grid.as_ref();
            let rounds_out = &rounds_out;
            let converged_out = &converged_out;
            let handle = move || {
                worker(
                    t, range, g, prog, cfg, locality, start_sparse, init, ctrl, global, back, frontiers,
                    grid, rounds_out, converged_out,
                );
            };
            if t == t_count - 1 {
                // Run the last worker on the caller thread: saves one
                // spawn and keeps thread 0 = a spawned worker symmetric.
                handle();
            } else {
                scope.spawn(handle);
            }
        }
    });

    let rounds = rounds_out.into_inner().unwrap();
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let values = if sync_mode {
        // Round r writes into `back` when r is even (buffers swap roles
        // each round); after `rounds.len()` rounds the freshest buffer is:
        if rounds.len() % 2 == 1 {
            back.to_vec()
        } else {
            global.to_vec()
        }
    } else {
        global.to_vec()
    };

    RunResult {
        values,
        rounds,
        mode: cfg.mode,
        schedule: cfg.schedule,
        threads: t_count,
        lanes: lane_count,
        converged: converged_out.load(Ordering::SeqCst),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker<G: GraphStore, P: VertexProgram>(
    t: usize,
    range: Range<VertexId>,
    g: &G,
    prog: &P,
    cfg: &EngineConfig,
    locality: Option<f64>,
    start_sparse: bool,
    init: &[u32],
    ctrl: &Ctrl,
    global: &SharedValues,
    back: &SharedValues,
    frontiers: Option<&Frontiers>,
    grid: Option<&StealGrid>,
    rounds_out: &Mutex<Vec<RoundStats>>,
    converged_out: &AtomicBool,
) {
    if cfg.numa {
        // Pin to the owning node before any page is faulted, then
        // first-touch this partition's element range in *both* value
        // arrays by writing the initial values: each page binds to this
        // socket's DRAM (`run` allocated the arrays untouched). The
        // barrier keeps round 0 from reading a neighbor's still-zero
        // pages; it is gated on `cfg.numa` alone — never on whether
        // pinning succeeded — so the gang stays barrier-symmetric even
        // when some workers' `sched_setaffinity` is denied.
        crate::partition::numa::pin_worker(t, ctrl.deltas.len());
        let k = prog.lanes();
        let (lo, hi) = (range.start as usize * k, range.end as usize * k);
        global.store_run(lo as VertexId, &init[lo..hi]);
        back.store_run(lo as VertexId, &init[lo..hi]);
        ctrl.barrier.wait();
    }
    let n = g.num_vertices();
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let adaptive = matches!(cfg.mode, ExecutionMode::Adaptive);
    // Batched multi-query lanes: every vertex owns a `lane_n`-wide lane
    // group; δ and the delay buffer keep their *element* units, so a
    // buffer of δ elements stages δ/lane_n vertex groups.
    let lane_n = prog.lanes();
    let multi = lane_n > 1;
    // Stealing can hand this thread chunks anywhere in the graph, so the
    // delayed-mode buffer is capped against n rather than the own range
    // (both in elements, i.e. scaled by the lane count). Sync mode never
    // stages (the double buffer *is* the delay).
    let vert_bound = if grid.is_some() { n } else { range.len() };
    let delta_bound = vert_bound * lane_n;
    // Adaptive: the controller seeds from the offline rule over this
    // thread's own range (locality was precomputed in `run`) and may
    // resize the buffer between any two rounds within [0, bound].
    let mut ctl: Option<DeltaController> = locality.map(|loc| {
        let max = round_delta(delta_bound);
        DeltaController::new(controller::seed_delta(loc, range.len() * lane_n, max), max)
    });
    let delta_cap = if sync_mode {
        0
    } else if let Some(c) = &ctl {
        c.delta()
    } else {
        cfg.effective_delta(delta_bound)
    };
    // Atomics-light async sweeps bypass the buffer for owned vertices;
    // the buffer only routes writes landing outside the own range
    // (stolen chunks), coalesced to whole lines.
    let no_atomics = cfg.no_atomics && !sync_mode;
    let buf = RefCell::new(DelayBuffer::new(if no_atomics { crate::VALUES_PER_LINE } else { delta_cap }));
    if ctl.is_some() {
        // Flush wall time is the controller's contention signal; static
        // modes skip the timing overhead entirely.
        buf.borrow_mut().set_timed(true);
    }
    let conditional = prog.conditional_writes();
    // Telemetry deltas for the controller (cumulative counters → per-round).
    let mut prev_flush_lines = 0u64;
    let mut prev_residual = f64::INFINITY;

    // Sync-mode frontier bookkeeping: the vertices we swept last round.
    // Their fresh value lives only in this round's *read* buffer, so if
    // we skip one this round it must be mirrored into the write buffer
    // to keep the double buffers interchangeable (`None` = a dense round
    // swept everything, so both buffers already agree for skipped ids).
    let mut prev_swept: Option<Vec<VertexId>> = None;

    let mut round = 0usize;
    // Round 0 is dense on cold runs; resumed sparse schedules start it
    // from the pre-seeded dirty frontier instead.
    let mut sparse = start_sparse;
    let mut t0 = Instant::now();
    // Per-thread round timer (t0 above belongs to thread 0's RoundStats).
    let mut my_t0 = Instant::now();
    loop {
        let mut delta = 0.0f64;
        let mut processed = 0u64;
        let mut changed = 0u64;
        let mut activated = 0u64;
        let mut steals = 0u64;
        // Batched runs: the lanes still live this round (thread 0
        // re-publishes the mask between rounds as queries converge) and
        // this thread's per-lane residual accumulators.
        let live = if multi { ctrl.live.load(Ordering::SeqCst) } else { 1u32 };
        let mut lane_delta = [0.0f64; lanes::MAX_LANES];
        let (cur, nxt) = match frontiers {
            Some(f) => (Some(&f.maps[round % 2]), Some(&f.maps[(round + 1) % 2])),
            None => (None, None),
        };
        // Shared by every sweep variant: a vertex whose update activates
        // (any live lane, for batched runs) re-activates its
        // out-neighbors for the next round, counting newly set bits
        // (thread 0 sums them for the adaptive density decision).
        let activate_out = |v: VertexId, activated: &mut u64| {
            if let Some(nx) = nxt {
                super::kernels::activate_out_neighbors(g, v, |w| {
                    if nx.set(w) {
                        *activated += 1;
                    }
                });
            }
        };

        // Chunk source for this round's sweep. Static: the whole own range,
        // once. Stealing: own chunks front-to-back (a contiguous sweep,
        // same order as static), then trailing chunks from the most loaded
        // victim until every deque is drained.
        let mut own_done = false;
        let mut served_whole = false;
        let mut next_chunk = |steals: &mut u64| -> Option<Range<VertexId>> {
            match grid {
                Some(gr) => {
                    if !own_done {
                        if let Some(c) = gr.part(t).pop_front() {
                            return Some(c);
                        }
                        own_done = true;
                    }
                    let c = gr.steal(t);
                    if c.is_some() {
                        *steals += 1;
                    }
                    c
                }
                None if served_whole => None,
                None => {
                    served_whole = true;
                    Some(range.clone())
                }
            }
        };

        if sync_mode {
            // Buffers swap roles each round; `front` is read-only here
            // because every writer targets `write` and ranges are disjoint.
            let (front, write) = if round % 2 == 0 { (global, back) } else { (back, global) };
            // Per-vertex sync update, shared by the dense and sparse
            // sweeps. Batched runs read and write whole lane groups; the
            // double buffer must carry every lane (live or dead) across
            // the swap, exactly like the unchanged-value store below.
            let mut sync_body = |v: VertexId,
                                 delta: &mut f64,
                                 lane_delta: &mut [f64],
                                 changed: &mut u64,
                                 activated: &mut u64| {
                if multi {
                    let mut group = [0u32; lanes::MAX_LANES];
                    let gv = &mut group[..lane_n];
                    front.load_group(v, gv);
                    let mut old = [0u32; lanes::MAX_LANES];
                    old[..lane_n].copy_from_slice(gv);
                    let mut rd = LaneFrontReader(front);
                    prog.update_lanes(v, &mut rd, gv, live);
                    let mut changed_any = false;
                    let mut act_any = false;
                    lanes::for_each_live(live, |l| {
                        let d = prog.lane_delta(l, old[l], gv[l]);
                        lane_delta[l] += d;
                        *delta += d;
                        changed_any |= gv[l] != old[l];
                        act_any |= prog.activates(old[l], gv[l]);
                    });
                    *changed += changed_any as u64;
                    if act_any {
                        activate_out(v, activated);
                    }
                    write.store_group(v, gv);
                } else {
                    let old = front.load(v);
                    let mut rd = SharedReaderShim(front);
                    let new = prog.update(v, &mut rd);
                    *delta += prog.delta(old, new);
                    *changed += (new != old) as u64;
                    if prog.activates(old, new) {
                        activate_out(v, activated);
                    }
                    // Sync must carry unchanged values across the swap.
                    write.store(v, if conditional && new == old { old } else { new });
                }
            };
            if sparse {
                let cur = cur.expect("sparse rounds require frontiers");
                // Copy-down: values we computed last round for vertices
                // skipped this round exist only in `front`.
                let copy_down = |v: VertexId| {
                    if !cur.get(v) {
                        if multi {
                            let mut gbuf = [0u32; lanes::MAX_LANES];
                            front.load_group(v, &mut gbuf[..lane_n]);
                            write.store_group(v, &gbuf[..lane_n]);
                        } else {
                            write.store(v, front.load(v));
                        }
                    }
                };
                match &prev_swept {
                    None => {
                        for v in range.clone() {
                            copy_down(v);
                        }
                    }
                    Some(list) => {
                        for &v in list {
                            copy_down(v);
                        }
                    }
                }
                let mut swept: Vec<VertexId> = Vec::new();
                while let Some(c) = next_chunk(&mut steals) {
                    cur.for_each_in(c, |v| {
                        sync_body(v, &mut delta, &mut lane_delta, &mut changed, &mut activated);
                        swept.push(v);
                    });
                }
                processed = swept.len() as u64;
                prev_swept = Some(swept);
            } else {
                while let Some(c) = next_chunk(&mut steals) {
                    processed += c.len() as u64;
                    for v in c {
                        sync_body(v, &mut delta, &mut lane_delta, &mut changed, &mut activated);
                    }
                }
                prev_swept = None;
            }
        } else if no_atomics {
            // Atomics-light async sweep (the non-blocking-PageRank
            // scheme; DESIGN.md §9). Updates are accumulated in
            // registers by `update`/`update_lanes` as always, but
            // publication splits on ownership:
            //
            // * vertices inside this thread's own range — one plain
            //   Relaxed store per group, straight to the shared array:
            //   no CAS, no RMW, no per-element buffer bookkeeping.
            //   Safe because a partition has exactly one writer: chunks
            //   are claimed through the steal deque exactly once per
            //   round, and this arm's direct stores target only the
            //   range no other static sweep touches.
            // * vertices outside the own range (stolen chunks) — routed
            //   through the one-line delay buffer, so a remote line is
            //   dirtied once per line instead of once per element.
            buf.borrow_mut().begin(lanes::group_base(range.start, lane_n));
            let mut body = |v: VertexId| {
                let owned = range.contains(&v);
                if multi {
                    let mut group = [0u32; lanes::MAX_LANES];
                    let gv = &mut group[..lane_n];
                    global.load_group(v, gv);
                    let mut old = [0u32; lanes::MAX_LANES];
                    old[..lane_n].copy_from_slice(gv);
                    {
                        let mut rd =
                            LaneAsyncReader { global, local: cfg.local_reads.then_some(&buf), lanes: lane_n };
                        prog.update_lanes(v, &mut rd, gv, live);
                    }
                    let mut changed_any = false;
                    let mut act_any = false;
                    lanes::for_each_live(live, |l| {
                        let d = prog.lane_delta(l, old[l], gv[l]);
                        lane_delta[l] += d;
                        delta += d;
                        changed_any |= gv[l] != old[l];
                        act_any |= prog.activates(old[l], gv[l]);
                    });
                    changed += changed_any as u64;
                    if act_any {
                        activate_out(v, &mut activated);
                    }
                    if owned {
                        if !conditional || changed_any {
                            global.store_group(v, gv);
                        }
                    } else {
                        let mut b = buf.borrow_mut();
                        b.seek(global, lanes::group_base(v, lane_n));
                        if conditional && !changed_any {
                            b.skip_n(global, lane_n);
                        } else {
                            for &x in gv.iter() {
                                b.push(global, x);
                            }
                        }
                    }
                } else {
                    let old = global.load(v);
                    let new = {
                        let mut rd = AsyncReader { global, local: cfg.local_reads.then_some(&buf) };
                        prog.update(v, &mut rd)
                    };
                    delta += prog.delta(old, new);
                    changed += (new != old) as u64;
                    if prog.activates(old, new) {
                        activate_out(v, &mut activated);
                    }
                    if owned {
                        if !conditional || new != old {
                            global.store(v, new);
                        }
                    } else {
                        let mut b = buf.borrow_mut();
                        b.seek(global, v);
                        if conditional && new == old {
                            b.skip(global);
                        } else {
                            b.push(global, new);
                        }
                    }
                }
                processed += 1;
            };
            while let Some(c) = next_chunk(&mut steals) {
                match (sparse, cur) {
                    (true, Some(cur)) => cur.for_each_in(c, &mut body),
                    _ => {
                        for v in c {
                            body(v);
                        }
                    }
                }
            }
            buf.borrow_mut().flush(global);
        } else {
            buf.borrow_mut().begin(lanes::group_base(range.start, lane_n));
            let mut body = |v: VertexId| {
                // No-op on contiguous (dense) sweeps; on sparse sweeps and
                // stolen chunks publishes the pending run before jumping
                // the gap. Element units: vertex v's lane group starts at
                // v * lane_n.
                buf.borrow_mut().seek(global, lanes::group_base(v, lane_n));
                if multi {
                    let mut group = [0u32; lanes::MAX_LANES];
                    let gv = &mut group[..lane_n];
                    global.load_group(v, gv);
                    let mut old = [0u32; lanes::MAX_LANES];
                    old[..lane_n].copy_from_slice(gv);
                    {
                        let mut rd =
                            LaneAsyncReader { global, local: cfg.local_reads.then_some(&buf), lanes: lane_n };
                        prog.update_lanes(v, &mut rd, gv, live);
                    }
                    let mut changed_any = false;
                    let mut act_any = false;
                    lanes::for_each_live(live, |l| {
                        let d = prog.lane_delta(l, old[l], gv[l]);
                        lane_delta[l] += d;
                        delta += d;
                        changed_any |= gv[l] != old[l];
                        act_any |= prog.activates(old[l], gv[l]);
                    });
                    changed += changed_any as u64;
                    if act_any {
                        activate_out(v, &mut activated);
                    }
                    let mut b = buf.borrow_mut();
                    if conditional && !changed_any {
                        // No live lane changed: skip the whole group —
                        // one flush-and-jump, exactly like the scalar
                        // conditional write.
                        b.skip_n(global, lane_n);
                    } else {
                        // Stage the whole group; dead lanes re-publish
                        // their frozen bits so flushed runs stay
                        // contiguous (and the line they share with live
                        // lanes is dirtied only once).
                        for &x in gv.iter() {
                            b.push(global, x);
                        }
                    }
                } else {
                    let old = global.load(v);
                    let new = {
                        let mut rd = AsyncReader { global, local: cfg.local_reads.then_some(&buf) };
                        prog.update(v, &mut rd)
                    };
                    delta += prog.delta(old, new);
                    changed += (new != old) as u64;
                    if prog.activates(old, new) {
                        activate_out(v, &mut activated);
                    }
                    let mut b = buf.borrow_mut();
                    if conditional && new == old {
                        b.skip(global);
                    } else {
                        b.push(global, new);
                    }
                }
                processed += 1;
            };
            while let Some(c) = next_chunk(&mut steals) {
                match (sparse, cur) {
                    (true, Some(cur)) => cur.for_each_in(c, &mut body),
                    _ => {
                        for v in c {
                            body(v);
                        }
                    }
                }
            }
            buf.borrow_mut().flush(global);
        }

        let my_round_secs = my_t0.elapsed().as_secs_f64();
        ctrl.deltas[t].store(delta.to_bits(), Ordering::Relaxed);
        if multi {
            for (l, &d) in lane_delta[..lane_n].iter().enumerate() {
                ctrl.lane_deltas[t * lane_n + l].store(d.to_bits(), Ordering::Relaxed);
            }
        }
        ctrl.flushes[t].store(buf.borrow().flushes(), Ordering::Relaxed);
        ctrl.processed[t].store(processed, Ordering::Relaxed);
        ctrl.changed[t].store(changed, Ordering::Relaxed);
        ctrl.activated[t].store(activated, Ordering::Relaxed);
        ctrl.steals[t].store(steals, Ordering::Relaxed);
        ctrl.delta_used[t].store(buf.borrow().capacity() as u64, Ordering::Relaxed);

        // ---- barrier 1: all writes of the round done ----
        ctrl.barrier.wait();

        // Between the barriers: cleanup that must not race the sweep.
        // Under stealing another thread may have been reading our slice of
        // the frontier bitmap (or claiming our chunks) right up to the
        // barrier, so consuming-side clears wait until every sweep is done.
        if let Some(cur) = cur {
            // This round's bits are consumed; clear our slice so the map
            // can serve as the round-after-next's activation target.
            // Masked: boundary words are shared with neighboring
            // partitions.
            cur.clear_range(range.clone());
        }
        if let Some(gr) = grid {
            gr.part(t).reset();
        }
        if let Some(c) = ctl.as_mut() {
            // Adaptive δ: digest this round's telemetry and resize the
            // (flushed-empty) buffer before the next round begins. The
            // resize is purely thread-local — no other thread ever touches
            // this buffer, stolen chunks ride the *executing* thread's
            // buffer via `seek` — so racing the steal deque is safe.
            let total_changed: u64 = ctrl.changed.iter().map(|x| x.load(Ordering::Relaxed)).sum();
            let residual: f64 = ctrl.deltas.iter().map(|d| f64::from_bits(d.load(Ordering::Relaxed))).sum();
            let residual_ratio =
                if prev_residual.is_finite() && prev_residual > 0.0 { residual / prev_residual } else { 1.0 };
            prev_residual = residual;
            let mut b = buf.borrow_mut();
            let tel = Telemetry {
                processed,
                flush_lines: b.lines_flushed() - prev_flush_lines,
                flush_cost: b.take_flush_secs(),
                round_cost: my_round_secs,
                density: total_changed as f64 / n.max(1) as f64,
                residual_ratio,
                live_lanes: live.count_ones() as u64,
            };
            prev_flush_lines = b.lines_flushed();
            let next = c.observe(&tel);
            if next != b.capacity() {
                b.resize(global, next);
            }
        }

        if t == 0 {
            let round_delta: f64 = ctrl.deltas.iter().map(|d| f64::from_bits(d.load(Ordering::Relaxed))).sum();
            let total_flushes: u64 = ctrl.flushes.iter().map(|f| f.load(Ordering::Relaxed)).sum();
            let total_active: u64 = ctrl.processed.iter().map(|p| p.load(Ordering::Relaxed)).sum();
            let total_steals: u64 = ctrl.steals.iter().map(|s| s.load(Ordering::Relaxed)).sum();
            // Batched runs: per-lane residuals drive per-lane drop-out —
            // a lane whose criterion is met is cleared from the live
            // mask, and the run converges once every query is answered.
            let (lane_sums, next_live) = if multi {
                let mut sums = vec![0.0f64; lane_n];
                for chunk in ctrl.lane_deltas.chunks_exact(lane_n) {
                    for (s, d) in sums.iter_mut().zip(chunk) {
                        *s += f64::from_bits(d.load(Ordering::Relaxed));
                    }
                }
                let mut mask = live;
                lanes::for_each_live(live, |l| {
                    if prog.lane_converged(l, sums[l]) {
                        mask &= !(1u32 << l);
                    }
                });
                (sums, mask)
            } else {
                (Vec::new(), live)
            };
            let mut rounds = rounds_out.lock().unwrap();
            let prev_flushes: u64 = rounds.iter().map(|r: &RoundStats| r.flushes).sum();
            rounds.push(RoundStats {
                time_s: t0.elapsed().as_secs_f64(),
                delta: round_delta,
                flushes: total_flushes - prev_flushes,
                active: total_active,
                steals: total_steals,
                delta_trace: if adaptive {
                    ctrl.delta_used.iter().map(|d| d.load(Ordering::Relaxed) as usize).collect()
                } else {
                    Vec::new()
                },
                lane_deltas: lane_sums,
            });
            let conv = if multi { next_live == 0 } else { prog.converged(round_delta) };
            if conv || rounds.len() >= cfg.max_rounds {
                ctrl.done.store(true, Ordering::SeqCst);
                converged_out.store(conv, Ordering::SeqCst);
            } else {
                if multi && next_live != live {
                    ctrl.live.store(next_live, Ordering::SeqCst);
                }
                if frontiers.is_some() {
                    let next_size: u64 = ctrl.activated.iter().map(|a| a.load(Ordering::Relaxed)).sum();
                    let sparse_next = match cfg.schedule {
                        SchedulePolicy::Dense => false,
                        SchedulePolicy::Frontier => true,
                        // DO-BFS-style density switch, re-evaluated per round.
                        SchedulePolicy::Adaptive => (next_size as usize) * ADAPTIVE_SPARSE_DIVISOR < n,
                    };
                    ctrl.sparse_next.store(sparse_next, Ordering::SeqCst);
                }
            }
        }

        // ---- barrier 2: decision published ----
        ctrl.barrier.wait();
        if ctrl.done.load(Ordering::SeqCst) {
            return;
        }
        sparse = ctrl.sparse_next.load(Ordering::SeqCst);
        if t == 0 {
            t0 = Instant::now();
        }
        my_t0 = Instant::now();
        round += 1;
    }
}

/// Local shim: a reader over `SharedValues` (can't use `SharedReader`
/// because sync mode's front buffer alternates between the two arrays).
struct SharedReaderShim<'a>(&'a SharedValues);

impl ValueReader for SharedReaderShim<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }

    #[inline]
    fn prefetch(&mut self, v: VertexId) {
        self.0.prefetch(v as usize);
    }
}

/// Serial reference executor: single thread, plain Jacobi (sync) sweep.
/// Used as the oracle in tests: `run` with `Synchronous` must match this
/// bit-exactly for any thread count (and, for frontier schedules, any
/// schedule — skipped vertices recompute identically by construction).
pub fn run_serial_sync<G: GraphStore, P: VertexProgram>(g: &G, prog: &P, max_rounds: usize) -> RunResult {
    assert_eq!(prog.lanes(), 1, "the serial oracle is single-lane; oracle batched runs lane by lane");
    let n = g.num_vertices();
    let mut front: Vec<u32> = (0..n as VertexId).map(|v| prog.init(v)).collect();
    let mut back = front.clone();
    let mut rounds = Vec::new();
    let mut converged = false;
    while rounds.len() < max_rounds {
        let t0 = Instant::now();
        let mut delta = 0.0;
        for v in 0..n as VertexId {
            let mut rd = SliceReader(&front);
            let new = prog.update(v, &mut rd);
            delta += prog.delta(front[v as usize], new);
            back[v as usize] = new;
        }
        std::mem::swap(&mut front, &mut back);
        rounds.push(RoundStats {
            time_s: t0.elapsed().as_secs_f64(),
            delta,
            flushes: 0,
            active: n as u64,
            steals: 0,
            delta_trace: Vec::new(),
            lane_deltas: Vec::new(),
        });
        if prog.converged(delta) {
            converged = true;
            break;
        }
    }
    RunResult {
        values: front,
        rounds,
        mode: ExecutionMode::Synchronous,
        schedule: SchedulePolicy::Dense,
        threads: 1,
        lanes: 1,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::program::ValueReader;
    use crate::graph::gap::GapGraph;
    use crate::graph::Csr;

    /// Toy program: each vertex takes max(own, in-neighbors) — converges
    /// to per-component max; easy to verify and sensitive to value
    /// propagation speed (async should need fewer rounds than sync).
    struct MaxProp<'g> {
        g: &'g Csr,
    }

    impl VertexProgram for MaxProp<'_> {
        fn name(&self) -> &'static str {
            "maxprop"
        }
        fn init(&self, v: VertexId) -> u32 {
            v * 7919 % 10007
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    fn fixed_point_serial(g: &Csr) -> Vec<u32> {
        run_serial_sync(g, &MaxProp { g }, 10_000).values
    }

    #[test]
    fn sync_matches_serial_any_thread_count() {
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for t in [1, 2, 4, 7] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(t, ExecutionMode::Synchronous));
            assert!(r.converged);
            assert_eq!(r.values, oracle, "threads={t}");
        }
    }

    #[test]
    fn all_modes_reach_same_fixed_point() {
        let g = GapGraph::Web.generate(9, 4);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Asynchronous, ExecutionMode::Delayed(16), ExecutionMode::Delayed(64)] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, mode));
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values, oracle, "{mode:?}");
        }
    }

    #[test]
    fn numa_flag_never_changes_results() {
        // NUMA placement is pure page placement: sync runs stay
        // bit-identical to serial, async/delayed still reach the fixed
        // point, and everything holds whether or not this host actually
        // has multiple nodes (pinning no-ops gracefully).
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            let cfg = EngineConfig::new(4, mode).with_numa();
            let r = run(&g, &MaxProp { g: &g }, &cfg);
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values, oracle, "{mode:?}");
        }
        // Stealing + frontier ride along unchanged (stolen chunks write
        // through the delay buffer into remote-owned, already-touched
        // pages — correctness never depended on placement).
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(16))
            .with_numa()
            .with_schedule(SchedulePolicy::Frontier)
            .with_stealing();
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        assert!(r.converged);
        assert_eq!(r.values, oracle);
    }

    #[test]
    fn numa_partitions_are_line_aligned() {
        let g = GapGraph::Web.generate(9, 4);
        let cfg = EngineConfig::new(5, ExecutionMode::Asynchronous).with_numa();
        let pm = cfg.partition_map(&g);
        let b = pm.bounds();
        for &x in &b[1..b.len() - 1] {
            assert_eq!(x as usize % crate::VALUES_PER_LINE, 0, "interior bound {x}");
        }
        assert_eq!(pm.num_vertices(), g.num_vertices());
    }

    #[test]
    fn frontier_schedules_match_dense_every_mode() {
        // Web is directed (exercises the transpose view); Road is the
        // sparse-frontier showcase.
        for g in [GapGraph::Web.generate(9, 4), GapGraph::Road.generate(9, 0)] {
            let oracle = fixed_point_serial(&g);
            for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
                for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                    let cfg = EngineConfig::new(4, mode).with_schedule(sched);
                    let r = run(&g, &MaxProp { g: &g }, &cfg);
                    assert!(r.converged, "{mode:?}/{sched:?}");
                    assert_eq!(r.values, oracle, "{mode:?}/{sched:?}");
                    assert_eq!(r.schedule, sched);
                }
            }
        }
    }

    #[test]
    fn frontier_sync_round_trajectory_matches_serial() {
        // In sync mode the frontier schedule is bit-identical to dense
        // Jacobi round by round: same round count, same per-round delta.
        let g = GapGraph::Road.generate(9, 0);
        let serial = run_serial_sync(&g, &MaxProp { g: &g }, 10_000);
        let r = run(
            &g,
            &MaxProp { g: &g },
            &EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier),
        );
        assert_eq!(r.num_rounds(), serial.num_rounds());
        for (a, b) in r.rounds.iter().zip(&serial.rounds) {
            assert_eq!(a.delta, b.delta);
        }
        assert_eq!(r.values, serial.values);
    }

    #[test]
    fn frontier_active_counts_shrink() {
        // Synchronous: the frontier trajectory is deterministic and the
        // round count matches dense exactly, so "less total work" is a
        // hard guarantee, not a race-dependent observation.
        let g = GapGraph::Road.generate(10, 0);
        let n = g.num_vertices() as u64;
        let p = MaxProp { g: &g };
        let dense = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous));
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier);
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert_eq!(r.num_rounds(), dense.num_rounds());
        let actives = r.active_counts();
        assert_eq!(actives[0], n, "round 0 is dense");
        assert!(*actives.last().unwrap() < n, "last round must be sparse: {actives:?}");
        // The headline: strictly less total work than the dense schedule.
        assert!(
            r.total_active() < dense.total_active(),
            "frontier {} vs dense {}",
            r.total_active(),
            dense.total_active()
        );
        assert_eq!(dense.total_active(), dense.num_rounds() as u64 * n);
    }

    #[test]
    fn adaptive_starts_dense_then_goes_sparse() {
        let g = GapGraph::Road.generate(10, 0);
        let n = g.num_vertices() as u64;
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Adaptive);
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        assert!(r.converged);
        let actives = r.active_counts();
        assert_eq!(actives[0], n);
        // The convergence tail must trip the density switch.
        assert!(
            actives.iter().any(|&a| a < n / ADAPTIVE_SPARSE_DIVISOR as u64),
            "no sparse round engaged: {actives:?}"
        );
    }

    #[test]
    fn async_never_more_rounds_than_sync_single_thread() {
        // With one thread, async is pure Gauss-Seidel: strictly faster
        // information flow than Jacobi on this monotone program.
        let g = GapGraph::Road.generate(10, 0);
        let p = MaxProp { g: &g };
        let sync = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Synchronous));
        let asyn = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Asynchronous));
        assert!(
            asyn.num_rounds() <= sync.num_rounds(),
            "async {} vs sync {}",
            asyn.num_rounds(),
            sync.num_rounds()
        );
        assert!(asyn.num_rounds() < sync.num_rounds(), "road should show a strict gap");
    }

    #[test]
    fn delayed_flush_counts_reported() {
        let g = GapGraph::Urand.generate(9, 8);
        let p = MaxProp { g: &g };
        let r = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(16)));
        assert!(r.total_flushes() > 0);
        let sync = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous));
        assert_eq!(sync.total_flushes(), 0);
    }

    #[test]
    fn local_reads_variant_converges() {
        let g = GapGraph::Kron.generate(8, 8);
        let oracle = fixed_point_serial(&g);
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(32)).with_local_reads());
        assert_eq!(r.values, oracle);
        let fcfg = EngineConfig::new(4, ExecutionMode::Delayed(32))
            .with_local_reads()
            .with_schedule(SchedulePolicy::Frontier);
        let fr = run(&g, &MaxProp { g: &g }, &fcfg);
        assert_eq!(fr.values, oracle);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        for sched in SchedulePolicy::ALL {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(8, ExecutionMode::Delayed(16)).with_schedule(sched));
            assert!(r.converged, "{sched:?}");
            assert_eq!(r.values.len(), 3, "{sched:?}");
        }
    }

    #[test]
    fn stealing_matches_static_every_mode_and_schedule() {
        // Scale 10 so every partition splits into multiple chunks and the
        // steal path really engages during the parity sweep.
        let g = GapGraph::Web.generate(10, 4);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in SchedulePolicy::ALL {
                let cfg = EngineConfig::new(4, mode).with_schedule(sched).with_stealing();
                let r = run(&g, &MaxProp { g: &g }, &cfg);
                assert!(r.converged, "{mode:?}/{sched:?}");
                assert_eq!(r.values, oracle, "{mode:?}/{sched:?}");
            }
        }
    }

    #[test]
    fn stealing_sync_is_bit_exact_with_serial() {
        // Sync reads only the stable front buffer, so who executes a
        // chunk is invisible: same rounds, same per-round delta (integer
        // counts for MaxProp), same values.
        let g = GapGraph::Road.generate(9, 0);
        let serial = run_serial_sync(&g, &MaxProp { g: &g }, 10_000);
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_stealing();
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        assert_eq!(r.num_rounds(), serial.num_rounds());
        assert_eq!(r.values, serial.values);
        for (a, b) in r.rounds.iter().zip(&serial.rounds) {
            assert_eq!(a.delta, b.delta);
        }
    }

    /// Every vertex points at the first 64: the lowest equal-vertex
    /// partition holds essentially all the pull work, guaranteeing a
    /// straggler whose trailing chunks get stolen.
    fn hub_graph(n: usize) -> Csr {
        let mut b = crate::graph::GraphBuilder::new(n);
        for v in 0..n as VertexId {
            for h in 0..64u32 {
                if v != h {
                    b.push(v, h, 1);
                }
            }
        }
        b.build()
    }

    #[test]
    fn stealing_reports_steals_on_skewed_work() {
        use crate::engine::PartitionStrategy;
        let g = hub_graph(4096);
        let p = MaxProp { g: &g };
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64))
            .with_partition(PartitionStrategy::EqualVertex)
            .with_stealing();
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert!(r.total_steals() > 0, "straggler chunks must be stolen");
        // Static execution of the same config reports zero steals.
        let st = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert_eq!(st.total_steals(), 0);
        assert_eq!(r.values, st.values);
    }

    #[test]
    fn stealing_with_more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(16)] {
            let cfg = EngineConfig::new(8, mode).with_stealing();
            let r = run(&g, &MaxProp { g: &g }, &cfg);
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn adaptive_mode_reaches_fixed_point_every_schedule_and_stealing() {
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for sched in SchedulePolicy::ALL {
            for steal in [false, true] {
                let mut cfg = EngineConfig::new(4, ExecutionMode::Adaptive).with_schedule(sched);
                if steal {
                    cfg = cfg.with_stealing();
                }
                let r = run(&g, &MaxProp { g: &g }, &cfg);
                assert!(r.converged, "{sched:?} steal={steal}");
                assert_eq!(r.values, oracle, "{sched:?} steal={steal}");
                // Every round carries a full per-thread δ trace,
                // cache-line rounded.
                for rs in &r.rounds {
                    assert_eq!(rs.delta_trace.len(), r.threads, "{sched:?} steal={steal}");
                    for &d in &rs.delta_trace {
                        assert_eq!(d % crate::VALUES_PER_LINE, 0, "{sched:?} steal={steal}");
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_trace_seeds_from_offline_rule() {
        // Low-locality graph: round 0's δ equals the offline dense rule
        // over each thread's own range; non-adaptive runs carry no trace.
        let g = GapGraph::Urand.generate(9, 8);
        let cfg = EngineConfig::new(4, ExecutionMode::Adaptive);
        let pm = cfg.partition_map(&g);
        let r = run(&g, &MaxProp { g: &g }, &cfg);
        let loc = properties::diagonal_locality(&g, 4);
        for (t, &d) in r.rounds[0].delta_trace.iter().enumerate() {
            let max = round_delta(pm.len(t));
            assert_eq!(d, controller::seed_delta(loc, pm.len(t), max), "thread {t}");
        }
        let st = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert!(st.rounds.iter().all(|rs| rs.delta_trace.is_empty()), "static runs carry no trace");
    }

    #[test]
    fn adaptive_with_more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(8, ExecutionMode::Adaptive).with_stealing());
        assert!(r.converged);
        assert_eq!(r.values.len(), 3);
    }

    /// k-lane batched MaxProp: lane `l` floods the max of a per-lane
    /// salted init — k independent label propagations in one sweep, each
    /// with a unique fixed point (so every mode must match bit-exactly).
    struct MultiMax<'g> {
        g: &'g Csr,
        k: usize,
    }

    fn salted_init(v: VertexId, l: usize) -> u32 {
        (v as u64 * (7919 + 13 * l as u64) % (10007 + l as u64)) as u32
    }

    impl VertexProgram for MultiMax<'_> {
        fn name(&self) -> &'static str {
            "multimax"
        }
        fn lanes(&self) -> usize {
            self.k
        }
        fn init(&self, v: VertexId) -> u32 {
            salted_init(v, 0)
        }
        fn init_lane(&self, v: VertexId, l: usize) -> u32 {
            salted_init(v, l)
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn update_lanes<R: lanes::LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
            let mut nb = [0u32; lanes::MAX_LANES];
            for &u in self.g.in_neighbors(v) {
                r.read_group(u, &mut nb[..self.k]);
                lanes::for_each_live(live, |l| out[l] = out[l].max(nb[l]));
            }
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    /// Lane `l` of [`MultiMax`] as an independent single-query program.
    struct SaltedMax<'g> {
        g: &'g Csr,
        l: usize,
    }

    impl VertexProgram for SaltedMax<'_> {
        fn name(&self) -> &'static str {
            "saltedmax"
        }
        fn init(&self, v: VertexId) -> u32 {
            salted_init(v, self.l)
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    #[test]
    fn batched_lanes_match_independent_runs_every_mode() {
        let g = GapGraph::Web.generate(9, 4);
        let k = 4;
        let oracles: Vec<Vec<u32>> =
            (0..k).map(|l| run_serial_sync(&g, &SaltedMax { g: &g, l }, 10_000).values).collect();
        for mode in [
            ExecutionMode::Synchronous,
            ExecutionMode::Asynchronous,
            ExecutionMode::Delayed(32),
            ExecutionMode::Adaptive,
        ] {
            for sched in SchedulePolicy::ALL {
                for steal in [false, true] {
                    let mut cfg = EngineConfig::new(4, mode).with_schedule(sched);
                    if steal {
                        cfg = cfg.with_stealing();
                    }
                    let r = run(&g, &MultiMax { g: &g, k }, &cfg);
                    assert!(r.converged, "{mode:?}/{sched:?} steal={steal}");
                    assert_eq!(r.lanes, k);
                    assert_eq!(r.values.len(), g.num_vertices() * k);
                    for (l, want) in oracles.iter().enumerate() {
                        assert_eq!(&r.lane_values(l), want, "lane {l} {mode:?}/{sched:?} steal={steal}");
                    }
                    for rs in &r.rounds {
                        assert_eq!(rs.lane_deltas.len(), k, "{mode:?}/{sched:?} steal={steal}");
                    }
                }
            }
        }
    }

    #[test]
    fn converged_lanes_drop_out_early() {
        // Lane 1 starts at its fixed point (constant 0 floods nothing);
        // lane 0 is a real propagation. The dead lane must report a 0.0
        // residual from round 0 on and keep its frozen values, while the
        // live lane iterates to the oracle.
        struct HalfDead<'g> {
            g: &'g Csr,
        }
        impl VertexProgram for HalfDead<'_> {
            fn name(&self) -> &'static str {
                "halfdead"
            }
            fn lanes(&self) -> usize {
                2
            }
            fn init(&self, v: VertexId) -> u32 {
                salted_init(v, 0)
            }
            fn init_lane(&self, v: VertexId, l: usize) -> u32 {
                if l == 0 {
                    salted_init(v, 0)
                } else {
                    0
                }
            }
            fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
                let mut best = r.read(v);
                for &u in self.g.in_neighbors(v) {
                    best = best.max(r.read(u));
                }
                best
            }
            fn update_lanes<R: lanes::LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
                let mut nb = [0u32; 2];
                for &u in self.g.in_neighbors(v) {
                    r.read_group(u, &mut nb);
                    lanes::for_each_live(live, |l| out[l] = out[l].max(nb[l]));
                }
            }
            fn delta(&self, old: u32, new: u32) -> f64 {
                (old != new) as u32 as f64
            }
            fn converged(&self, d: f64) -> bool {
                d == 0.0
            }
        }
        let g = GapGraph::Road.generate(9, 0);
        let oracle = run_serial_sync(&g, &SaltedMax { g: &g, l: 0 }, 10_000).values;
        let r = run(&g, &HalfDead { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(64)));
        assert!(r.converged);
        assert!(r.num_rounds() > 2, "lane 0 must outlive lane 1");
        assert_eq!(r.lane_values(0), oracle);
        assert!(r.lane_values(1).iter().all(|&x| x == 0), "dead lane frozen at its init");
        let t1 = r.lane_delta_trace(1);
        assert!(t1.iter().all(|&d| d == 0.0), "lane 1 never produced a residual: {t1:?}");
        let t0 = r.lane_delta_trace(0);
        assert!(t0[0] > 0.0, "lane 0 starts live: {t0:?}");
        assert_eq!(*t0.last().unwrap(), 0.0, "lane 0 ends converged");
    }

    #[test]
    fn no_atomics_matches_async_fixed_point_every_schedule_and_stealing() {
        let g = GapGraph::Web.generate(9, 4);
        let oracle = fixed_point_serial(&g);
        for sched in SchedulePolicy::ALL {
            for steal in [false, true] {
                let mut cfg = EngineConfig::new(4, ExecutionMode::Asynchronous).with_no_atomics().with_schedule(sched);
                if steal {
                    cfg = cfg.with_stealing();
                }
                let r = run(&g, &MaxProp { g: &g }, &cfg);
                assert!(r.converged, "{sched:?} steal={steal}");
                assert_eq!(r.values, oracle, "{sched:?} steal={steal}");
            }
        }
    }

    #[test]
    fn no_atomics_batched_lanes_match_independent_runs() {
        let g = GapGraph::Web.generate(9, 4);
        let k = 4;
        let oracles: Vec<Vec<u32>> =
            (0..k).map(|l| run_serial_sync(&g, &SaltedMax { g: &g, l }, 10_000).values).collect();
        for steal in [false, true] {
            let mut cfg = EngineConfig::new(4, ExecutionMode::Asynchronous).with_no_atomics();
            if steal {
                cfg = cfg.with_stealing();
            }
            let r = run(&g, &MultiMax { g: &g, k }, &cfg);
            assert!(r.converged, "steal={steal}");
            for (l, want) in oracles.iter().enumerate() {
                assert_eq!(&r.lane_values(l), want, "lane {l} steal={steal}");
            }
        }
    }

    #[test]
    fn no_atomics_routes_stolen_chunks_through_the_buffer() {
        use crate::engine::PartitionStrategy;
        // The hub graph forces steals; stolen (non-owned) chunks must be
        // published via line-coalesced flushes, owned ones store plain.
        let g = hub_graph(4096);
        let p = MaxProp { g: &g };
        let cfg = EngineConfig::new(4, ExecutionMode::Asynchronous)
            .with_no_atomics()
            .with_partition(PartitionStrategy::EqualVertex)
            .with_stealing();
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert!(r.total_steals() > 0, "straggler chunks must be stolen");
        assert_eq!(r.values, fixed_point_serial(&g));
        // A steal-free no-atomics run never touches the routing buffer.
        let quiet = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Asynchronous).with_no_atomics());
        assert_eq!(quiet.total_flushes(), 0, "owned-range sweeps bypass the buffer entirely");
    }

    #[test]
    fn no_atomics_composes_with_conditional_writes() {
        struct CondMax<'g> {
            g: &'g Csr,
        }
        impl VertexProgram for CondMax<'_> {
            fn name(&self) -> &'static str {
                "condmax"
            }
            fn init(&self, v: VertexId) -> u32 {
                v * 7919 % 10007
            }
            fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
                let mut best = r.read(v);
                for &u in self.g.in_neighbors(v) {
                    best = best.max(r.read(u));
                }
                best
            }
            fn delta(&self, old: u32, new: u32) -> f64 {
                (old != new) as u32 as f64
            }
            fn converged(&self, d: f64) -> bool {
                d == 0.0
            }
            fn conditional_writes(&self) -> bool {
                true
            }
        }
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        let r = run(&g, &CondMax { g: &g }, &EngineConfig::new(4, ExecutionMode::Asynchronous).with_no_atomics());
        assert!(r.converged);
        assert_eq!(r.values, oracle);
    }

    #[test]
    #[should_panic(expected = "no_atomics is an asynchronous-mode variant")]
    fn no_atomics_rejects_non_async_modes() {
        let g = crate::graph::GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let _ = run(&g, &MaxProp { g: &g }, &EngineConfig::new(2, ExecutionMode::Delayed(16)).with_no_atomics());
    }

    #[test]
    fn resume_from_fixed_point_is_near_instant() {
        let g = GapGraph::Road.generate(9, 0);
        let p = MaxProp { g: &g };
        let cold_cfg =
            EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier);
        let cold = run(&g, &p, &cold_cfg);
        assert!(cold.converged);
        // Warm start at the fixed point with a tiny dirty set: round 0
        // sweeps only the dirty vertices, finds no change, and the run
        // confirms convergence immediately.
        let cfg = cold_cfg.clone().with_resume(cold.resume_from(&[0, 1, 2]));
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        assert_eq!(r.values, cold.values);
        assert!(r.num_rounds() < cold.num_rounds(), "warm start must beat the cold run");
        assert_eq!(r.num_rounds(), 1, "fixed-point resume confirms in one sparse round");
        assert_eq!(r.total_active(), 3, "only the dirty vertices are swept");
        // Dense schedules accept the seed too (values-only warm start).
        let dense = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous).with_resume(cold.resume_from(&[0])));
        assert!(dense.converged);
        assert_eq!(dense.values, cold.values);
        assert_eq!(dense.num_rounds(), 1);
    }

    #[test]
    fn resume_propagates_from_dirty_region() {
        // Bump one vertex's value above the old fixed point and mark it
        // dirty: the warm async run must flood the new max from there.
        let g = GapGraph::Road.generate(9, 0);
        let p = MaxProp { g: &g };
        let cold = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Asynchronous));
        assert!(cold.converged);
        let s = (0..g.num_vertices() as VertexId).find(|&v| g.out_degree(v) > 0).unwrap();
        // Dirty = the vertices whose *inputs* changed: s's readers (its
        // out-neighbors), plus s itself.
        let dirty: Vec<VertexId> = std::iter::once(s).chain(g.out_neighbors(s).iter().copied()).collect();
        let mut seed = cold.resume_from(&dirty);
        seed.values[s as usize] = 1_000_000; // larger than any init value
        let cfg = EngineConfig::new(4, ExecutionMode::Asynchronous)
            .with_schedule(SchedulePolicy::Frontier)
            .with_resume(seed);
        let r = run(&g, &p, &cfg);
        assert!(r.converged);
        // s keeps the bump and its readers adopt it.
        assert_eq!(r.values[s as usize], 1_000_000);
        assert!(r.values.iter().filter(|&&x| x == 1_000_000).count() > 1, "the bump must spread");
    }

    #[test]
    fn max_rounds_respected() {
        struct NeverConverge;
        impl VertexProgram for NeverConverge {
            fn name(&self) -> &'static str {
                "never"
            }
            fn init(&self, _v: VertexId) -> u32 {
                0
            }
            fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
                r.read(v).wrapping_add(1)
            }
            fn delta(&self, _o: u32, _n: u32) -> f64 {
                1.0
            }
            fn converged(&self, _d: f64) -> bool {
                false
            }
        }
        let g = crate::graph::GraphBuilder::new(4).edges(&[(0, 1)]).build();
        let mut cfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        cfg.max_rounds = 5;
        let r = run(&g, &NeverConverge, &cfg);
        assert_eq!(r.num_rounds(), 5);
        assert!(!r.converged);
    }
}
