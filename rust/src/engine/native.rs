//! Real-thread executor: `std::thread` workers, one per partition block,
//! barrier-synchronized rounds, with value visibility governed by
//! [`ExecutionMode`].
//!
//! All three modes share the same round structure (the paper counts
//! rounds for the asynchronous version too — threads sweep their range
//! once per round and a barrier separates rounds so convergence can be
//! evaluated globally); only *when* newly computed values become visible
//! differs:
//!
//! * sync — written to the inactive half of a double buffer, visible
//!   next round;
//! * async — stored straight into the shared array;
//! * delayed(δ) — staged in a [`DelayBuffer`] and published every δ
//!   elements.

use std::cell::RefCell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::Instant;

use crate::graph::{Csr, VertexId};

use super::delay_buffer::DelayBuffer;
use super::program::{ValueReader, VertexProgram};
use super::shared::{SharedValues, SliceReader};
use super::stats::{RoundStats, RunResult};
use super::{EngineConfig, ExecutionMode};

/// Reader for async/delayed modes: global array, optionally patched with
/// the thread's own unflushed values (§III-C local-read variant).
struct AsyncReader<'a> {
    global: &'a SharedValues,
    local: Option<&'a RefCell<DelayBuffer>>,
}

impl ValueReader for AsyncReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        if let Some(buf) = self.local {
            if let Some(bits) = buf.borrow().pending(v) {
                return bits;
            }
        }
        self.global.load(v)
    }
}

/// Shared control block for the worker gang.
struct Ctrl {
    barrier: Barrier,
    /// Per-thread round delta (f64 bits), written by owner only.
    deltas: Vec<AtomicU64>,
    /// Per-thread cumulative flush count.
    flushes: Vec<AtomicU64>,
    /// Set by thread 0 once converged / max rounds hit.
    done: AtomicBool,
}

/// Run `prog` on `g` under `cfg`. Spawns `cfg.threads` OS threads (they
/// live for the whole run). Deterministic for `Synchronous` mode;
/// async/delayed results depend on interleaving but converge to the same
/// fixed point (chaotic relaxation).
pub fn run<P: VertexProgram>(g: &Csr, prog: &P, cfg: &EngineConfig) -> RunResult {
    let n = g.num_vertices();
    let pm = cfg.partition_map(g);
    let t_count = pm.num_parts();
    let init: Vec<u32> = (0..n as VertexId).map(|v| prog.init(v)).collect();

    let global = SharedValues::from_bits(init.iter().copied());
    // Double buffer for sync mode only (async/delayed read+write `global`).
    let back = SharedValues::from_bits(init.iter().copied());

    let ctrl = Ctrl {
        barrier: Barrier::new(t_count),
        deltas: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        flushes: (0..t_count).map(|_| AtomicU64::new(0)).collect(),
        done: AtomicBool::new(false),
    };
    // Written by thread 0 only (between barriers); Mutex for Sync-ness.
    let rounds_out: Mutex<Vec<RoundStats>> = Mutex::new(Vec::new());
    let converged_out = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for t in 0..t_count {
            let range = pm.range(t);
            let ctrl = &ctrl;
            let global = &global;
            let back = &back;
            let rounds_out = &rounds_out;
            let converged_out = &converged_out;
            let handle = move || {
                worker(t, range, g, prog, cfg, ctrl, global, back, rounds_out, converged_out);
            };
            if t == t_count - 1 {
                // Run the last worker on the caller thread: saves one
                // spawn and keeps thread 0 = a spawned worker symmetric.
                handle();
            } else {
                scope.spawn(handle);
            }
        }
    });

    let rounds = rounds_out.into_inner().unwrap();
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let values = if sync_mode {
        // Round r writes into `back` when r is even (buffers swap roles
        // each round); after `rounds.len()` rounds the freshest buffer is:
        if rounds.len() % 2 == 1 {
            back.to_vec()
        } else {
            global.to_vec()
        }
    } else {
        global.to_vec()
    };

    RunResult {
        values,
        rounds,
        mode: cfg.mode,
        threads: t_count,
        converged: converged_out.load(Ordering::SeqCst),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker<P: VertexProgram>(
    t: usize,
    range: Range<VertexId>,
    g: &Csr,
    prog: &P,
    cfg: &EngineConfig,
    ctrl: &Ctrl,
    global: &SharedValues,
    back: &SharedValues,
    rounds_out: &Mutex<Vec<RoundStats>>,
    converged_out: &AtomicBool,
) {
    let _ = g;
    let delta_cap = cfg.effective_delta(range.len());
    let buf = RefCell::new(DelayBuffer::new(delta_cap));
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    let conditional = prog.conditional_writes();

    let mut round = 0usize;
    let mut t0 = Instant::now();
    loop {
        let mut delta = 0.0f64;

        if sync_mode {
            // Buffers swap roles each round; `front` is read-only here
            // because every writer targets `write` and ranges are disjoint.
            let (front, write) = if round % 2 == 0 { (global, back) } else { (back, global) };
            let snapshot_reader = front; // reads are racy-free: nobody writes front this round
            for v in range.clone() {
                let old = snapshot_reader.load(v);
                let mut rd = SharedReaderShim(snapshot_reader);
                let new = prog.update(v, &mut rd);
                delta += prog.delta(old, new);
                // Sync must carry unchanged values across the swap.
                write.store(v, if conditional && new == old { old } else { new });
            }
        } else {
            buf.borrow_mut().begin(range.start);
            for v in range.clone() {
                let old = global.load(v);
                let new = {
                    let mut rd = AsyncReader { global, local: cfg.local_reads.then_some(&buf) };
                    prog.update(v, &mut rd)
                };
                delta += prog.delta(old, new);
                let mut b = buf.borrow_mut();
                if conditional && new == old {
                    b.skip(global);
                } else {
                    b.push(global, new);
                }
            }
            buf.borrow_mut().flush(global);
        }

        ctrl.deltas[t].store(delta.to_bits(), Ordering::Relaxed);
        ctrl.flushes[t].store(buf.borrow().flushes(), Ordering::Relaxed);

        // ---- barrier 1: all writes of the round done ----
        ctrl.barrier.wait();

        if t == 0 {
            let round_delta: f64 = ctrl.deltas.iter().map(|d| f64::from_bits(d.load(Ordering::Relaxed))).sum();
            let total_flushes: u64 = ctrl.flushes.iter().map(|f| f.load(Ordering::Relaxed)).sum();
            let mut rounds = rounds_out.lock().unwrap();
            let prev_flushes: u64 = rounds.iter().map(|r: &RoundStats| r.flushes).sum();
            rounds.push(RoundStats {
                time_s: t0.elapsed().as_secs_f64(),
                delta: round_delta,
                flushes: total_flushes - prev_flushes,
            });
            let conv = prog.converged(round_delta);
            if conv || rounds.len() >= cfg.max_rounds {
                ctrl.done.store(true, Ordering::SeqCst);
                converged_out.store(conv, Ordering::SeqCst);
            }
        }

        // ---- barrier 2: decision published ----
        ctrl.barrier.wait();
        if ctrl.done.load(Ordering::SeqCst) {
            return;
        }
        if t == 0 {
            t0 = Instant::now();
        }
        round += 1;
    }
}

/// Local shim: a reader over `SharedValues` (can't use `SharedReader`
/// because sync mode's front buffer alternates between the two arrays).
struct SharedReaderShim<'a>(&'a SharedValues);

impl ValueReader for SharedReaderShim<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }
}

/// Serial reference executor: single thread, plain Jacobi (sync) sweep.
/// Used as the oracle in tests: `run` with `Synchronous` must match this
/// bit-exactly for any thread count.
pub fn run_serial_sync<P: VertexProgram>(g: &Csr, prog: &P, max_rounds: usize) -> RunResult {
    let n = g.num_vertices();
    let mut front: Vec<u32> = (0..n as VertexId).map(|v| prog.init(v)).collect();
    let mut back = front.clone();
    let mut rounds = Vec::new();
    let mut converged = false;
    while rounds.len() < max_rounds {
        let t0 = Instant::now();
        let mut delta = 0.0;
        for v in 0..n as VertexId {
            let mut rd = SliceReader(&front);
            let new = prog.update(v, &mut rd);
            delta += prog.delta(front[v as usize], new);
            back[v as usize] = new;
        }
        std::mem::swap(&mut front, &mut back);
        rounds.push(RoundStats { time_s: t0.elapsed().as_secs_f64(), delta, flushes: 0 });
        if prog.converged(delta) {
            converged = true;
            break;
        }
    }
    RunResult { values: front, rounds, mode: ExecutionMode::Synchronous, threads: 1, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::program::ValueReader;
    use crate::graph::gap::GapGraph;

    /// Toy program: each vertex takes max(own, in-neighbors) — converges
    /// to per-component max; easy to verify and sensitive to value
    /// propagation speed (async should need fewer rounds than sync).
    struct MaxProp<'g> {
        g: &'g Csr,
    }

    impl VertexProgram for MaxProp<'_> {
        fn name(&self) -> &'static str {
            "maxprop"
        }
        fn init(&self, v: VertexId) -> u32 {
            v * 7919 % 10007
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    fn fixed_point_serial(g: &Csr) -> Vec<u32> {
        run_serial_sync(g, &MaxProp { g }, 10_000).values
    }

    #[test]
    fn sync_matches_serial_any_thread_count() {
        let g = GapGraph::Kron.generate(9, 8);
        let oracle = fixed_point_serial(&g);
        for t in [1, 2, 4, 7] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(t, ExecutionMode::Synchronous));
            assert!(r.converged);
            assert_eq!(r.values, oracle, "threads={t}");
        }
    }

    #[test]
    fn all_modes_reach_same_fixed_point() {
        let g = GapGraph::Web.generate(9, 4);
        let oracle = fixed_point_serial(&g);
        for mode in [ExecutionMode::Asynchronous, ExecutionMode::Delayed(16), ExecutionMode::Delayed(64)] {
            let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, mode));
            assert!(r.converged, "{mode:?}");
            assert_eq!(r.values, oracle, "{mode:?}");
        }
    }

    #[test]
    fn async_never_more_rounds_than_sync_single_thread() {
        // With one thread, async is pure Gauss-Seidel: strictly faster
        // information flow than Jacobi on this monotone program.
        let g = GapGraph::Road.generate(10, 0);
        let p = MaxProp { g: &g };
        let sync = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Synchronous));
        let asyn = run(&g, &p, &EngineConfig::new(1, ExecutionMode::Asynchronous));
        assert!(
            asyn.num_rounds() <= sync.num_rounds(),
            "async {} vs sync {}",
            asyn.num_rounds(),
            sync.num_rounds()
        );
        assert!(asyn.num_rounds() < sync.num_rounds(), "road should show a strict gap");
    }

    #[test]
    fn delayed_flush_counts_reported() {
        let g = GapGraph::Urand.generate(9, 8);
        let p = MaxProp { g: &g };
        let r = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(16)));
        assert!(r.total_flushes() > 0);
        let sync = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous));
        assert_eq!(sync.total_flushes(), 0);
    }

    #[test]
    fn local_reads_variant_converges() {
        let g = GapGraph::Kron.generate(8, 8);
        let oracle = fixed_point_serial(&g);
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(4, ExecutionMode::Delayed(32)).with_local_reads());
        assert_eq!(r.values, oracle);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let r = run(&g, &MaxProp { g: &g }, &EngineConfig::new(8, ExecutionMode::Delayed(16)));
        assert!(r.converged);
        assert_eq!(r.values.len(), 3);
    }

    #[test]
    fn max_rounds_respected() {
        struct NeverConverge;
        impl VertexProgram for NeverConverge {
            fn name(&self) -> &'static str {
                "never"
            }
            fn init(&self, _v: VertexId) -> u32 {
                0
            }
            fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
                r.read(v).wrapping_add(1)
            }
            fn delta(&self, _o: u32, _n: u32) -> f64 {
                1.0
            }
            fn converged(&self, _d: f64) -> bool {
                false
            }
        }
        let g = crate::graph::GraphBuilder::new(4).edges(&[(0, 1)]).build();
        let mut cfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        cfg.max_rounds = 5;
        let r = run(&g, &NeverConverge, &cfg);
        assert_eq!(r.num_rounds(), 5);
        assert!(!r.converged);
    }
}
