//! Online adaptive δ controller — closing the paper's §V open question
//! ("further work must be done to determine what buffer size to use,
//! dependent on both the graph's topology and the number of threads")
//! with a runtime feedback loop instead of a one-shot offline rule.
//!
//! Under [`super::ExecutionMode::Adaptive`] every worker owns one
//! [`DeltaController`] and resizes its delay buffer *between rounds*
//! from three per-round signals (DESIGN.md §7):
//!
//! 1. **Flush-burst cost** — cost per flushed cache line. Each controller
//!    remembers the cheapest per-line flush it has ever seen (the
//!    uncontended baseline); a round whose per-line cost exceeds
//!    [`CONTENTION_FACTOR`] × that baseline is *flush-contended*: other
//!    threads are invalidating the lines this thread publishes.
//! 2. **Update density** — the fraction of vertices whose stored value
//!    actually *changed* this round (Maiter-style observed usefulness).
//!    Under a sparse schedule this is the set `RoundStats::active`
//!    sweeps next round's frontier from; unlike the swept count it
//!    remains meaningful under the paper's dense sweeps, where SSSP/CC
//!    touch every vertex but change almost none. Dense change means
//!    updates are plentiful and staleness is cheap; sparse change
//!    (§IV-D) means every update is precious.
//! 3. **Residual improvement** — the round-over-round ratio of the
//!    summed convergence metric. Growing δ is only considered while the
//!    residual is still shrinking: delaying harder when progress has
//!    stalled would slow information flow further.
//!
//! Policy: **double δ** when flushes are contended and progress is dense
//! and improving; **halve toward asynchronous** after
//! [`SHRINK_STREAK`] consecutive sparse rounds (hysteresis, so one
//! sparse round under an adaptive *schedule* never triggers a spurious
//! shrink); otherwise hold. Every move is guarded by a regression check:
//! a resize that worsens this thread's per-vertex round cost by more
//! than [`REGRESSION_GATE`] is undone the next round, and each reverted
//! move doubles the evidence required to try that direction again
//! (exponential backoff), so oscillating around a good operating point
//! costs a geometrically vanishing share of the run — which is how the
//! `daig experiment adaptive` regret against the exhaustive static-δ
//! sweep stays small.
//!
//! The initial δ comes from the same offline rule as
//! [`crate::coordinator::autotune`] (which now delegates here): the
//! §IV-C diagonal-locality gate seeds web-like topologies at δ = 0 and
//! everything else at [`dense_rule_delta`] of the thread's own range.
//!
//! Bounds and invariants (property-tested in `rust/tests/prop_engine.rs`):
//! every δ the controller emits is a whole number of cache lines
//! ([`round_delta`]), lies in `[0, max]`, and consecutive values differ
//! by at most one [`grow_step`]/[`shrink_step`]. δ = 0 buffers nothing,
//! so a round executed at δ = 0 charges no flushes.
//!
//! Determinism: the controller is a pure function of its telemetry. The
//! simulator feeds it deterministic cycle counts, so simulated δ traces
//! are bit-identical across runs; the native executor feeds wall-clock
//! times, so its trace may differ run to run — harmlessly, because δ
//! affects only performance, never the fixed point (chaotic relaxation).

use crate::VALUES_PER_LINE;

use super::delay_buffer::round_delta;
use super::schedule::ADAPTIVE_SPARSE_DIVISOR;

/// Topology threshold above which buffering is predicted useless (§IV-C:
/// Web measures ~0.88, all buffer-friendly graphs < 0.05). Shared with
/// the offline rule in [`crate::coordinator::autotune`].
pub const LOCALITY_GATE: f64 = 0.5;

/// A round is flush-contended when its cost per flushed line exceeds
/// this multiple of the cheapest per-line flush the thread has seen.
pub const CONTENTION_FACTOR: f64 = 1.5;

/// Consecutive sparse rounds required before δ actually halves —
/// hysteresis so a single sparse round (e.g. the adaptive *schedule*
/// dipping below its density threshold once) cannot trigger a shrink.
pub const SHRINK_STREAK: u32 = 2;

/// A resize that worsens per-vertex round cost by more than this factor
/// is reverted on the next observation.
pub const REGRESSION_GATE: f64 = 1.10;

/// Upper bound on the exponential backoff counters (shrink evidence
/// requirement and grow suppression span, in rounds).
pub const BACKOFF_CAP: u32 = 64;

/// The offline dense-update rule (§IV, Figs 3–4): δ ≈ half the
/// per-thread range, snapped to a power of two inside the paper's sweep
/// `[16, 32768]`, cache-line rounded. [`crate::coordinator::autotune`]
/// applies it ahead of time; the adaptive controller uses it as a seed.
pub fn dense_rule_delta(range: usize) -> usize {
    let target = (range / 2).clamp(16, 32_768);
    let pow2 = if target.is_power_of_two() { target } else { target.next_power_of_two() / 2 };
    round_delta(pow2).max(VALUES_PER_LINE)
}

/// Seed δ for one thread: the §IV-C locality gate sends web-like
/// topologies straight to asynchronous (δ = 0); everything else starts
/// at the offline dense rule over the thread's own range, clamped to the
/// controller's upper bound.
pub fn seed_delta(locality: f64, range: usize, max: usize) -> usize {
    if locality > LOCALITY_GATE || range == 0 || max == 0 {
        0
    } else {
        dense_rule_delta(range).min(max)
    }
}

/// One controller step up: δ = 0 grows to a single cache line, anything
/// else doubles, capped at `max`.
pub fn grow_step(cur: usize, max: usize) -> usize {
    if cur == 0 {
        if max >= VALUES_PER_LINE {
            VALUES_PER_LINE
        } else {
            0
        }
    } else {
        (cur * 2).min(max)
    }
}

/// One controller step down: a single cache line (or less) collapses to
/// asynchronous, anything else halves (cache-line rounded).
pub fn shrink_step(cur: usize) -> usize {
    if cur <= VALUES_PER_LINE {
        0
    } else {
        round_delta(cur / 2)
    }
}

/// One round of per-thread measurements, in whatever cost unit the
/// executor uses (seconds native, cycles sim) — the controller only ever
/// compares costs against each other, never across executors.
#[derive(Debug, Clone, Copy)]
pub struct Telemetry {
    /// Vertices this thread swept this round (own plus stolen chunks).
    pub processed: u64,
    /// Cache lines this thread's delay-buffer flushes dirtied this round.
    pub flush_lines: u64,
    /// Cost spent inside flushes this round.
    pub flush_cost: f64,
    /// Total cost of this thread's round.
    pub round_cost: f64,
    /// Global fraction of vertices whose stored value changed this
    /// round (changed ÷ n — the Maiter-style usefulness signal; under a
    /// sparse schedule this is what next round's frontier grows from).
    pub density: f64,
    /// This round's summed residual over the previous round's (≤ 1 means
    /// converging; 1.0 on the first round).
    pub residual_ratio: f64,
    /// Value lanes still live this round (1 for single-query runs).
    /// Under batched execution every flushed line carries this many
    /// queries' updates, so the contention signal divides the per-line
    /// flush cost by it: a line that costs 2× but serves 8 queries is
    /// cheap, not contended.
    pub live_lanes: u64,
}

/// Per-thread online δ controller (see module docs for the policy).
#[derive(Debug, Clone)]
pub struct DeltaController {
    /// δ for the upcoming round (cache-line rounded; 0 = asynchronous).
    cur: usize,
    /// Upper bound (cache-line rounded; the thread's range, or n under
    /// work stealing, mirroring the static executors' cap).
    max: usize,
    /// Cheapest cost-per-flushed-line seen — the uncontended baseline.
    best_line_cost: f64,
    /// Consecutive sparse-round shrink votes.
    shrink_votes: u32,
    /// Votes required before a shrink fires; starts at
    /// [`SHRINK_STREAK`] and doubles (capped at [`BACKOFF_CAP`]) every
    /// time a shrink is reverted, so a workload that punishes small δ
    /// is probed geometrically less often.
    shrink_need: u32,
    /// Rounds during which growth stays suppressed after a reverted
    /// grow; the suppression span doubles per reverted grow.
    grow_cooldown: u32,
    grow_penalty: u32,
    /// `Some(grew)` when the previous round ran a *fresh policy move*
    /// whose regression check is still pending. Reverts and holds leave
    /// this `None`, so noise after a revert can neither "revert the
    /// revert" nor back off a direction that was never attempted.
    pending: Option<bool>,
    /// δ used in the previous observed round (revert target).
    last_delta: usize,
    /// Per-vertex round cost of the previous observed round.
    last_cost: f64,
}

impl DeltaController {
    /// Controller starting at `seed`, bounded by `round_delta(max)`.
    pub fn new(seed: usize, max: usize) -> Self {
        let max = round_delta(max);
        let cur = round_delta(seed).min(max);
        Self {
            cur,
            max,
            best_line_cost: f64::INFINITY,
            shrink_votes: 0,
            shrink_need: SHRINK_STREAK,
            grow_cooldown: 0,
            grow_penalty: SHRINK_STREAK,
            pending: None,
            last_delta: cur,
            last_cost: f64::INFINITY,
        }
    }

    /// δ for the next round.
    pub fn delta(&self) -> usize {
        self.cur
    }

    /// The controller's upper bound.
    pub fn bound(&self) -> usize {
        self.max
    }

    /// Digest one round of telemetry; returns the δ for the next round.
    pub fn observe(&mut self, t: &Telemetry) -> usize {
        if t.processed == 0 {
            // Nothing measured (empty partition or fully-skipped sparse
            // round): hold, and forget any pending regression check.
            self.pending = None;
            self.last_delta = self.cur;
            return self.cur;
        }
        let cost = t.round_cost / t.processed as f64;
        // Lane-aware per-line flush cost: a flushed line carries one
        // update per live lane, so its cost is split across them.
        let line_cost = if t.flush_lines > 0 {
            t.flush_cost / (t.flush_lines * t.live_lanes.max(1)) as f64
        } else {
            f64::INFINITY
        };
        if line_cost < self.best_line_cost {
            self.best_line_cost = line_cost;
        }

        // Regression guard, evaluated only for the round that ran a
        // fresh policy move (`pending`): a resize that made this
        // thread's per-vertex round cost worse is undone (one step back,
        // by construction), and the direction that failed backs off
        // exponentially so re-probing it costs a vanishing share of the
        // run. The revert itself leaves `pending` empty, so a noisy
        // post-revert round can neither bounce back to the rejected δ
        // nor back off a direction that was never attempted.
        if let Some(grew) = self.pending.take() {
            if self.last_cost.is_finite() && cost > self.last_cost * REGRESSION_GATE {
                if grew {
                    self.grow_penalty = (self.grow_penalty * 2).min(BACKOFF_CAP);
                    self.grow_cooldown = self.grow_penalty;
                } else {
                    self.shrink_need = (self.shrink_need * 2).min(BACKOFF_CAP);
                }
                let back = self.last_delta;
                self.last_delta = self.cur;
                self.last_cost = cost;
                self.cur = back;
                self.shrink_votes = 0;
                return self.cur;
            }
        }
        self.grow_cooldown = self.grow_cooldown.saturating_sub(1);

        let dense = t.density * ADAPTIVE_SPARSE_DIVISOR as f64 >= 1.0;
        let improving = t.residual_ratio <= 1.0;
        let contended = line_cost.is_finite()
            && self.best_line_cost.is_finite()
            && line_cost > CONTENTION_FACTOR * self.best_line_cost;

        let next = if contended && dense && improving && self.grow_cooldown == 0 {
            self.shrink_votes = 0;
            grow_step(self.cur, self.max)
        } else if !dense {
            self.shrink_votes += 1;
            if self.shrink_votes >= self.shrink_need {
                self.shrink_votes = 0;
                shrink_step(self.cur)
            } else {
                self.cur
            }
        } else {
            self.shrink_votes = 0;
            self.cur
        };
        if next != self.cur {
            self.pending = Some(next > self.cur);
        }
        self.last_delta = self.cur;
        self.last_cost = cost;
        self.cur = next;
        self.cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tel(processed: u64, density: f64) -> Telemetry {
        Telemetry {
            processed,
            flush_lines: 4,
            flush_cost: 4.0,
            round_cost: 1000.0,
            density,
            residual_ratio: 0.9,
            live_lanes: 1,
        }
    }

    #[test]
    fn steps_are_line_rounded_and_inverse() {
        assert_eq!(grow_step(0, 1024), VALUES_PER_LINE);
        assert_eq!(grow_step(16, 1024), 32);
        assert_eq!(grow_step(512, 1024), 1024);
        assert_eq!(grow_step(1024, 1024), 1024, "capped at max");
        assert_eq!(grow_step(0, 0), 0, "no room to grow");
        assert_eq!(shrink_step(0), 0);
        assert_eq!(shrink_step(16), 0);
        assert_eq!(shrink_step(32), 16);
        assert_eq!(shrink_step(1024), 512);
        for d in [0usize, 16, 32, 64, 4096] {
            assert_eq!(grow_step(d, 1 << 20) % VALUES_PER_LINE, 0);
            assert_eq!(shrink_step(d) % VALUES_PER_LINE, 0);
        }
    }

    #[test]
    fn seed_respects_locality_gate_and_bounds() {
        assert_eq!(seed_delta(0.9, 1000, 1024), 0, "web-like: async start");
        assert_eq!(seed_delta(0.1, 0, 1024), 0, "empty range");
        assert_eq!(seed_delta(0.1, 1000, 0), 0, "zero bound");
        let s = seed_delta(0.1, 1000, 1024);
        assert_eq!(s, 256, "range/2 snapped to 2^k");
        assert_eq!(seed_delta(0.1, 1000, 64), 64, "clamped to max");
        assert_eq!(dense_rule_delta(4), 16, "floor of the paper's sweep");
        assert_eq!(dense_rule_delta(1 << 20), 32_768, "ceiling of the paper's sweep");
    }

    #[test]
    fn sparse_rounds_shrink_only_after_streak() {
        let mut c = DeltaController::new(64, 1024);
        assert_eq!(c.observe(&tel(100, 0.01)), 64, "one sparse round holds");
        assert_eq!(c.observe(&tel(100, 0.01)), 32, "second sparse round halves");
        assert_eq!(c.observe(&tel(100, 0.01)), 32);
        assert_eq!(c.observe(&tel(100, 0.01)), 16);
        assert_eq!(c.observe(&tel(100, 0.01)), 16);
        assert_eq!(c.observe(&tel(100, 0.01)), 0, "one line collapses to async");
        assert_eq!(c.observe(&tel(100, 0.01)), 0, "absorbing at 0");
    }

    #[test]
    fn dense_round_resets_shrink_votes() {
        let mut c = DeltaController::new(64, 1024);
        c.observe(&tel(100, 0.01));
        c.observe(&tel(100, 0.9)); // dense round in between
        assert_eq!(c.observe(&tel(100, 0.01)), 64, "streak was reset");
        assert_eq!(c.observe(&tel(100, 0.01)), 32);
    }

    #[test]
    fn contended_dense_improving_grows() {
        let mut c = DeltaController::new(64, 1024);
        // Establish a cheap flush baseline.
        let cheap = Telemetry { flush_cost: 4.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&cheap), 64);
        // Now flushes cost 3x per line: contended, dense, improving.
        let hot = Telemetry { flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&hot), 128);
        // Stalled residual blocks further growth.
        let stalled = Telemetry { residual_ratio: 1.5, ..hot };
        assert_eq!(c.observe(&stalled), 128);
    }

    #[test]
    fn dying_lanes_raise_per_query_line_cost() {
        let mut c = DeltaController::new(64, 1024);
        let batched = Telemetry { live_lanes: 8, ..tel(100, 0.9) };
        assert_eq!(c.observe(&batched), 64, "baseline at 8 live lanes");
        // Identical physical flush cost after 7 of the 8 queries
        // finished: each flushed line now carries one update instead of
        // eight, so the per-query line cost is 8× the baseline —
        // contended + dense + improving ⇒ grow.
        let solo = Telemetry { live_lanes: 1, ..tel(100, 0.9) };
        assert_eq!(c.observe(&solo), 128);
    }

    #[test]
    fn regression_reverts_one_step() {
        let mut c = DeltaController::new(64, 1024);
        let cheap = Telemetry { flush_cost: 4.0, ..tel(100, 0.9) };
        c.observe(&cheap);
        let hot = Telemetry { flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&hot), 128, "grew on contention");
        // The grown round costs 50% more per vertex: revert.
        let worse = Telemetry { round_cost: 1500.0, flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&worse), 64, "regression reverted");
    }

    #[test]
    fn noise_after_revert_neither_bounces_nor_misattributes() {
        let mut c = DeltaController::new(64, 1024);
        let cheap = Telemetry { flush_cost: 4.0, ..tel(100, 0.9) };
        c.observe(&cheap); // flush baseline
        let hot = Telemetry { flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&hot), 128, "grew on contention");
        let worse = Telemetry { round_cost: 1500.0, flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&worse), 64, "regression reverted");
        // A noisy round right after the revert must hold: no policy move
        // is pending, so there is nothing to re-revert, and growth is on
        // cooldown.
        let noisy = Telemetry { round_cost: 2500.0, flush_cost: 12.0, ..tel(100, 0.9) };
        assert_eq!(c.observe(&noisy), 64, "no bounce back to the rejected δ");
        // And the shrink hysteresis was not inflated by the noise: two
        // sparse votes still shrink.
        c.observe(&tel(100, 0.01));
        assert_eq!(c.observe(&tel(100, 0.01)), 32, "shrink_need untouched by a reverted *grow*");
    }

    #[test]
    fn reverted_shrink_backs_off_exponentially() {
        let mut c = DeltaController::new(64, 1024);
        // Two sparse rounds shrink 64 -> 32.
        c.observe(&tel(100, 0.01));
        assert_eq!(c.observe(&tel(100, 0.01)), 32);
        // The shrunken round costs 50% more per vertex: revert to 64 and
        // double the evidence requirement.
        let worse = Telemetry { round_cost: 1500.0, ..tel(100, 0.01) };
        assert_eq!(c.observe(&worse), 64, "shrink reverted");
        // Now 4 sparse votes are needed before the next shrink attempt
        // (cost back to baseline so no further reverts fire).
        let back = Telemetry { round_cost: 1440.0, ..tel(100, 0.01) };
        assert_eq!(c.observe(&back), 64, "vote 1/4");
        assert_eq!(c.observe(&back), 64, "vote 2/4");
        assert_eq!(c.observe(&back), 64, "vote 3/4");
        assert_eq!(c.observe(&back), 32, "vote 4/4 shrinks again");
    }

    #[test]
    fn zero_processed_holds() {
        let mut c = DeltaController::new(64, 1024);
        for _ in 0..10 {
            assert_eq!(c.observe(&tel(0, 0.0)), 64);
        }
    }

    #[test]
    fn trace_invariants_under_arbitrary_telemetry() {
        // Whatever the signals, δ stays line-rounded, bounded, and moves
        // by at most one step.
        let mut rng = crate::util::rng::SplitMix64::new(0xADA9);
        let max = 4096usize;
        let mut c = DeltaController::new(seed_delta(0.1, 5000, max), max);
        let mut prev = c.delta();
        for _ in 0..500 {
            let t = Telemetry {
                processed: rng.next_below(200),
                flush_lines: rng.next_below(64),
                flush_cost: rng.next_f64() * 100.0,
                round_cost: rng.next_f64() * 10_000.0,
                density: rng.next_f64(),
                residual_ratio: rng.next_f64() * 2.0,
                live_lanes: 1 + rng.next_below(16),
            };
            let d = c.observe(&t);
            assert_eq!(d % VALUES_PER_LINE, 0);
            assert!(d <= max);
            let one_step = d == prev
                || d == grow_step(prev, max)
                || d == shrink_step(prev)
                || prev == grow_step(d, max)
                || prev == shrink_step(d);
            assert!(one_step, "{prev} -> {d} is more than one step");
            prev = d;
        }
    }
}
