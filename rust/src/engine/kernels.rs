//! Lane-group compute kernels: a scalar reference path (always built,
//! stable toolchain) and — under `--features simd` (nightly
//! `portable_simd`) — `std::simd` vector versions dispatched at runtime
//! on the lane count.
//!
//! Design constraints, in order:
//!
//! 1. **Post-gather vectorization.** Kernels operate on a lane group
//!    *after* [`super::lanes::LaneReader::read_group`] has produced it,
//!    so the reader call sequence — and therefore the simulator's
//!    line-access charging — is identical for the scalar and vector
//!    paths. SIMD changes how a group is *combined*, never how it is
//!    *fetched*.
//! 2. **Bit parity with the scalar path.** Where the engine is bit-exact
//!    (sync mode, the deterministic simulator), scalar and SIMD runs
//!    must produce identical bits. For SSSP that is free: the branchless
//!    `min(out, du saturating+ w)` form is bit-identical to the
//!    INF-guarded scalar relax (`INF` saturates back to `INF`, which
//!    loses every `min`). For PageRank it means the vector kernel uses a
//!    *separate* multiply and add — a fused mul-add would round once
//!    where the scalar path rounds twice, breaking parity — so the SIMD
//!    win comes from width, not from fusion.
//! 3. **Mask-driven lane drop-out.** Converged queries (dead lanes)
//!    must keep their frozen bits. The vector kernels blend with the
//!    live-lane mask, writing back the original bits of dead lanes —
//!    observationally identical to the scalar `for_each_live` loop.
//!
//! Lane counts 4/8/16 take the vector path (`u32x4/8/16`, `f32x4/8/16`);
//! k ∈ {1, 2} always runs scalar (a 2-lane vector spans 8 bytes — below
//! the width where the mask/select overhead pays for itself).

use crate::graph::VertexId;

use super::lanes;

#[cfg(feature = "simd")]
use std::sync::atomic::{AtomicBool, Ordering};

/// When set (SIMD builds only), the dispatchers below ignore the vector
/// kernels and run the scalar reference — the in-binary baseline that
/// lets one `--features simd` process measure its own scalar-vs-SIMD
/// speedup (`bench_micro` → BENCH_simd.json) and lets the differential
/// suite compare the two paths end-to-end through the engine.
#[cfg(feature = "simd")]
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force (or stop forcing) the scalar path in a SIMD build. A no-op in
/// scalar builds, where the scalar path is all there is. Not meant for
/// concurrent toggling mid-run: flip it between engine runs only.
pub fn set_force_scalar(on: bool) {
    #[cfg(feature = "simd")]
    FORCE_SCALAR.store(on, Ordering::Relaxed);
    #[cfg(not(feature = "simd"))]
    let _ = on;
}

/// Whether dispatch is currently pinned to the scalar reference.
pub fn force_scalar() -> bool {
    #[cfg(feature = "simd")]
    {
        FORCE_SCALAR.load(Ordering::Relaxed)
    }
    #[cfg(not(feature = "simd"))]
    {
        false
    }
}

/// Distance marker for unreachable vertices, duplicated from
/// `algorithms::sssp::INF` to keep the engine layer free of algorithm
/// imports (the two are asserted equal in tests).
pub const RELAX_INF: u32 = u32::MAX;

/// Issue a prefetch-into-L1 hint for the cache line holding `*p`.
/// Compiles to `prefetcht0` on x86-64 and to nothing elsewhere. A
/// prefetch has no memory effects (it is legal for any address, mapped
/// or not), so callers may hint speculatively past the end of a
/// neighbor list.
#[inline(always)]
pub fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint with no architectural side effects;
    // it cannot fault even on invalid addresses.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8)
    };
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Relax every live lane of `out` against neighbor group `nb` over an
/// edge of weight `w`: `out[l] = min(out[l], nb[l] saturating+ w)`.
/// Dead lanes keep their bits. Dispatches to the vector kernel for
/// k ∈ {4, 8, 16} when built with `--features simd`.
#[inline]
pub fn sssp_relax(out: &mut [u32], nb: &[u32], w: u32, live: u32) {
    #[cfg(feature = "simd")]
    if !force_scalar() {
        match out.len() {
            4 => return vector::sssp_relax::<4>(out, nb, w, live),
            8 => return vector::sssp_relax::<8>(out, nb, w, live),
            16 => return vector::sssp_relax::<16>(out, nb, w, live),
            _ => {}
        }
    }
    scalar::sssp_relax(out, nb, w, live);
}

/// Accumulate one neighbor's PageRank contribution into every live lane:
/// `acc[l] += f32(nb[l]) * inv`. Dead lanes keep their bits.
#[inline]
pub fn pr_accumulate(acc: &mut [f32], nb: &[u32], inv: f32, live: u32) {
    #[cfg(feature = "simd")]
    if !force_scalar() {
        match acc.len() {
            4 => return vector::pr_accumulate::<4>(acc, nb, inv, live),
            8 => return vector::pr_accumulate::<8>(acc, nb, inv, live),
            16 => return vector::pr_accumulate::<16>(acc, nb, inv, live),
            _ => {}
        }
    }
    scalar::pr_accumulate(acc, nb, inv, live);
}

/// Finish a PageRank group: `out[l] = bits(base[l] + damping * acc[l])`
/// for live lanes; dead lanes keep their bits.
#[inline]
pub fn pr_finish(out: &mut [u32], base: &[f32], acc: &[f32], damping: f32, live: u32) {
    #[cfg(feature = "simd")]
    if !force_scalar() {
        match out.len() {
            4 => return vector::pr_finish::<4>(out, base, acc, damping, live),
            8 => return vector::pr_finish::<8>(out, base, acc, damping, live),
            16 => return vector::pr_finish::<16>(out, base, acc, damping, live),
            _ => {}
        }
    }
    scalar::pr_finish(out, base, acc, damping, live);
}

/// Whether this build dispatches lane counts 4/8/16 to `std::simd`
/// kernels (reported into BENCH_simd.json so scalar and SIMD artifacts
/// are distinguishable).
pub const fn simd_enabled() -> bool {
    cfg!(feature = "simd")
}

/// The scalar reference kernels — the portable fallback, and the
/// definition of correct (and, where applicable, bit-exact) results
/// that the vector path must reproduce.
pub mod scalar {
    use super::lanes;

    /// See [`super::sssp_relax`].
    #[inline]
    pub fn sssp_relax(out: &mut [u32], nb: &[u32], w: u32, live: u32) {
        lanes::for_each_live(live, |l| {
            let du = nb[l];
            if du != super::RELAX_INF {
                out[l] = out[l].min(du.saturating_add(w));
            }
        });
    }

    /// See [`super::pr_accumulate`].
    #[inline]
    pub fn pr_accumulate(acc: &mut [f32], nb: &[u32], inv: f32, live: u32) {
        lanes::for_each_live(live, |l| acc[l] += f32::from_bits(nb[l]) * inv);
    }

    /// See [`super::pr_finish`].
    #[inline]
    pub fn pr_finish(out: &mut [u32], base: &[f32], acc: &[f32], damping: f32, live: u32) {
        lanes::for_each_live(live, |l| out[l] = (base[l] + damping * acc[l]).to_bits());
    }
}

/// `std::simd` kernels (nightly `portable_simd`). One vector spans the
/// whole lane group — exactly the register shape the interleaved lane
/// layout was designed to be (`engine::lanes` module docs).
#[cfg(feature = "simd")]
pub mod vector {
    use std::simd::cmp::{SimdOrd, SimdPartialEq};
    use std::simd::num::{SimdFloat, SimdUint};
    use std::simd::{LaneCount, Mask, Simd, SupportedLaneCount};

    /// Per-element mask from the engine's live-lane bitmask: lane `l`
    /// is on iff bit `l` of `live` is set.
    #[inline]
    fn live_mask<const N: usize>(live: u32) -> Mask<i32, N>
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let bits = Simd::<u32, N>::from_array(std::array::from_fn(|l| 1u32 << l));
        (Simd::splat(live) & bits).simd_ne(Simd::splat(0))
    }

    /// Vector min-relax: saturating add subsumes the scalar INF guard
    /// bit-exactly (module docs, constraint 2).
    #[inline]
    pub fn sssp_relax<const N: usize>(out: &mut [u32], nb: &[u32], w: u32, live: u32)
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let old = Simd::<u32, N>::from_slice(out);
        let cand = Simd::<u32, N>::from_slice(nb).saturating_add(Simd::splat(w));
        live_mask::<N>(live).select(old.simd_min(cand), old).copy_to_slice(out);
    }

    /// Vector rank accumulation. Deliberately *unfused* multiply-then-
    /// add: the scalar path rounds the product and the sum separately,
    /// and sync/sim bit parity is an acceptance gate (module docs,
    /// constraint 2).
    #[inline]
    pub fn pr_accumulate<const N: usize>(acc: &mut [f32], nb: &[u32], inv: f32, live: u32)
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let old = Simd::<f32, N>::from_slice(acc);
        let contrib = Simd::<f32, N>::from_bits(Simd::<u32, N>::from_slice(nb)) * Simd::splat(inv);
        live_mask::<N>(live).select(old + contrib, old).copy_to_slice(acc);
    }

    /// Vector PageRank finish (same unfused-rounding argument).
    #[inline]
    pub fn pr_finish<const N: usize>(out: &mut [u32], base: &[f32], acc: &[f32], damping: f32, live: u32)
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let old = Simd::<u32, N>::from_slice(out);
        let fresh = Simd::<f32, N>::from_slice(base) + Simd::splat(damping) * Simd::<f32, N>::from_slice(acc);
        live_mask::<N>(live).select(fresh.to_bits(), old).copy_to_slice(out);
    }
}

/// Prefetch look-ahead driver for CSR gather loops: hints the group of
/// the neighbor `dist` positions ahead of index `i` in `neighbors`
/// (no-op when `dist == 0` or past the end of the list).
#[inline(always)]
pub fn prefetch_ahead<F: FnMut(VertexId)>(neighbors: &[VertexId], i: usize, dist: usize, mut hint: F) {
    if dist != 0 {
        if let Some(&a) = neighbors.get(i + dist) {
            hint(a);
        }
    }
}

/// Frontier activation gather: feed every out-neighbor of `v` to
/// `sink`. The one place both executors' activation inner loops live —
/// the native path sinks into an atomic frontier bitmap, the simulator
/// sinks into its deterministic bitmap while charging buffer-push cost.
/// Generic over [`crate::graph::GraphStore`], so overlay-backed graphs
/// activate through insert/delete deltas with no executor changes.
#[inline(always)]
pub fn activate_out_neighbors<G, F>(g: &G, v: VertexId, mut sink: F)
where
    G: crate::graph::GraphStore,
    F: FnMut(VertexId),
{
    for w in g.out_neighbors(v) {
        sink(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic test-vector generator (SplitMix64).
    fn mix(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn rand_u32s(seed: u64, n: usize, inf_every: usize) -> Vec<u32> {
        let mut s = seed;
        (0..n)
            .map(|i| if inf_every != 0 && i % inf_every == 0 { RELAX_INF } else { mix(&mut s) as u32 })
            .collect()
    }

    #[test]
    fn inf_marker_matches_sssp() {
        assert_eq!(RELAX_INF, crate::algorithms::sssp::INF);
    }

    #[test]
    fn scalar_relax_masks_and_saturates() {
        let mut out = [10, 20, 30, 40];
        // Lane 1 dead; lane 2's neighbor is INF (must not wrap to a
        // tiny distance); lane 3 relaxes.
        scalar::sssp_relax(&mut out, &[5, 1, RELAX_INF, 7], 3, 0b1101);
        assert_eq!(out, [8, 20, 30, 10]);
        // Saturation near the top of the range.
        let mut out = [RELAX_INF; 1];
        scalar::sssp_relax(&mut out, &[RELAX_INF - 1], 5, 0b1);
        assert_eq!(out, [RELAX_INF], "u32::MAX - 1 + 5 saturates to INF");
    }

    #[test]
    fn scalar_pr_kernels_match_inline_arithmetic() {
        let nb = [1.5f32.to_bits(), 2.0f32.to_bits()];
        let mut acc = [0.25f32, 9.0];
        scalar::pr_accumulate(&mut acc, &nb, 0.5, 0b01);
        assert_eq!(acc, [0.25 + 1.5 * 0.5, 9.0], "dead lane untouched");
        let mut out = [0u32, 77];
        scalar::pr_finish(&mut out, &[0.15, 0.15], &acc, 0.85, 0b01);
        assert_eq!(out, [(0.15f32 + 0.85 * 1.0).to_bits(), 77]);
    }

    #[test]
    fn dispatch_leaves_dead_lanes_frozen_every_k() {
        for k in crate::engine::lanes::LANE_COUNTS {
            let nb = rand_u32s(7 + k as u64, k, 3);
            let mut out = rand_u32s(99 + k as u64, k, 0);
            let frozen = out.clone();
            sssp_relax(&mut out, &nb, 4, 0);
            assert_eq!(out, frozen, "k={k}: empty mask must not move bits");
        }
    }

    #[test]
    fn force_scalar_toggle_roundtrips() {
        // Other tests in this binary either pass an empty mask or call
        // the scalar/vector kernels directly, so flipping the global
        // toggle here cannot change their results.
        assert!(!force_scalar(), "default is dispatched");
        set_force_scalar(true);
        assert_eq!(force_scalar(), simd_enabled(), "toggle only bites in SIMD builds");
        set_force_scalar(false);
        assert!(!force_scalar());
    }

    #[test]
    fn prefetch_is_safe_and_lookahead_bounded() {
        // Smoke: hinting a real address and the null page must not fault.
        let x = 42u32;
        prefetch_read(&x as *const u32);
        prefetch_read(std::ptr::null::<u32>());
        let ns: Vec<VertexId> = (0..10).collect();
        let mut hits = Vec::new();
        for i in 0..ns.len() {
            prefetch_ahead(&ns, i, 4, |v| hits.push(v));
        }
        assert_eq!(hits, vec![4, 5, 6, 7, 8, 9], "look-ahead stops at the end");
        hits.clear();
        for i in 0..ns.len() {
            prefetch_ahead(&ns, i, 0, |v| hits.push(v));
        }
        assert!(hits.is_empty(), "distance 0 disables hinting");
    }

    /// The SIMD acceptance gate at kernel granularity: for every vector
    /// width and a spread of live masks, the vector kernels must be
    /// bit-identical to the scalar reference on randomized groups.
    #[cfg(feature = "simd")]
    mod simd_parity {
        use super::*;
        use crate::engine::lanes::full_mask;

        fn masks(k: usize) -> Vec<u32> {
            let full = full_mask(k);
            vec![full, 0, 1, full & 0b1010_1010_1010_1010, full >> 1]
        }

        #[test]
        fn sssp_relax_bit_exact() {
            for k in [4usize, 8, 16] {
                for live in masks(k) {
                    for trial in 0..50u64 {
                        let nb = rand_u32s(trial * 3 + k as u64, k, 4);
                        let w = (trial as u32).wrapping_mul(2654435761) % 300;
                        let mut a = rand_u32s(trial * 5 + 1, k, 6);
                        let mut b = a.clone();
                        scalar::sssp_relax(&mut a, &nb, w, live);
                        match k {
                            4 => vector::sssp_relax::<4>(&mut b, &nb, w, live),
                            8 => vector::sssp_relax::<8>(&mut b, &nb, w, live),
                            _ => vector::sssp_relax::<16>(&mut b, &nb, w, live),
                        }
                        assert_eq!(a, b, "k={k} live={live:#b} trial={trial}");
                    }
                }
            }
        }

        #[test]
        fn pr_kernels_bit_exact() {
            for k in [4usize, 8, 16] {
                for live in masks(k) {
                    for trial in 0..50u64 {
                        let mut s = trial + 1000 * k as u64;
                        // Finite, well-scaled scores (the engine only
                        // ever stores finite f32 rank bits).
                        let nb: Vec<u32> =
                            (0..k).map(|_| ((mix(&mut s) as f64 / u64::MAX as f64) as f32).to_bits()).collect();
                        let base: Vec<f32> = (0..k).map(|_| (mix(&mut s) % 1000) as f32 * 1e-4).collect();
                        let inv = 1.0 / ((mix(&mut s) % 63 + 1) as f32);
                        let mut acc_a: Vec<f32> = (0..k).map(|_| (mix(&mut s) % 997) as f32 * 1e-3).collect();
                        let mut acc_b = acc_a.clone();
                        scalar::pr_accumulate(&mut acc_a, &nb, inv, live);
                        match k {
                            4 => vector::pr_accumulate::<4>(&mut acc_b, &nb, inv, live),
                            8 => vector::pr_accumulate::<8>(&mut acc_b, &nb, inv, live),
                            _ => vector::pr_accumulate::<16>(&mut acc_b, &nb, inv, live),
                        }
                        assert_eq!(
                            acc_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                            acc_b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                            "accumulate k={k} live={live:#b} trial={trial}"
                        );
                        let mut out_a = rand_u32s(trial, k, 0);
                        let mut out_b = out_a.clone();
                        scalar::pr_finish(&mut out_a, &base, &acc_a, 0.85, live);
                        match k {
                            4 => vector::pr_finish::<4>(&mut out_b, &base, &acc_b, 0.85, live),
                            8 => vector::pr_finish::<8>(&mut out_b, &base, &acc_b, 0.85, live),
                            _ => vector::pr_finish::<16>(&mut out_b, &base, &acc_b, 0.85, live),
                        }
                        assert_eq!(out_a, out_b, "finish k={k} live={live:#b} trial={trial}");
                    }
                }
            }
        }
    }
}
