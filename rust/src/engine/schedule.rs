//! Frontier-aware round scheduling — which vertices a round touches.
//!
//! The paper's executors sweep **every** vertex in **every** round. On
//! high-diameter or rapidly converging workloads (road/web graphs,
//! SSSP/CC/BFS) the overwhelming majority of vertices are already at
//! their fixed point after a few rounds, so a dense sweep wastes almost
//! all of its work — the inefficiency delta/frontier-driven systems
//! (Maiter-style accumulative iteration, arXiv 2407.14544) eliminate.
//!
//! [`SchedulePolicy`] makes the choice a first-class engine dimension:
//!
//! * [`SchedulePolicy::Dense`] — the paper's behavior, bit-for-bit: every
//!   round sweeps every vertex, no activation tracking at all.
//! * [`SchedulePolicy::Frontier`] — round 0 sweeps densely (every vertex
//!   must compute once from its init value); afterwards a round touches
//!   only vertices *activated* by a neighbor's change in the previous
//!   round (see [`crate::engine::VertexProgram::activates`]).
//! * [`SchedulePolicy::Adaptive`] — DO-BFS-style discrete hybrid (the
//!   precedent already cited in `algorithms/dobfs.rs`): sweeps densely
//!   while the upcoming frontier is large (bitmap scans beat random
//!   access), sparsely once it shrinks below `1/`[`ADAPTIVE_SPARSE_DIVISOR`]
//!   of the vertices.
//!
//! Correctness: every vertex program here recomputes its value as a pure
//! function of values read through the [`crate::engine::ValueReader`], so
//! skipping a vertex none of whose in-neighbors changed reproduces the
//! dense sweep's result *exactly* — in synchronous mode the schedule is
//! bit-identical to the dense serial oracle round by round. The δ-delay
//! machinery composes because sparse sweeps generalize the conditional-
//! write `skip()` path: staged runs stay contiguous, jumping flushes
//! first ([`crate::engine::delay_buffer::DelayBuffer::seek`]).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::graph::VertexId;

/// Which vertices a round touches (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SchedulePolicy {
    /// The paper's dense sweep: every vertex, every round.
    #[default]
    Dense,
    /// Dense round 0, then only activated vertices.
    Frontier,
    /// Dense while the frontier is large, sparse once it shrinks.
    Adaptive,
}

/// `Adaptive` switches to sparse sweeps when the next frontier holds
/// fewer than `n / ADAPTIVE_SPARSE_DIVISOR` vertices (and back to dense
/// when it regrows) — the α/β direction heuristic of DO-BFS collapsed to
/// one density threshold, re-evaluated every round.
pub const ADAPTIVE_SPARSE_DIVISOR: usize = 8;

impl SchedulePolicy {
    /// Canonical CLI/report label.
    pub fn label(&self) -> &'static str {
        match self {
            SchedulePolicy::Dense => "dense",
            SchedulePolicy::Frontier => "frontier",
            SchedulePolicy::Adaptive => "adaptive",
        }
    }

    /// Parse labels produced by [`Self::label`].
    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Some(SchedulePolicy::Dense),
            "frontier" | "sparse" => Some(SchedulePolicy::Frontier),
            "adaptive" => Some(SchedulePolicy::Adaptive),
            _ => None,
        }
    }

    /// All policies, for sweeps and tests.
    pub const ALL: [SchedulePolicy; 3] = [SchedulePolicy::Dense, SchedulePolicy::Frontier, SchedulePolicy::Adaptive];
}

/// Word/bit split of a vertex id.
#[inline]
fn word_bit(v: VertexId) -> (usize, u64) {
    ((v / 64) as usize, 1u64 << (v % 64))
}

/// Mask selecting the bits of word `w` (vertex ids `64w..64w+64`) that
/// fall inside `range`. Zero when the word is disjoint from the range.
#[inline]
fn range_mask(w: usize, range: &Range<VertexId>) -> u64 {
    let lo = (w as u64) * 64;
    let hi = lo + 64;
    let (start, end) = (range.start as u64, range.end as u64);
    if end <= lo || start >= hi {
        return 0;
    }
    let mut mask = !0u64;
    if start > lo {
        mask &= !0u64 << (start - lo);
    }
    if end < hi {
        mask &= !0u64 >> (hi - end);
    }
    mask
}

/// Words overlapping `range` (empty iterator for an empty range).
#[inline]
fn word_span(range: &Range<VertexId>) -> Range<usize> {
    if range.start >= range.end {
        return 0..0;
    }
    (range.start / 64) as usize..((range.end - 1) / 64) as usize + 1
}

/// A shared frontier bitmap: any thread may activate any vertex, each
/// thread consumes only its own partition range. Relaxed atomics — the
/// round barrier orders publication, exactly like the value array.
pub struct AtomicBitmap {
    words: Vec<AtomicU64>,
}

impl AtomicBitmap {
    /// All-clear bitmap over `n` vertices.
    pub fn new(n: usize) -> Self {
        Self { words: (0..n.div_ceil(64)).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Set bit `v`; returns true if it was newly set (callers count
    /// frontier growth without a second pass).
    #[inline]
    pub fn set(&self, v: VertexId) -> bool {
        let (w, bit) = word_bit(v);
        self.words[w].fetch_or(bit, Ordering::Relaxed) & bit == 0
    }

    /// Whether bit `v` is set.
    #[inline]
    pub fn get(&self, v: VertexId) -> bool {
        let (w, bit) = word_bit(v);
        self.words[w].load(Ordering::Relaxed) & bit != 0
    }

    /// Visit every set bit inside `range`, ascending.
    pub fn for_each_in<F: FnMut(VertexId)>(&self, range: Range<VertexId>, mut f: F) {
        for w in word_span(&range) {
            let mut bits = self.words[w].load(Ordering::Relaxed) & range_mask(w, &range);
            while bits != 0 {
                let b = bits.trailing_zeros();
                f((w as u64 * 64) as VertexId + b);
                bits &= bits - 1;
            }
        }
    }

    /// Clear only the bits inside `range`. Boundary words may be shared
    /// with a neighboring partition, so this masks rather than storing
    /// zero wholesale.
    pub fn clear_range(&self, range: Range<VertexId>) {
        for w in word_span(&range) {
            self.words[w].fetch_and(!range_mask(w, &range), Ordering::Relaxed);
        }
    }

    /// Number of set bits (diagnostics; O(words)).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.load(Ordering::Relaxed).count_ones() as usize).sum()
    }
}

/// Plain (single-owner) bitmap helpers for the deterministic simulator.
pub mod bits {
    use super::{range_mask, word_bit, word_span, Range, VertexId};

    /// Backing words for `n` vertices.
    pub fn words_for(n: usize) -> Vec<u64> {
        vec![0u64; n.div_ceil(64)]
    }

    /// Set bit `v`; returns true if newly set.
    #[inline]
    pub fn set(words: &mut [u64], v: VertexId) -> bool {
        let (w, bit) = word_bit(v);
        let fresh = words[w] & bit == 0;
        words[w] |= bit;
        fresh
    }

    /// Whether bit `v` is set.
    #[inline]
    pub fn get(words: &[u64], v: VertexId) -> bool {
        let (w, bit) = word_bit(v);
        words[w] & bit != 0
    }

    /// Visit every set bit inside `range`, ascending.
    pub fn for_each_in<F: FnMut(VertexId)>(words: &[u64], range: Range<VertexId>, mut f: F) {
        for w in word_span(&range) {
            let mut bits = words[w] & range_mask(w, &range);
            while bits != 0 {
                let b = bits.trailing_zeros();
                f((w as u64 * 64) as VertexId + b);
                bits &= bits - 1;
            }
        }
    }

    /// Population count.
    pub fn count(words: &[u64]) -> usize {
        words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for p in SchedulePolicy::ALL {
            assert_eq!(SchedulePolicy::from_label(p.label()), Some(p));
        }
        assert_eq!(SchedulePolicy::from_label("bogus"), None);
        assert_eq!(SchedulePolicy::default(), SchedulePolicy::Dense);
    }

    #[test]
    fn atomic_bitmap_set_get_count() {
        let b = AtomicBitmap::new(200);
        assert!(b.set(0));
        assert!(b.set(63));
        assert!(b.set(64));
        assert!(b.set(199));
        assert!(!b.set(63), "second set reports not-new");
        assert!(b.get(64) && !b.get(65));
        assert_eq!(b.count(), 4);
    }

    #[test]
    fn for_each_respects_range() {
        let b = AtomicBitmap::new(256);
        for v in [0u32, 10, 63, 64, 65, 127, 128, 255] {
            b.set(v);
        }
        let mut seen = Vec::new();
        b.for_each_in(10..129, |v| seen.push(v));
        assert_eq!(seen, vec![10, 63, 64, 65, 127, 128]);
        let mut none = Vec::new();
        b.for_each_in(30..60, |v| none.push(v));
        assert!(none.is_empty());
        b.for_each_in(0..0, |_| panic!("empty range must not visit"));
    }

    #[test]
    fn clear_range_is_masked() {
        let b = AtomicBitmap::new(128);
        for v in 0..128u32 {
            b.set(v);
        }
        b.clear_range(10..70);
        assert_eq!(b.count(), 128 - 60);
        assert!(b.get(9) && !b.get(10) && !b.get(69) && b.get(70));
    }

    #[test]
    fn plain_bits_match_atomic() {
        let mut w = bits::words_for(150);
        assert!(bits::set(&mut w, 149));
        assert!(!bits::set(&mut w, 149));
        assert!(bits::get(&w, 149));
        assert_eq!(bits::count(&w), 1);
        let mut seen = Vec::new();
        bits::for_each_in(&w, 0..150, |v| seen.push(v));
        assert_eq!(seen, vec![149]);
    }

    #[test]
    fn threads_can_activate_concurrently() {
        let b = AtomicBitmap::new(4096);
        let newly: u64 = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4u32)
                .map(|t| {
                    let b = &b;
                    s.spawn(move || {
                        let mut fresh = 0u64;
                        for i in 0..4096u32 {
                            if i % 4 >= t {
                                // overlapping sets across threads
                                if b.set(i) {
                                    fresh += 1;
                                }
                            }
                        }
                        fresh
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        // Every vertex got set by at least one thread, exactly once "newly".
        assert_eq!(newly, 4096);
        assert_eq!(b.count(), 4096);
    }
}
