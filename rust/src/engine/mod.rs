//! Execution engine: the paper's three modes over pull-style vertex
//! programs.
//!
//! * [`ExecutionMode::Synchronous`] — Jacobi-style double buffering; new
//!   values become visible only at the next round.
//! * [`ExecutionMode::Asynchronous`] — Gauss-Seidel-style single shared
//!   array; every store is immediately visible (and immediately
//!   invalidates the cache line for any other thread reading it).
//! * [`ExecutionMode::Delayed`]`(δ)` — **the contribution**: thread-local
//!   aligned buffers of δ elements, flushed to the shared array when full
//!   or at end of the thread's range. Coalesces invalidation-causing
//!   writes while still propagating values within a round.
//!
//! Two executors consume the same [`VertexProgram`]s:
//! [`native::run`] uses real OS threads (correct parallel library);
//! [`sim::run`] is a deterministic multicore-with-caches simulator that
//! reproduces the paper's contention measurements on any host
//! (DESIGN.md §3 explains the substitution).
//!
//! Orthogonal to the mode, [`SchedulePolicy`] decides *which vertices*
//! a round touches: the paper's dense sweep, a frontier of activated
//! vertices, or an adaptive dense↔sparse hybrid (DESIGN.md §4).
//!
//! A fourth dimension is *how many queries* one sweep answers:
//! [`lanes`] packs k independent queries as interleaved value lanes per
//! vertex, so each neighbor read and each delay-buffer flush is
//! amortized across all k (DESIGN.md §8). Programs opt in by reporting
//! [`VertexProgram::lanes`] > 1; finished queries drop out of the sweep
//! via per-lane convergence.

pub mod controller;
pub mod convergence;
pub mod delay_buffer;
pub mod kernels;
pub mod lanes;
pub mod native;
pub mod program;
pub mod schedule;
pub mod shared;
pub mod sim;
pub mod stats;
pub mod steal;

pub use lanes::LaneReader;
pub use program::{ValueReader, VertexProgram};
pub use schedule::SchedulePolicy;
pub use stats::{RoundStats, RunResult};

use crate::partition::PartitionMap;

/// How updates propagate between threads. δ is in 32-bit elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Double-buffered: visibility deferred to the next round.
    Synchronous,
    /// In-place: every write immediately visible.
    Asynchronous,
    /// Buffer up to δ elements per thread before publishing.
    /// `Delayed(0)` behaves exactly like `Asynchronous`;
    /// `Delayed(≥ thread range)` approaches `Synchronous`.
    Delayed(usize),
    /// Online δ: every worker owns a [`controller::DeltaController`] that
    /// resizes its delay buffer between rounds from flush-contention,
    /// update-density, and residual telemetry, seeded by the §IV-C
    /// locality gate (the offline [`crate::coordinator::autotune`] rule).
    Adaptive,
}

impl ExecutionMode {
    /// Canonical short label for reports ("sync", "async", "d256",
    /// "adaptive").
    pub fn label(&self) -> String {
        match self {
            ExecutionMode::Synchronous => "sync".into(),
            ExecutionMode::Asynchronous => "async".into(),
            ExecutionMode::Delayed(d) => format!("d{d}"),
            ExecutionMode::Adaptive => "adaptive".into(),
        }
    }

    /// Parse labels produced by [`Self::label`] (case-insensitive).
    /// `None` means the label is not one of `sync | async | dN |
    /// adaptive`; CLI call sites must surface that explicitly rather
    /// than fall back silently.
    pub fn from_label(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sync" => Some(ExecutionMode::Synchronous),
            "async" => Some(ExecutionMode::Asynchronous),
            "adaptive" => Some(ExecutionMode::Adaptive),
            other => other
                .strip_prefix('d')
                // All-digits only: `usize::from_str` would also accept a
                // leading '+', which `label()` never emits.
                .filter(|digits| !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()))
                .and_then(|digits| digits.parse().ok())
                .map(ExecutionMode::Delayed),
        }
    }
}

/// Warm-start seed for incremental recomputation after graph mutations
/// (DESIGN.md §10): start from a previous run's values instead of the
/// program's `init`, with the round-0 frontier restricted to the
/// mutation-touched `dirty` set (under sparse schedules; a dense
/// schedule still sweeps everything but converges from the warm values).
///
/// Build one with [`RunResult::resume_from`] — or the algorithm-level
/// helpers ([`crate::algorithms::sssp::resume_seed`],
/// [`crate::algorithms::pagerank::resume_seed`]), which also apply the
/// algorithm's reset rule so the warm values are a *safe* starting
/// point on the mutated graph.
#[derive(Debug, Clone)]
pub struct ResumeSeed {
    /// Previous per-vertex values (raw bits): `n` elements for
    /// single-lane programs, `n × lanes` vertex-major lane groups for
    /// batched ones.
    pub values: Vec<u32>,
    /// Vertices whose inputs may have changed — the round-0 frontier.
    /// Sorted and deduplicated.
    pub dirty: Vec<crate::graph::VertexId>,
}

/// Which partitioner assigns vertices to threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionStrategy {
    /// The paper's contiguous in-degree-balanced blocks.
    #[default]
    BlockedByDegree,
    /// Ablation: equal vertex counts.
    EqualVertex,
}

/// Engine configuration shared by both executors.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Number of (real or simulated) worker threads.
    pub threads: usize,
    pub mode: ExecutionMode,
    pub partition: PartitionStrategy,
    /// Which vertices a round touches (dense sweep vs frontier-driven).
    pub schedule: SchedulePolicy,
    /// §III-C variant: serve reads of not-yet-flushed own values from the
    /// local delay buffer. The paper found this rarely faster; default off.
    pub local_reads: bool,
    /// Intra-round work stealing: partitions split into cache-line-aligned
    /// chunks; a worker drains its own chunks first, then steals trailing
    /// chunks from the most loaded victim (see [`steal`]). Default off —
    /// the paper's static schedule.
    pub stealing: bool,
    /// Atomics-light asynchronous sweeps (the non-blocking-PageRank
    /// scheme, PAPERS.md): results for vertices the sweeping thread
    /// *owns* are published with one plain Relaxed store per group — no
    /// CAS, no RMW, no per-element buffer bookkeeping — while writes
    /// landing outside the own range (stolen chunks) route through a
    /// one-line delay buffer. Requires `Asynchronous` mode (the native
    /// executor asserts this). CLI: `--mode async --no-atomics`.
    pub no_atomics: bool,
    /// Software-prefetch look-ahead distance for CSR gather loops, in
    /// neighbors: while consuming neighbor `i` the reader is hinted
    /// about neighbor `i + prefetch`'s lane group. `0` (default)
    /// disables hinting. Results are distance-invariant — a prefetch is
    /// a hint — which the differential suite asserts.
    pub prefetch: usize,
    /// NUMA-aware placement (DESIGN.md §12): partition bounds are
    /// rounded to whole value lines, the native executor pins each
    /// worker to the CPUs of the socket that owns its partition and
    /// first-touches the partition's value lines and delay buffers from
    /// that worker, and the sim charges remote-socket DRAM fills
    /// through [`sim::cache::LineTable`] line homes. Graceful no-op
    /// when the host exposes no topology (pinning fails silently, and a
    /// single-node machine leaves placement unchanged). Default off —
    /// byte-identical behavior to before this field existed.
    pub numa: bool,
    /// Safety valve: abort after this many rounds.
    pub max_rounds: usize,
    /// Warm-start seed: initialize values (and, under sparse schedules,
    /// the round-0 frontier) from a previous run instead of
    /// `VertexProgram::init`. `None` (default) is a cold run —
    /// byte-identical behavior to before this field existed. For
    /// multi-lane programs the seed must carry `n × lanes` elements in
    /// the vertex-major lane-group layout (the sharded round driver
    /// uses this to mirror remote shards' lane groups between rounds).
    pub resume: Option<std::sync::Arc<ResumeSeed>>,
    /// Sweep only this vertex range (`None` = the whole graph,
    /// byte-identical behavior to before this field existed). The value
    /// arrays stay full-length — vertices outside the range keep their
    /// initial (or resumed) values and are readable as neighbors — but
    /// partitioning, sweeping, and stealing all happen inside the
    /// range. This is how a shard executes one global round over its
    /// owned partition while treating the rest of the value array as a
    /// mirror of remote shards (see [`crate::shard`]). Native executor
    /// only; the sim asserts it off.
    pub restrict: Option<std::ops::Range<crate::graph::VertexId>>,
}

impl EngineConfig {
    /// Config with defaults (blocked partitioning, dense sweeps, global
    /// reads).
    pub fn new(threads: usize, mode: ExecutionMode) -> Self {
        Self {
            threads,
            mode,
            partition: PartitionStrategy::default(),
            schedule: SchedulePolicy::default(),
            local_reads: false,
            stealing: false,
            no_atomics: false,
            prefetch: 0,
            numa: false,
            max_rounds: 10_000,
            resume: None,
            restrict: None,
        }
    }

    /// Builder-style: enable local reads.
    pub fn with_local_reads(mut self) -> Self {
        self.local_reads = true;
        self
    }

    /// Builder-style: enable intra-round work stealing.
    pub fn with_stealing(mut self) -> Self {
        self.stealing = true;
        self
    }

    /// Builder-style: choose partitioner.
    pub fn with_partition(mut self, p: PartitionStrategy) -> Self {
        self.partition = p;
        self
    }

    /// Builder-style: choose the round schedule.
    pub fn with_schedule(mut self, s: SchedulePolicy) -> Self {
        self.schedule = s;
        self
    }

    /// Builder-style: enable the atomics-light async write path.
    pub fn with_no_atomics(mut self) -> Self {
        self.no_atomics = true;
        self
    }

    /// Builder-style: set the software-prefetch look-ahead distance
    /// (in neighbors; 0 disables).
    pub fn with_prefetch(mut self, dist: usize) -> Self {
        self.prefetch = dist;
        self
    }

    /// Builder-style: warm-start from a previous run's values + dirty
    /// frontier (incremental recomputation after graph mutations).
    pub fn with_resume(mut self, seed: ResumeSeed) -> Self {
        self.resume = Some(std::sync::Arc::new(seed));
        self
    }

    /// Builder-style: sweep only `range` (sharded execution; see the
    /// [`Self::restrict`] field docs).
    pub fn with_restrict(mut self, range: std::ops::Range<crate::graph::VertexId>) -> Self {
        self.restrict = Some(range);
        self
    }

    /// Builder-style: enable NUMA-aware placement (socket-pinned
    /// first-touch in the native executor, remote-socket line costs in
    /// the sim).
    pub fn with_numa(mut self) -> Self {
        self.numa = true;
        self
    }

    /// Resolve the partition map for a graph (any
    /// [`crate::graph::GraphStore`] backend — overlays are partitioned
    /// by their current degrees). Under [`Self::numa`] interior bounds
    /// are rounded to whole value lines so no cache line of the value
    /// array spans two partitions — the precondition for per-partition
    /// first-touch page placement (and it holds for every lane count,
    /// since a group boundary at a line-multiple vertex is itself
    /// line-aligned).
    pub fn partition_map<G: crate::graph::GraphStore>(&self, g: &G) -> PartitionMap {
        if let Some(r) = &self.restrict {
            assert!(r.end as usize <= g.num_vertices(), "restrict range {r:?} exceeds {} vertices", g.num_vertices());
            // Restricted runs partition only the swept window. Interior
            // bounds are not line-rounded here even under `numa`: the
            // cross-shard cut (the window itself) is what must be
            // line-exact, and `crate::shard::shard_partition` aligns it.
            return match self.partition {
                PartitionStrategy::BlockedByDegree => crate::partition::blocked::partition_range(g, r.clone(), self.threads),
                PartitionStrategy::EqualVertex => crate::partition::equal_vertex::partition_range(r.clone(), self.threads),
            };
        }
        let pm = match self.partition {
            PartitionStrategy::BlockedByDegree => crate::partition::blocked::partition(g, self.threads),
            PartitionStrategy::EqualVertex => crate::partition::equal_vertex::partition(g, self.threads),
        };
        if self.numa {
            crate::partition::numa::line_align(pm, g.num_vertices())
        } else {
            pm
        }
    }

    /// Effective δ for a thread range of `len` elements: `Synchronous`
    /// buffers everything, `Asynchronous` nothing. For `Adaptive` this is
    /// the controller's *upper bound* (`len`); the actual per-round δ is
    /// chosen at runtime by [`controller::DeltaController`].
    pub fn effective_delta(&self, len: usize) -> usize {
        match self.mode {
            ExecutionMode::Synchronous | ExecutionMode::Adaptive => len,
            ExecutionMode::Asynchronous => 0,
            ExecutionMode::Delayed(d) => d.min(len),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_labels_roundtrip() {
        for m in [
            ExecutionMode::Synchronous,
            ExecutionMode::Asynchronous,
            ExecutionMode::Delayed(256),
            ExecutionMode::Delayed(0),
            ExecutionMode::Adaptive,
        ] {
            assert_eq!(ExecutionMode::from_label(&m.label()), Some(m));
        }
        assert_eq!(ExecutionMode::from_label("ADAPTIVE"), Some(ExecutionMode::Adaptive), "case-insensitive");
        assert_eq!(ExecutionMode::from_label(" d64 "), Some(ExecutionMode::Delayed(64)), "whitespace-tolerant");
        // Unknown labels must surface as None, never as a silent default.
        for bad in ["bogus", "d", "dxyz", "d-5", "d+5", "d 5", "delayed", ""] {
            assert_eq!(ExecutionMode::from_label(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn schedule_builder_and_default() {
        let c = EngineConfig::new(4, ExecutionMode::Asynchronous);
        assert_eq!(c.schedule, SchedulePolicy::Dense);
        let f = c.with_schedule(SchedulePolicy::Frontier);
        assert_eq!(f.schedule, SchedulePolicy::Frontier);
    }

    #[test]
    fn no_atomics_and_prefetch_builders_and_defaults() {
        let c = EngineConfig::new(4, ExecutionMode::Asynchronous);
        assert!(!c.no_atomics, "the paper's atomic-store sweep is the default");
        assert_eq!(c.prefetch, 0, "hinting is opt-in");
        let c = c.with_no_atomics().with_prefetch(8);
        assert!(c.no_atomics);
        assert_eq!(c.prefetch, 8);
    }

    #[test]
    fn stealing_builder_and_default() {
        let c = EngineConfig::new(4, ExecutionMode::Delayed(64));
        assert!(!c.stealing, "the paper's static schedule is the default");
        assert!(c.with_stealing().stealing);
    }

    #[test]
    fn effective_delta() {
        let c = EngineConfig::new(4, ExecutionMode::Delayed(100));
        assert_eq!(c.effective_delta(50), 50);
        assert_eq!(c.effective_delta(500), 100);
        let s = EngineConfig::new(4, ExecutionMode::Synchronous);
        assert_eq!(s.effective_delta(500), 500);
        let a = EngineConfig::new(4, ExecutionMode::Asynchronous);
        assert_eq!(a.effective_delta(500), 0);
        let ad = EngineConfig::new(4, ExecutionMode::Adaptive);
        assert_eq!(ad.effective_delta(500), 500, "adaptive reports its upper bound");
    }
}
