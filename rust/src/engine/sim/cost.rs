//! Latency cost model for the multicore simulator.
//!
//! Cycle counts are round numbers in line with published measurements for
//! the paper's two platforms (Haswell-EP and Cascade Lake-SP): L1 ≈ 4
//! cycles, shared LLC ≈ 40, a dirty line forwarded from another core on
//! the same socket ≈ 70, cross-socket forward ≈ 130, DRAM ≈ 150–200. The
//! *absolute* numbers matter little — every figure in the paper reports
//! ratios — but their ordering and rough magnitudes drive the same
//! trade-off the real machines exhibit: asynchronous stores turn other
//! threads' L1 hits into 70–130-cycle coherence misses.

/// Latencies (cycles) and per-operation compute costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// L1 hit (line already in this thread's cache, valid).
    pub l1: u64,
    /// Clean line obtained from LLC / another core's clean copy.
    pub llc: u64,
    /// Dirty line forwarded from a core on the same socket.
    pub remote_core: u64,
    /// Dirty line forwarded across the socket interconnect.
    pub remote_socket: u64,
    /// Cold miss to DRAM attached to the accessor's own socket.
    pub dram: u64,
    /// Cold miss served by the *other* socket's DRAM (the line's home
    /// node under first-touch placement is not the accessor's): the
    /// fill crosses the interconnect on top of the DRAM access. This is
    /// what `--numa` placement avoids for owner-partition traffic.
    pub remote_dram: u64,
    /// Fixed work per vertex update (loop overhead, convergence math).
    pub vertex_base: u64,
    /// ALU work per in-edge (multiply-add / min-plus).
    pub edge_compute: u64,
    /// Store into the thread-local delay buffer (always L1-resident).
    pub buffer_push: u64,
    /// Claiming a chunk from another partition's deque (a CAS on a
    /// contended shared line — roughly an LLC round trip). Charged once
    /// per stolen chunk; owner-side claims stay on an owned line and are
    /// folded into `vertex_base`.
    pub steal: u64,
    /// Reallocating a delay buffer when the adaptive controller resizes
    /// δ between rounds (an allocator round trip plus first-touch of the
    /// new lines). Charged to the resizing thread at its next round
    /// start.
    pub resize: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            l1: 4,
            llc: 40,
            remote_core: 70,
            remote_socket: 130,
            dram: 160,
            remote_dram: 240,
            vertex_base: 8,
            edge_compute: 2,
            buffer_push: 1,
            steal: 40,
            resize: 200,
        }
    }
}

/// A simulated machine: thread count, socket split, clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Machine {
    pub name: &'static str,
    /// Hardware threads available.
    pub threads: usize,
    /// Number of sockets (threads are split contiguously across them,
    /// mirroring the paper's pinning policy).
    pub sockets: usize,
    /// Core clock in Hz (converts cycles → seconds for Table I).
    pub clock_hz: f64,
    pub cost: CostModel,
}

impl Machine {
    /// Dual-socket Xeon E5-2667v3 (the paper's 32-thread Haswell).
    pub fn haswell() -> Self {
        Self { name: "haswell32", threads: 32, sockets: 2, clock_hz: 3.2e9, cost: CostModel::default() }
    }

    /// Dual-socket Xeon Platinum 8280 (the paper's 112-thread Cascade
    /// Lake). Slightly cheaper cross-socket than Haswell (UPI vs QPI).
    pub fn cascade_lake() -> Self {
        Self {
            name: "cascadelake112",
            threads: 112,
            sockets: 2,
            clock_hz: 2.7e9,
            cost: CostModel { remote_socket: 120, ..CostModel::default() },
        }
    }

    /// Which socket a thread lives on (contiguous split).
    #[inline]
    pub fn socket_of(&self, thread: usize, active_threads: usize) -> usize {
        // When running with fewer threads than the machine has, the
        // paper pins ≤half-complement runs to one socket.
        if active_threads * 2 <= self.threads {
            0
        } else {
            thread * self.sockets / active_threads
        }
    }

    /// Latency for pulling a dirty line from `from` as seen by `to`.
    #[inline]
    pub fn forward_cost(&self, from: usize, to: usize, active_threads: usize) -> u64 {
        if self.socket_of(from, active_threads) == self.socket_of(to, active_threads) {
            self.cost.remote_core
        } else {
            self.cost.remote_socket
        }
    }

    /// Machine with the same cost model but a different thread count
    /// (for thread-scaling sweeps).
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_sane() {
        let c = CostModel::default();
        assert!(c.l1 < c.llc && c.llc < c.remote_core);
        assert!(c.remote_core < c.remote_socket && c.remote_socket < c.dram);
        // A cross-socket DRAM fill stacks interconnect on top of the
        // memory access: strictly worse than local DRAM, and worse than
        // a cache-to-cache forward.
        assert!(c.dram < c.remote_dram && c.remote_socket < c.remote_dram);
        assert!(c.buffer_push <= c.l1);
        // Stealing pays a contended CAS: pricier than local work, cheaper
        // than a cross-socket forward.
        assert!(c.steal >= c.llc && c.steal < c.remote_socket);
        // A resize is an allocator round trip: pricier than any single
        // memory access, far below a round's work.
        assert!(c.resize >= c.dram);
    }

    #[test]
    fn socket_split() {
        let m = Machine::haswell();
        // Full complement: half the threads on each socket.
        assert_eq!(m.socket_of(0, 32), 0);
        assert_eq!(m.socket_of(15, 32), 0);
        assert_eq!(m.socket_of(16, 32), 1);
        assert_eq!(m.socket_of(31, 32), 1);
        // Half complement or less: pinned to socket 0.
        assert_eq!(m.socket_of(15, 16), 0);
        assert_eq!(m.socket_of(7, 8), 0);
    }

    #[test]
    fn forward_costs() {
        let m = Machine::haswell();
        assert_eq!(m.forward_cost(0, 1, 32), m.cost.remote_core);
        assert_eq!(m.forward_cost(0, 31, 32), m.cost.remote_socket);
    }

    #[test]
    fn presets() {
        assert_eq!(Machine::haswell().threads, 32);
        assert_eq!(Machine::cascade_lake().threads, 112);
        assert!(Machine::cascade_lake().clock_hz < Machine::haswell().clock_hz);
    }
}
