//! Deterministic multicore simulator.
//!
//! Executes a [`VertexProgram`] over T *logical* threads with a
//! line-granularity coherence model ([`cache`]) and a latency cost model
//! ([`cost`]), producing both the algorithm result and the contention
//! metrics ([`trace`]) the paper measures on real hardware.
//!
//! Why it exists: the paper's phenomena are cache-line invalidations on
//! 32–112-thread machines; this host may have one core. The simulator
//! reproduces those phenomena *deterministically* — same seed, same
//! graph, same cycle counts — on any host (DESIGN.md §3).
//!
//! Execution model: threads interleave at vertex-update granularity,
//! ordered by per-thread cycle clocks (the thread with the lowest clock
//! executes next; ties break by thread id). Every read/write of a shared
//! value array passes through the line table, which charges latencies
//! and records invalidations. Rounds are barrier-separated exactly like
//! [`super::native`].
//!
//! Scheduling mirrors the native executor: under
//! [`SchedulePolicy::Frontier`]/[`SchedulePolicy::Adaptive`] a round
//! sweeps only the vertices activated last round, so cache/contention
//! measurements cover the sparse regime too. Frontier bitmap stores are
//! charged at the delay-buffer push rate (`cost.buffer_push`): the bitmap
//! is thread-hot and tiny (1 bit/vertex), below line-table granularity.
//!
//! Work stealing mirrors `engine::steal` the same way: partitions split
//! into the same cache-line-aligned chunks, owners drain their own chunks
//! front-to-back, and a thread that runs dry steals the trailing chunk of
//! the most loaded victim. Claims resolve deterministically in clock
//! order (ties by thread id, like every other simulator event) and each
//! stolen chunk is charged `cost.steal` cycles — a contended CAS — so
//! contention measurements stay meaningful under dynamic scheduling.

pub mod cache;
pub mod cost;
pub mod trace;

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::ops::Range;

use super::controller::{self, DeltaController, Telemetry};
use super::delay_buffer::round_delta;
use super::lanes;
use super::program::{ValueReader, VertexProgram};
use super::schedule::{bits, SchedulePolicy, ADAPTIVE_SPARSE_DIVISOR};
use super::stats::{RoundStats, RunResult};
use super::steal::DEFAULT_CHUNK;
use super::{EngineConfig, ExecutionMode};
use crate::graph::{properties, GraphStore, VertexId};
use crate::partition::{chunk_bounds, PartitionMap};
use cache::LineTable;
use cost::Machine;
use trace::SimMetrics;

/// Result of a simulated run: the algorithm output plus coherence metrics.
#[derive(Debug, Clone)]
pub struct SimRun {
    pub result: RunResult,
    pub metrics: SimMetrics,
}

impl SimRun {
    /// Total simulated cycles.
    pub fn total_cycles(&self) -> u64 {
        self.metrics.round_cycles.iter().sum()
    }
}

/// Thread-local staged updates (simulator twin of
/// [`super::delay_buffer::DelayBuffer`], with costs charged explicitly).
struct SimBuffer {
    data: Vec<u32>,
    cap: usize,
    base: VertexId,
}

impl SimBuffer {
    fn new(delta: usize) -> Self {
        let cap = round_delta(delta);
        Self { data: Vec::with_capacity(cap), cap, base: 0 }
    }

    fn begin(&mut self, start: VertexId) {
        debug_assert!(self.data.is_empty());
        self.base = start;
    }

    #[inline]
    fn pending(&self, v: VertexId) -> Option<u32> {
        let off = v.checked_sub(self.base)? as usize;
        self.data.get(off).copied()
    }
}

/// Per-thread, per-round flush accounting — the simulator twin of the
/// native `DelayBuffer` counters, feeding both [`RoundStats::flushes`]
/// and the adaptive controller's telemetry.
#[derive(Debug, Default, Clone, Copy)]
struct FlushAcct {
    flushes: u64,
    /// Cache lines the flushes dirtied.
    lines: u64,
    /// Cycles charged for the flushes.
    cycles: u64,
}

/// One stealable unit of a round's sweep: a dense vertex span or (on
/// sparse rounds) the active vertices inside one chunk's span.
enum SimChunk {
    Span(Range<VertexId>),
    List(Vec<VertexId>),
}

impl SimChunk {
    fn len(&self) -> usize {
        match self {
            SimChunk::Span(r) => r.len(),
            SimChunk::List(l) => l.len(),
        }
    }

    fn get(&self, i: usize) -> VertexId {
        match self {
            SimChunk::Span(r) => r.start + i as VertexId,
            SimChunk::List(l) => l[i],
        }
    }
}

/// Deterministic twin of [`super::steal::StealGrid`]: the same
/// cache-line-aligned chunks per partition, the same claim protocol
/// (owners from the front, thieves take the trailing chunk of the most
/// loaded victim, ties to the lowest partition id) — but claims resolve
/// in simulated-clock order instead of hardware CAS order, so runs are
/// reproducible. Sparse rounds pre-slice each partition's worklist at the
/// chunk boundaries and drop empty chunks (claiming an empty chunk does
/// no observable work in the native executor either).
struct WorkSource {
    chunks: Vec<Vec<SimChunk>>,
    /// Per-partition claim window: `head..tail` are unclaimed.
    head: Vec<usize>,
    tail: Vec<usize>,
    /// Per-thread current chunk: (owning partition, chunk index, next
    /// position within the chunk).
    cur: Vec<Option<(usize, usize, usize)>>,
    /// Chunks executed away from their owner this round.
    steals: u64,
}

impl WorkSource {
    fn new(pm: &PartitionMap, lists: Option<&[Vec<VertexId>]>, chunk: usize) -> Self {
        let t_count = pm.num_parts();
        let mut chunks: Vec<Vec<SimChunk>> = Vec::with_capacity(t_count);
        for t in 0..t_count {
            let bounds = chunk_bounds(&pm.range(t), chunk);
            let mut cs: Vec<SimChunk> = Vec::new();
            match lists {
                None => {
                    for w in bounds.windows(2) {
                        cs.push(SimChunk::Span(w[0]..w[1]));
                    }
                }
                Some(ls) => {
                    // `ls[t]` is sorted and confined to the partition, so
                    // slicing at the ascending chunk boundaries partitions
                    // it exactly.
                    let list = &ls[t];
                    let mut i = 0usize;
                    for w in bounds.windows(2) {
                        let start = i;
                        while i < list.len() && list[i] < w[1] {
                            i += 1;
                        }
                        if i > start {
                            cs.push(SimChunk::List(list[start..i].to_vec()));
                        }
                    }
                }
            }
            chunks.push(cs);
        }
        let tail: Vec<usize> = chunks.iter().map(Vec::len).collect();
        Self { head: vec![0; t_count], tail, cur: vec![None; t_count], chunks, steals: 0 }
    }

    /// Claim-and-return thread `t`'s next vertex; the flag is true when
    /// this claim stole a chunk (the caller charges `cost.steal`).
    fn next(&mut self, t: usize) -> Option<(VertexId, bool)> {
        if let Some((p, c, pos)) = self.cur[t] {
            if pos < self.chunks[p][c].len() {
                self.cur[t] = Some((p, c, pos + 1));
                return Some((self.chunks[p][c].get(pos), false));
            }
        }
        if self.head[t] < self.tail[t] {
            let c = self.head[t];
            self.head[t] += 1;
            self.cur[t] = Some((t, c, 1));
            return Some((self.chunks[t][c].get(0), false));
        }
        let mut best: Option<(usize, usize)> = None;
        for i in 0..self.chunks.len() {
            if i == t {
                continue;
            }
            let r = self.tail[i] - self.head[i];
            if r == 0 {
                continue;
            }
            match best {
                Some((br, _)) if br >= r => {}
                _ => best = Some((r, i)),
            }
        }
        let (_, victim) = best?;
        self.tail[victim] -= 1;
        let c = self.tail[victim];
        self.steals += 1;
        self.cur[t] = Some((victim, c, 1));
        Some((self.chunks[victim][c].get(0), true))
    }

    /// True when `t` has nothing left to execute: current chunk drained,
    /// own queue empty, and nothing left to steal.
    fn exhausted(&self, t: usize) -> bool {
        if let Some((p, c, pos)) = self.cur[t] {
            if pos < self.chunks[p][c].len() {
                return false;
            }
        }
        if self.head[t] < self.tail[t] {
            return false;
        }
        (0..self.chunks.len()).all(|i| i == t || self.head[i] >= self.tail[i])
    }
}

/// Reader charging cache costs for every access.
struct SimReader<'a> {
    t: usize,
    values: &'a [u32],
    table: &'a mut LineTable,
    metrics: &'a mut SimMetrics,
    /// Flat vertex→owner map (precomputed; §Perf: a binary search per
    /// read through `PartitionMap::owner` cost ~15% of sim throughput).
    owners: &'a [u16],
    machine: &'a Machine,
    active: usize,
    /// Cycles accumulated by this vertex update.
    cost: u64,
    /// §III-C local reads: the thread's own unflushed values.
    buf: Option<&'a SimBuffer>,
}

impl ValueReader for SimReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        if let Some(b) = self.buf {
            if let Some(bits) = b.pending(v) {
                self.cost += self.machine.cost.buffer_push + self.machine.cost.edge_compute;
                return bits;
            }
        }
        let a = self.table.read(self.t, v as usize, self.machine, self.active);
        self.metrics.on_read(&a);
        self.metrics.count_read(self.t, self.owners[v as usize] as usize);
        self.cost += a.cycles + self.machine.cost.edge_compute;
        self.values[v as usize]
    }

    /// Prefetch is a pure hint: it moves no architectural state and is
    /// deliberately *not charged* — so sweeps at any prefetch distance
    /// (and the scalar vs SIMD kernels, which only differ after the
    /// gather) stay bit-comparable with the charging model unchanged.
    #[inline]
    fn prefetch(&mut self, _v: VertexId) {}
}

/// Lane-group reader: one coherence access per neighbor group (a group
/// never straddles a line) plus per-live-lane ALU work — the charging
/// model behind the batched throughput win: k queries share each line
/// transfer.
struct SimLaneReader<'a> {
    t: usize,
    values: &'a [u32],
    table: &'a mut LineTable,
    metrics: &'a mut SimMetrics,
    owners: &'a [u16],
    machine: &'a Machine,
    active: usize,
    cost: u64,
    /// Lanes per group.
    lanes: usize,
    /// Live lanes this round (ALU work scales with these only).
    live_n: u64,
    /// §III-C local reads: the thread's own unflushed values.
    buf: Option<&'a SimBuffer>,
}

impl lanes::LaneReader for SimLaneReader<'_> {
    #[inline]
    fn read_group(&mut self, v: VertexId, out: &mut [u32]) {
        let e = v as usize * self.lanes;
        if let Some(b) = self.buf {
            // Staged runs advance in whole lane groups, so pending
            // membership is all-or-nothing per group.
            if b.pending(e as VertexId).is_some() {
                for (l, o) in out.iter_mut().enumerate() {
                    *o = b.pending((e + l) as VertexId).expect("runs advance in whole lane groups");
                }
                self.cost += self.machine.cost.buffer_push + self.live_n * self.machine.cost.edge_compute;
                return;
            }
        }
        let a = self.table.read(self.t, e, self.machine, self.active);
        self.metrics.on_read(&a);
        self.metrics.count_read(self.t, self.owners[v as usize] as usize);
        self.cost += a.cycles + self.live_n * self.machine.cost.edge_compute;
        out.copy_from_slice(&self.values[e..e + self.lanes]);
    }

    /// Uncharged no-op, same argument as [`SimReader::prefetch`]: one
    /// group access per neighbor is the charging model either way.
    #[inline]
    fn prefetch_group(&mut self, _v: VertexId) {}
}

/// Simulate `prog` on `g` with `cfg.threads` logical threads on `machine`.
///
/// Generic over [`GraphStore`], monomorphized per backend exactly like
/// [`super::native::run`]: a static-CSR simulation charges precisely the
/// accesses the pre-trait simulator charged, so sim metrics are
/// bit-identical; overlay backends replay the same machinery over their
/// composed rows.
pub fn run<G: GraphStore, P: VertexProgram>(g: &G, prog: &P, cfg: &EngineConfig, machine: &Machine) -> SimRun {
    let n = g.num_vertices();
    assert!(
        cfg.restrict.is_none(),
        "the simulator models whole-graph runs; restricted (sharded) sweeps are native-executor only"
    );
    let pm = cfg.partition_map(g);
    let t_count = pm.num_parts();
    assert!(t_count <= cache::MAX_THREADS, "simulator supports ≤{} threads", cache::MAX_THREADS);
    let sync_mode = matches!(cfg.mode, ExecutionMode::Synchronous);
    // The atomics-light variant is charged as plain async: for owned
    // vertices both publish one immediate store per group (identical
    // line traffic — the native win is dropped per-element bookkeeping,
    // not fewer line transfers), and its stolen-chunk line coalescing
    // is a native-executor micro-optimization below this model's
    // resolution. Asserting the mode keeps the two executors' accepted
    // configs identical.
    if cfg.no_atomics {
        assert!(
            matches!(cfg.mode, ExecutionMode::Asynchronous),
            "no_atomics is an asynchronous-mode variant (got {:?})",
            cfg.mode
        );
    }
    let conditional = prog.conditional_writes();
    let frontier_on = cfg.schedule != SchedulePolicy::Dense;
    if frontier_on {
        g.ensure_out_edges();
    }
    // Batched multi-query lanes: vertex v's lane group occupies elements
    // v*lane_n .. v*lane_n+lane_n; δ, the line tables, and the staged
    // buffers all keep element units (see `engine::lanes`).
    let lane_n = prog.lanes();
    assert!(
        lanes::valid_lane_count(lane_n),
        "program reports {lane_n} lanes; lane counts must divide a cache line"
    );
    // Element indices (v·lanes + l) ride in VertexId, so the widened
    // value space must still fit the u32 id range.
    assert!(n * lane_n <= u32::MAX as usize, "{n} vertices x {lane_n} lanes exceeds the u32 element space");
    let multi = lane_n > 1;

    // Front/back arrays with their own coherence tables. Async/delayed
    // use only the front pair.
    let mut values: Vec<u32> = match &cfg.resume {
        // Warm start: previous run's values instead of the cold init
        // (incremental recomputation, DESIGN.md §10) — mirrors the
        // native executor exactly.
        Some(seed) => {
            assert_eq!(lane_n, 1, "resume seeds are single-lane; lane groups interleave k queries");
            assert_eq!(seed.values.len(), n, "resume seed has {} values for {n} vertices", seed.values.len());
            assert!(
                seed.dirty.iter().all(|&v| (v as usize) < n),
                "resume dirty set contains out-of-range vertices"
            );
            seed.values.clone()
        }
        None => {
            let mut values = Vec::with_capacity(n * lane_n);
            for v in 0..n as VertexId {
                for l in 0..lane_n {
                    values.push(prog.init_lane(v, l));
                }
            }
            values
        }
    };
    let mut back = values.clone();
    let mut table = LineTable::new(n * lane_n);
    let mut table_back = LineTable::new(n * lane_n);

    // Adaptive mode: one deterministic controller per logical thread,
    // seeded exactly like the native executor (§IV-C locality gate over
    // the offline rule). All of its telemetry below is cycle-exact, so
    // the per-round δ trace is bit-identical across repeated runs.
    let adaptive = matches!(cfg.mode, ExecutionMode::Adaptive);
    let mut controllers: Vec<DeltaController> = if adaptive {
        let locality = properties::diagonal_locality(g, t_count.max(2));
        (0..t_count)
            .map(|t| {
                let max = round_delta((if cfg.stealing { n } else { pm.len(t) }) * lane_n);
                DeltaController::new(controller::seed_delta(locality, pm.len(t) * lane_n, max), max)
            })
            .collect()
    } else {
        Vec::new()
    };

    // Stealing can hand a thread chunks anywhere in the graph, so the
    // delayed-mode buffer caps against n instead of the own range (sync
    // mode never stages — the double buffer is the delay).
    let mut buffers: Vec<SimBuffer> = (0..t_count)
        .map(|t| {
            let cap = if sync_mode {
                0
            } else if adaptive {
                controllers[t].delta()
            } else if cfg.stealing {
                cfg.effective_delta(n * lane_n)
            } else {
                cfg.effective_delta(pm.len(t) * lane_n)
            };
            SimBuffer::new(cap)
        })
        .collect();

    // Flat vertex→owner table: O(1) per read instead of a binary search
    // (see SimReader.owners).
    let mut owners = vec![0u16; n];
    for t in 0..t_count {
        for v in pm.range(t) {
            owners[v as usize] = t as u16;
        }
    }

    // NUMA mirror of the native first-touch placement: with `--numa` on
    // a multi-socket machine every value line's home node is the socket
    // of the thread owning the line's first element (the partitions are
    // line-aligned, see `partition::numa::line_align`, so a line has
    // exactly one owner). Cold fills from the other socket then cost
    // `remote_dram`. Without the flag — or on one socket — the tables
    // keep `None` homes and the simulation is bit-identical to before.
    if cfg.numa && machine.sockets > 1 {
        let homes: Vec<u8> = (0..table.num_lines())
            .map(|li| {
                let v = (li * crate::VALUES_PER_LINE / lane_n).min(n - 1);
                machine.socket_of(owners[v] as usize, t_count) as u8
            })
            .collect();
        table.set_homes(homes.clone());
        table_back.set_homes(homes);
    }

    let mut metrics = SimMetrics::new(t_count);
    let mut rounds: Vec<RoundStats> = Vec::new();
    let mut converged = false;
    let mut clock_base = 0u64;

    // Frontier state: `cur` is consumed this round, activations land in
    // `nxt` (swapped at round end). `prev_lists` is last round's sweep
    // (None = dense), needed by the sync-mode copy-down.
    let mut cur = bits::words_for(n);
    let mut nxt = bits::words_for(n);
    // Round 0 is dense on cold runs; resumed sparse schedules start it
    // from the seeded dirty frontier instead (the same rule the native
    // executor applies, so resumed sim traces mirror native behavior).
    let mut sparse = false;
    if let Some(seed) = &cfg.resume {
        if frontier_on {
            sparse = match cfg.schedule {
                SchedulePolicy::Frontier => true,
                SchedulePolicy::Adaptive => seed.dirty.len() * ADAPTIVE_SPARSE_DIVISOR < n,
                SchedulePolicy::Dense => false,
            };
            if sparse {
                for &v in &seed.dirty {
                    bits::set(&mut cur, v);
                }
            }
        }
    }
    let mut prev_lists: Option<Vec<Vec<VertexId>>> = None;
    // Adaptive bookkeeping: the allocator cost of a between-round resize
    // lands at the resizing thread's next round start, and the residual
    // ratio needs the previous round's summed delta.
    let mut resize_carry = vec![0u64; t_count];
    let mut prev_residual = f64::INFINITY;
    // Batched runs: lanes not yet converged (per-lane drop-out).
    let mut live_mask = lanes::full_mask(lane_n);

    while rounds.len() < cfg.max_rounds {
        let round_start = clock_base;
        let mut clocks: Vec<u64> = (0..t_count).map(|t| clock_base + std::mem::take(&mut resize_carry[t])).collect();
        let mut deltas = vec![0.0f64; t_count];
        let mut facct = vec![FlushAcct::default(); t_count];
        // Vertices whose stored value changed this round — the adaptive
        // controller's update-density signal.
        let mut changed = 0u64;
        // This round's live lanes and per-(thread, lane) residual sums
        // (per-thread accumulation then a fixed-order cross-thread sum,
        // exactly like the native executor, so lane residuals — and
        // therefore per-lane convergence rounds — are bit-identical to
        // an independent single-query run's).
        let live = live_mask;
        let live_n = u64::from(live.count_ones());
        let mut lane_sums_t = vec![0.0f64; t_count * lane_n];

        // Materialize per-thread worklists for sparse rounds (dense
        // rounds iterate partition ranges directly, as before).
        let lists: Option<Vec<Vec<VertexId>>> = if sparse {
            let mut ls: Vec<Vec<VertexId>> = vec![Vec::new(); t_count];
            for (t, l) in ls.iter_mut().enumerate() {
                bits::for_each_in(&cur, pm.range(t), |v| l.push(v));
            }
            Some(ls)
        } else {
            None
        };

        if sync_mode && sparse {
            // Copy-down: vertices swept last round but skipped this round
            // have their fresh value only in `values` (the read buffer);
            // mirror it into `back` so the double buffers stay
            // interchangeable. Charged to the owner as a back-array store.
            let mut copy_down = |v: VertexId,
                                 back: &mut [u32],
                                 table_back: &mut LineTable,
                                 metrics: &mut SimMetrics,
                                 clocks: &mut [u64]| {
                if !bits::get(&cur, v) {
                    let t = owners[v as usize] as usize;
                    // Whole lane group (the scalar store for lane_n = 1);
                    // one back-array write — a group shares one line.
                    let e = v as usize * lane_n;
                    let w = table_back.write(t, e, machine, t_count);
                    metrics.on_write(&w);
                    clocks[t] += w.cycles + machine.cost.buffer_push;
                    back[e..e + lane_n].copy_from_slice(&values[e..e + lane_n]);
                }
            };
            match &prev_lists {
                None => {
                    for v in 0..n as VertexId {
                        copy_down(v, &mut back, &mut table_back, &mut metrics, &mut clocks);
                    }
                }
                Some(ls) => {
                    for l in ls {
                        for &v in l {
                            copy_down(v, &mut back, &mut table_back, &mut metrics, &mut clocks);
                        }
                    }
                }
            }
        }

        let len_of = |t: usize| -> usize {
            match &lists {
                Some(ls) => ls[t].len(),
                None => pm.len(t),
            }
        };
        let total_active: u64 = (0..t_count).map(|t| len_of(t) as u64).sum();
        let mut idx = vec![0usize; t_count];
        // Chunked claim structure mirroring the native StealGrid; the
        // static path below keeps the plain per-partition index sweep.
        let mut ws = cfg.stealing.then(|| WorkSource::new(&pm, lists.as_deref(), DEFAULT_CHUNK));

        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for t in 0..t_count {
            if !sync_mode {
                buffers[t].begin(lanes::group_base(pm.range(t).start, lane_n));
            }
            let has_work = match &ws {
                Some(w) => !w.exhausted(t),
                None => len_of(t) > 0,
            };
            if has_work {
                heap.push(Reverse((clocks[t], t)));
            }
        }

        while let Some(Reverse((clock, t))) = heap.pop() {
            // §Perf: batch-pop — keep running this thread while it stays
            // the global minimum. Ordering is identical to popping per
            // vertex (it would be re-popped immediately), but saves the
            // heap traffic that profiling showed at ~13% of sim time.
            let mut clock = clock;
            let next_key = heap.peek().map(|Reverse(k)| *k);
            loop {
                let (v, stole) = match ws.as_mut() {
                    Some(w) => match w.next(t) {
                        Some(claim) => claim,
                        None => {
                            // Everything was claimed since this thread last
                            // checked: it is done for the round.
                            if !sync_mode {
                                let buf = &mut buffers[t];
                                clocks[t] = clock;
                                clocks[t] += flush_buffer(
                                    t,
                                    buf,
                                    &mut values,
                                    &mut table,
                                    &mut metrics,
                                    machine,
                                    t_count,
                                    &mut facct[t],
                                );
                            }
                            break;
                        }
                    },
                    None => {
                        let v = match &lists {
                            Some(ls) => ls[t][idx[t]],
                            None => pm.range(t).start + idx[t] as VertexId,
                        };
                        (v, false)
                    }
                };
                let mut cost = machine.cost.vertex_base;
                if stole {
                    // The claim itself: a CAS on the victim's contended deque.
                    cost += machine.cost.steal;
                }

                // Outcome flags of this vertex update — any-live-lane
                // semantics for batched runs (set inside the lane arm;
                // by the scalar tail below otherwise).
                let mut changed_this = false;
                let mut activate_this = false;

                let (new, old) = if multi {
                    let e = v as usize * lane_n;
                    // One coherence read covers the whole own group (a
                    // group never straddles a cache line).
                    let old_a = table.read(t, e, machine, t_count);
                    metrics.on_read(&old_a);
                    cost += old_a.cycles;
                    let mut group = [0u32; lanes::MAX_LANES];
                    let gv = &mut group[..lane_n];
                    gv.copy_from_slice(&values[e..e + lane_n]);
                    let mut old_g = [0u32; lanes::MAX_LANES];
                    old_g[..lane_n].copy_from_slice(gv);
                    {
                        let mut rd = SimLaneReader {
                            t,
                            values: &values,
                            table: &mut table,
                            metrics: &mut metrics,
                            owners: &owners,
                            machine,
                            active: t_count,
                            cost: 0,
                            lanes: lane_n,
                            live_n,
                            buf: if !sync_mode && cfg.local_reads { Some(&buffers[t]) } else { None },
                        };
                        prog.update_lanes(v, &mut rd, gv, live);
                        cost += rd.cost;
                    }
                    let mut ch = false;
                    let mut act = false;
                    lanes::for_each_live(live, |l| {
                        let d = prog.lane_delta(l, old_g[l], gv[l]);
                        deltas[t] += d;
                        lane_sums_t[t * lane_n + l] += d;
                        ch |= gv[l] != old_g[l];
                        act |= prog.activates(old_g[l], gv[l]);
                    });
                    changed_this = ch;
                    activate_this = act;

                    if sync_mode {
                        // Sync carries every lane across the swap; the
                        // group shares one line, so one back-array write.
                        let w = table_back.write(t, e, machine, t_count);
                        metrics.on_write(&w);
                        cost += w.cycles;
                        back[e..e + lane_n].copy_from_slice(gv);
                    } else {
                        let buf = &mut buffers[t];
                        let eb = e as VertexId;
                        if (sparse || cfg.stealing) && buf.cap != 0 {
                            // Non-contiguous sweep: keep the staged run
                            // contiguous, exactly like the single-lane
                            // seek path (element units).
                            if buf.data.is_empty() {
                                buf.base = eb;
                            } else if buf.base + buf.data.len() as VertexId != eb {
                                cost += flush_buffer(
                                    t,
                                    buf,
                                    &mut values,
                                    &mut table,
                                    &mut metrics,
                                    machine,
                                    t_count,
                                    &mut facct[t],
                                );
                                buf.base = eb;
                            }
                        }
                        if buf.cap == 0 {
                            // Asynchronous: the whole group stores
                            // straight through (one line write).
                            if changed_this || !conditional {
                                let w = table.write(t, e, machine, t_count);
                                metrics.on_write(&w);
                                cost += w.cycles;
                                values[e..e + lane_n].copy_from_slice(gv);
                            }
                        } else if conditional && !changed_this {
                            // No live lane changed: publish pending and
                            // skip the whole group.
                            cost += flush_buffer(
                                t,
                                buf,
                                &mut values,
                                &mut table,
                                &mut metrics,
                                machine,
                                t_count,
                                &mut facct[t],
                            );
                            buf.base += lane_n as VertexId;
                        } else {
                            // Capacity is a whole number of lines and the
                            // lane count divides a line, so fullness only
                            // ever triggers at a group boundary: groups
                            // are never split across flushes.
                            if buf.data.len() == buf.cap {
                                cost += flush_buffer(
                                    t,
                                    buf,
                                    &mut values,
                                    &mut table,
                                    &mut metrics,
                                    machine,
                                    t_count,
                                    &mut facct[t],
                                );
                            }
                            buf.data.extend_from_slice(gv);
                            cost += lane_n as u64 * machine.cost.buffer_push;
                        }
                    }
                    (0, 0) // unused: the lane arm accumulated flags and deltas above
                } else if sync_mode {
                    // Read old + neighbors from front, write into back.
                    let old_a = table.read(t, v as usize, machine, t_count);
                    metrics.on_read(&old_a);
                    cost += old_a.cycles;
                    let old = values[v as usize];
                    let mut rd = SimReader {
                        t,
                        values: &values,
                        table: &mut table,
                        metrics: &mut metrics,
                        owners: &owners,
                        machine,
                        active: t_count,
                        cost: 0,
                        buf: None,
                    };
                    let new = prog.update(v, &mut rd);
                    cost += rd.cost;
                    let stored = if conditional && new == old { old } else { new };
                    let w = table_back.write(t, v as usize, machine, t_count);
                    metrics.on_write(&w);
                    cost += w.cycles;
                    back[v as usize] = stored;
                    (new, old)
                } else {
                    let old_a = table.read(t, v as usize, machine, t_count);
                    metrics.on_read(&old_a);
                    cost += old_a.cycles;
                    let old = values[v as usize];
                    let new = {
                        let mut rd = SimReader {
                            t,
                            values: &values,
                            table: &mut table,
                            metrics: &mut metrics,
                            owners: &owners,
                            machine,
                            active: t_count,
                            cost: 0,
                            buf: if cfg.local_reads { Some(&buffers[t]) } else { None },
                        };
                        let new = prog.update(v, &mut rd);
                        cost += rd.cost;
                        new
                    };
                    let buf = &mut buffers[t];
                    if (sparse || cfg.stealing) && buf.cap != 0 {
                        // Non-contiguous sweep (sparse gaps or a stolen
                        // chunk): keep the staged run contiguous — the
                        // generalized skip()/seek() path of the native
                        // DelayBuffer.
                        if buf.data.is_empty() {
                            buf.base = v;
                        } else if buf.base + buf.data.len() as VertexId != v {
                            cost += flush_buffer(
                                t,
                                buf,
                                &mut values,
                                &mut table,
                                &mut metrics,
                                machine,
                                t_count,
                                &mut facct[t],
                            );
                            buf.base = v;
                        }
                    }
                    if buf.cap == 0 {
                        // Asynchronous: store straight through.
                        if !(conditional && new == old) {
                            let w = table.write(t, v as usize, machine, t_count);
                            metrics.on_write(&w);
                            cost += w.cycles;
                            values[v as usize] = new;
                        }
                    } else if conditional && new == old {
                        // Publish pending, skip this slot.
                        cost += flush_buffer(
                            t,
                            buf,
                            &mut values,
                            &mut table,
                            &mut metrics,
                            machine,
                            t_count,
                            &mut facct[t],
                        );
                        buf.base += 1;
                    } else {
                        if buf.data.len() == buf.cap {
                            cost += flush_buffer(
                                t,
                                buf,
                                &mut values,
                                &mut table,
                                &mut metrics,
                                machine,
                                t_count,
                                &mut facct[t],
                            );
                        }
                        buf.data.push(new);
                        cost += machine.cost.buffer_push;
                    }
                    (new, old)
                };

                if !multi {
                    changed_this = new != old;
                    activate_this = prog.activates(old, new);
                    deltas[t] += prog.delta(old, new);
                }
                if frontier_on && activate_this {
                    super::kernels::activate_out_neighbors(g, v, |w2| {
                        bits::set(&mut nxt, w2);
                        cost += machine.cost.buffer_push;
                    });
                }

                changed += changed_this as u64;
                idx[t] += 1;
                clock += cost;
                clocks[t] = clock;

                let done = match &ws {
                    Some(w) => w.exhausted(t),
                    None => idx[t] >= len_of(t),
                };
                if done {
                    if !sync_mode {
                        // End of range: final flush, charged to this thread.
                        let buf = &mut buffers[t];
                        clocks[t] += flush_buffer(
                            t,
                            buf,
                            &mut values,
                            &mut table,
                            &mut metrics,
                            machine,
                            t_count,
                            &mut facct[t],
                        );
                    }
                    break;
                }
                if let Some(k) = next_key {
                    if (clock, t) > k {
                        heap.push(Reverse((clock, t)));
                        break;
                    }
                }
            } // batch loop
        }

        let round_end = clocks.iter().copied().max().unwrap_or(clock_base);
        let round_cycles = round_end - clock_base;
        clock_base = round_end;
        metrics.round_cycles.push(round_cycles);

        if sync_mode {
            std::mem::swap(&mut values, &mut back);
            std::mem::swap(&mut table, &mut table_back);
        }

        let round_delta: f64 = deltas.iter().sum();
        // Cross-thread lane sums in thread order (the native order).
        let mut lane_sums = vec![0.0f64; lane_n];
        for chunk in lane_sums_t.chunks_exact(lane_n.max(1)) {
            for (s, d) in lane_sums.iter_mut().zip(chunk) {
                *s += d;
            }
        }
        rounds.push(RoundStats {
            time_s: round_cycles as f64 / machine.clock_hz,
            delta: round_delta,
            flushes: facct.iter().map(|a| a.flushes).sum(),
            active: total_active,
            steals: ws.as_ref().map_or(0, |w| w.steals),
            // Captured before the controllers observe: the δ in effect
            // *during* this round.
            delta_trace: if adaptive { controllers.iter().map(|c| c.delta()).collect() } else { Vec::new() },
            lane_deltas: if multi { lane_sums.clone() } else { Vec::new() },
        });
        if multi {
            // Per-lane drop-out, deterministic mirror of the native
            // executor: a lane whose criterion is met is masked dead and
            // its values freeze; the run ends once every query answered.
            let mut mask = live;
            lanes::for_each_live(live, |l| {
                if prog.lane_converged(l, lane_sums[l]) {
                    mask &= !(1u32 << l);
                }
            });
            live_mask = mask;
            if live_mask == 0 {
                converged = true;
                break;
            }
        } else if prog.converged(round_delta) {
            converged = true;
            break;
        }

        if adaptive {
            // Deterministic mirror of the native controller step: all
            // inputs are cycle counts and deterministic aggregates, so
            // the δ trace is bit-identical across repeated runs. A resize
            // charges `cost.resize` at the thread's next round start.
            let residual_ratio =
                if prev_residual.is_finite() && prev_residual > 0.0 { round_delta / prev_residual } else { 1.0 };
            prev_residual = round_delta;
            let density = changed as f64 / n.max(1) as f64;
            for t in 0..t_count {
                let tel = Telemetry {
                    processed: idx[t] as u64,
                    flush_lines: facct[t].lines,
                    flush_cost: facct[t].cycles as f64,
                    round_cost: (clocks[t] - round_start) as f64,
                    density,
                    residual_ratio,
                    live_lanes: live_n,
                };
                let next = controllers[t].observe(&tel);
                if next != buffers[t].cap {
                    buffers[t].cap = next;
                    resize_carry[t] = machine.cost.resize;
                }
            }
        }

        if frontier_on {
            // `lists` was exactly this round's sweep (None = dense).
            prev_lists = lists;
            std::mem::swap(&mut cur, &mut nxt);
            nxt.iter_mut().for_each(|w| *w = 0);
            let next_size = bits::count(&cur);
            sparse = match cfg.schedule {
                SchedulePolicy::Dense => false,
                SchedulePolicy::Frontier => true,
                SchedulePolicy::Adaptive => next_size * ADAPTIVE_SPARSE_DIVISOR < n,
            };
        }
    }

    SimRun {
        result: RunResult {
            values,
            rounds,
            mode: cfg.mode,
            schedule: cfg.schedule,
            threads: t_count,
            lanes: lane_n,
            converged,
        },
        metrics,
    }
}

/// Publish a SimBuffer: one coherence write per cache line spanned plus a
/// line-sized copy. Returns the cycle cost (also accumulated in `acct`).
#[allow(clippy::too_many_arguments)]
fn flush_buffer(
    t: usize,
    buf: &mut SimBuffer,
    values: &mut [u32],
    table: &mut LineTable,
    metrics: &mut SimMetrics,
    machine: &Machine,
    active: usize,
    acct: &mut FlushAcct,
) -> u64 {
    if buf.data.is_empty() {
        return 0;
    }
    let mut cost = 0;
    let base = buf.base as usize;
    let len = buf.data.len();
    values[base..base + len].copy_from_slice(&buf.data);
    // Charge one RFO per line touched: the vector stores of an aligned
    // flush dirty each destination line exactly once.
    let first_line = LineTable::line_of(base);
    let last_line = LineTable::line_of(base + len - 1);
    for line in first_line..=last_line {
        let w = table.write(t, line * crate::VALUES_PER_LINE, machine, active);
        metrics.on_write(&w);
        cost += w.cycles;
    }
    buf.base += len as VertexId;
    buf.data.clear();
    acct.flushes += 1;
    acct.lines += (last_line - first_line + 1) as u64;
    acct.cycles += cost;
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::program::ValueReader;
    use crate::graph::gap::GapGraph;
    use crate::graph::Csr;

    struct MaxProp<'g> {
        g: &'g Csr,
    }

    impl VertexProgram for MaxProp<'_> {
        fn name(&self) -> &'static str {
            "maxprop"
        }
        fn init(&self, v: VertexId) -> u32 {
            (v as u64 * 2654435761 % 1000003) as u32
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    #[test]
    fn deterministic() {
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let cfg = EngineConfig::new(8, ExecutionMode::Delayed(32));
        let m = Machine::haswell();
        let a = run(&g, &p, &cfg, &m);
        let b = run(&g, &p, &cfg, &m);
        assert_eq!(a.result.values, b.result.values);
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.total_cycles(), b.total_cycles());
    }

    #[test]
    fn numa_homes_deterministic_same_values_different_cycles() {
        // The NUMA mirror changes only cold-fill charges: deterministic
        // cycle totals and the same fixed point as the plain config.
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let plain = EngineConfig::new(32, ExecutionMode::Delayed(32));
        let numa = plain.clone().with_numa();
        let a = run(&g, &p, &numa, &m);
        let b = run(&g, &p, &numa, &m);
        assert_eq!(a.result.values, b.result.values);
        assert_eq!(a.total_cycles(), b.total_cycles(), "placement model is deterministic");
        let base = run(&g, &p, &plain, &m);
        // Line-aligned partitions can shift sweep interleavings, so only
        // the fixed point itself is comparable across the two configs.
        assert_eq!(a.result.values, base.result.values, "placement never changes results");
    }

    #[test]
    fn numa_single_socket_machine_installs_no_homes() {
        // sockets == 1 → no homes → every cold fill is plain local DRAM;
        // the run stays deterministic and reaches the same fixed point.
        let g = GapGraph::Web.generate(8, 4);
        let p = MaxProp { g: &g };
        let mut m = Machine::haswell();
        m.sockets = 1;
        let cfg = EngineConfig::new(8, ExecutionMode::Delayed(16)).with_numa();
        let a = run(&g, &p, &cfg, &m);
        let b = run(&g, &p, &cfg, &m);
        assert_eq!(a.result.values, b.result.values);
        assert_eq!(a.total_cycles(), b.total_cycles());
        let oracle = crate::engine::native::run_serial_sync(&g, &p, 10_000);
        assert_eq!(a.result.values, oracle.values);
    }

    #[test]
    fn deterministic_with_frontier() {
        let g = GapGraph::Web.generate(8, 4);
        let p = MaxProp { g: &g };
        let cfg = EngineConfig::new(8, ExecutionMode::Delayed(32)).with_schedule(SchedulePolicy::Frontier);
        let m = Machine::haswell();
        let a = run(&g, &p, &cfg, &m);
        let b = run(&g, &p, &cfg, &m);
        assert_eq!(a.result.values, b.result.values);
        assert_eq!(a.metrics, b.metrics);
    }

    #[test]
    fn matches_native_fixed_point() {
        let g = GapGraph::Web.generate(8, 4);
        let p = MaxProp { g: &g };
        let native = crate::engine::native::run_serial_sync(&g, &p, 10_000);
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(16)] {
            let s = run(&g, &p, &EngineConfig::new(4, mode), &Machine::haswell());
            assert!(s.result.converged, "{mode:?}");
            assert_eq!(s.result.values, native.values, "{mode:?}");
        }
    }

    #[test]
    fn frontier_schedules_match_dense_fixed_point() {
        for g in [GapGraph::Web.generate(8, 4), GapGraph::Road.generate(8, 0)] {
            let p = MaxProp { g: &g };
            let oracle = crate::engine::native::run_serial_sync(&g, &p, 10_000);
            for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(16)] {
                for sched in [SchedulePolicy::Frontier, SchedulePolicy::Adaptive] {
                    let cfg = EngineConfig::new(4, mode).with_schedule(sched);
                    let s = run(&g, &p, &cfg, &Machine::haswell());
                    assert!(s.result.converged, "{mode:?}/{sched:?}");
                    assert_eq!(s.result.values, oracle.values, "{mode:?}/{sched:?}");
                }
            }
        }
    }

    #[test]
    fn frontier_sync_matches_dense_round_count() {
        // Sync frontier is bit-identical to sync dense: same rounds, same
        // per-round deltas, and per-round active counts shrink.
        let g = GapGraph::Road.generate(9, 0);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let dense = run(&g, &p, &EngineConfig::new(8, ExecutionMode::Synchronous), &m);
        let front = run(
            &g,
            &p,
            &EngineConfig::new(8, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier),
            &m,
        );
        assert_eq!(front.result.num_rounds(), dense.result.num_rounds());
        assert_eq!(front.result.values, dense.result.values);
        for (a, b) in front.result.rounds.iter().zip(&dense.result.rounds) {
            assert_eq!(a.delta, b.delta);
        }
        assert!(front.result.total_active() < dense.result.total_active());
    }

    #[test]
    fn frontier_sparse_rounds_cost_fewer_cycles() {
        // Road converges from a shrinking frontier. Synchronous keeps the
        // round count identical to dense, so the cycle comparison is a
        // hard guarantee: every sparse round does strictly less work.
        let g = GapGraph::Road.generate(9, 0);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let dense = run(&g, &p, &EngineConfig::new(8, ExecutionMode::Synchronous), &m);
        let front =
            run(&g, &p, &EngineConfig::new(8, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier), &m);
        assert!(front.result.converged);
        assert_eq!(front.result.num_rounds(), dense.result.num_rounds());
        assert!(
            front.total_cycles() < dense.total_cycles(),
            "frontier {} vs dense {} cycles",
            front.total_cycles(),
            dense.total_cycles()
        );
    }

    #[test]
    fn resume_from_fixed_point_is_cheap_and_exact() {
        // Resuming at a fixed point with a small dirty set must converge
        // in one sparse round sweeping only the dirty vertices, at a
        // fraction of the cold run's simulated cost.
        let g = GapGraph::Web.generate(8, 4);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_schedule(SchedulePolicy::Frontier);
        let cold = run(&g, &p, &cfg, &m);
        assert!(cold.result.converged);

        let seed = cold.result.resume_from(&[0, 1, 2]);
        let warm = run(&g, &p, &cfg.clone().with_resume(seed), &m);
        assert!(warm.result.converged);
        assert_eq!(warm.result.values, cold.result.values);
        assert_eq!(warm.result.num_rounds(), 1, "fixed-point resume needs one confirming round");
        assert_eq!(warm.result.total_active(), 3, "only the dirty vertices are swept");
        assert!(
            warm.total_cycles() < cold.total_cycles(),
            "warm {} vs cold {} cycles",
            warm.total_cycles(),
            cold.total_cycles()
        );

        // Dense resume re-sweeps everything but still confirms in one round.
        let dense_seed = cold.result.resume_from(&[0]);
        let dense_cfg = EngineConfig::new(4, ExecutionMode::Synchronous).with_resume(dense_seed);
        let dw = run(&g, &p, &dense_cfg, &m);
        assert!(dw.result.converged);
        assert_eq!(dw.result.values, cold.result.values);
        assert_eq!(dw.result.num_rounds(), 1);
    }

    #[test]
    fn async_fewer_rounds_sync_fewer_invalidations() {
        // The paper's core trade-off, visible in simulation.
        let g = GapGraph::Kron.generate(10, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let sync = run(&g, &p, &EngineConfig::new(16, ExecutionMode::Synchronous), &m);
        let asyn = run(&g, &p, &EngineConfig::new(16, ExecutionMode::Asynchronous), &m);
        assert!(
            asyn.result.num_rounds() <= sync.result.num_rounds(),
            "async {} sync {}",
            asyn.result.num_rounds(),
            sync.result.num_rounds()
        );
        // Sync's per-round invalidations are bounded: writes go to a
        // private-ish back array. Compare per-round rates.
        let sync_rate = sync.metrics.invalidations as f64 / sync.result.num_rounds() as f64;
        let async_rate = asyn.metrics.invalidations as f64 / asyn.result.num_rounds() as f64;
        assert!(async_rate > sync_rate, "async {async_rate} vs sync {sync_rate}");
    }

    #[test]
    fn delayed_reduces_invalidations_vs_async() {
        let g = GapGraph::Urand.generate(10, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let asyn = run(&g, &p, &EngineConfig::new(16, ExecutionMode::Asynchronous), &m);
        let del = run(&g, &p, &EngineConfig::new(16, ExecutionMode::Delayed(256)), &m);
        let a_rate = asyn.metrics.invalidations as f64 / asyn.result.num_rounds() as f64;
        let d_rate = del.metrics.invalidations as f64 / del.result.num_rounds() as f64;
        assert!(d_rate < a_rate, "delayed {d_rate} vs async {a_rate}");
    }

    #[test]
    fn access_matrix_web_is_diagonal() {
        let g = GapGraph::Web.generate(10, 8);
        let kron = GapGraph::Kron.generate(10, 8);
        let m = Machine::haswell();
        let cfg = EngineConfig::new(8, ExecutionMode::Asynchronous);
        let web_run = run(&g, &MaxProp { g: &g }, &cfg, &m);
        let kron_run = run(&kron, &MaxProp { g: &kron }, &cfg, &m);
        assert!(
            web_run.metrics.diagonal_fraction() > 2.0 * kron_run.metrics.diagonal_fraction(),
            "web {} kron {}",
            web_run.metrics.diagonal_fraction(),
            kron_run.metrics.diagonal_fraction()
        );
    }

    #[test]
    fn flush_counts() {
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let del = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(16)), &m);
        assert!(del.result.total_flushes() > 0);
        let sync = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Synchronous), &m);
        assert_eq!(sync.result.total_flushes(), 0);
    }

    #[test]
    fn local_reads_converges_same() {
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let oracle = crate::engine::native::run_serial_sync(&g, &p, 10_000).values;
        let lr = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(64)).with_local_reads(), &m);
        assert_eq!(lr.result.values, oracle);
        let fcfg = EngineConfig::new(4, ExecutionMode::Delayed(64))
            .with_local_reads()
            .with_schedule(SchedulePolicy::Frontier);
        let lr_frontier = run(&g, &p, &fcfg, &m);
        assert_eq!(lr_frontier.result.values, oracle);
    }

    #[test]
    fn round_times_positive() {
        let g = GapGraph::Road.generate(8, 0);
        let p = MaxProp { g: &g };
        let s = run(&g, &p, &EngineConfig::new(4, ExecutionMode::Delayed(16)), &Machine::cascade_lake());
        for r in &s.result.rounds {
            assert!(r.time_s > 0.0);
        }
        assert_eq!(s.metrics.round_cycles.len(), s.result.num_rounds());
    }

    #[test]
    fn stealing_deterministic_and_matches_fixed_point() {
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let oracle = crate::engine::native::run_serial_sync(&g, &p, 10_000).values;
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in [SchedulePolicy::Dense, SchedulePolicy::Frontier] {
                let cfg = EngineConfig::new(8, mode).with_schedule(sched).with_stealing();
                let a = run(&g, &p, &cfg, &m);
                let b = run(&g, &p, &cfg, &m);
                assert_eq!(a.result.values, b.result.values, "{mode:?}/{sched:?}");
                assert_eq!(a.metrics, b.metrics, "{mode:?}/{sched:?} nondeterministic");
                assert_eq!(a.result.values, oracle, "{mode:?}/{sched:?}");
            }
        }
    }

    /// Every vertex points at the first 64, so the lowest equal-vertex
    /// partition holds essentially all the pull work — a guaranteed
    /// straggler whose trailing chunks must get stolen.
    fn hub_graph(n: usize) -> Csr {
        let mut b = crate::graph::GraphBuilder::new(n);
        for v in 0..n as VertexId {
            for h in 0..64u32 {
                if v != h {
                    b.push(v, h, 1);
                }
            }
        }
        b.build()
    }

    #[test]
    fn adaptive_trace_bit_identical_across_runs() {
        let g = GapGraph::Kron.generate(8, 8);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let oracle = crate::engine::native::run_serial_sync(&g, &p, 10_000).values;
        for steal in [false, true] {
            for sched in [SchedulePolicy::Dense, SchedulePolicy::Frontier] {
                let mut cfg = EngineConfig::new(8, ExecutionMode::Adaptive).with_schedule(sched);
                if steal {
                    cfg = cfg.with_stealing();
                }
                let a = run(&g, &p, &cfg, &m);
                let b = run(&g, &p, &cfg, &m);
                assert_eq!(a.result.values, oracle, "steal={steal} {sched:?}");
                assert_eq!(a.result.values, b.result.values, "steal={steal} {sched:?}");
                assert_eq!(a.metrics, b.metrics, "steal={steal} {sched:?}");
                let ta: Vec<&[usize]> = a.result.rounds.iter().map(|r| r.delta_trace.as_slice()).collect();
                let tb: Vec<&[usize]> = b.result.rounds.iter().map(|r| r.delta_trace.as_slice()).collect();
                assert_eq!(ta, tb, "δ trace must be bit-identical (steal={steal}, {sched:?})");
                assert!(ta.iter().all(|tr| tr.len() == 8), "one δ per thread per round");
            }
        }
    }

    /// Banded graph: every edge stays within ±2 ids, so nearly all edges
    /// are internal to their partition block — diagonal locality far
    /// above the §IV-C gate, which must seed the controller at δ = 0.
    fn banded_graph(n: usize) -> Csr {
        let mut b = crate::graph::GraphBuilder::new(n);
        for v in 2..n as VertexId {
            b.push(v - 1, v, 1);
            b.push(v - 2, v, 1);
        }
        b.build()
    }

    #[test]
    fn adaptive_zero_delta_means_zero_flushes() {
        let g = banded_graph(512);
        let p = MaxProp { g: &g };
        let s = run(&g, &p, &EngineConfig::new(8, ExecutionMode::Adaptive), &Machine::haswell());
        assert!(
            s.result.rounds[0].delta_trace.iter().all(|&d| d == 0),
            "high locality must seed δ=0: {:?}",
            s.result.rounds[0].delta_trace
        );
        for r in &s.result.rounds {
            if r.delta_trace.iter().all(|&d| d == 0) {
                assert_eq!(r.flushes, 0, "δ=0 rounds charge no flushes");
            }
        }
        assert_eq!(s.result.total_flushes(), 0, "controller never left async");
    }

    /// k-lane batched MaxProp with per-lane salted inits: k independent
    /// floods, each with a unique fixed point.
    struct MultiMax<'g> {
        g: &'g Csr,
        k: usize,
    }

    fn salted(v: VertexId, l: usize) -> u32 {
        (v as u64 * (2654435761 + 7 * l as u64) % (1000003 + l as u64)) as u32
    }

    impl VertexProgram for MultiMax<'_> {
        fn name(&self) -> &'static str {
            "multimax"
        }
        fn lanes(&self) -> usize {
            self.k
        }
        fn init(&self, v: VertexId) -> u32 {
            salted(v, 0)
        }
        fn init_lane(&self, v: VertexId, l: usize) -> u32 {
            salted(v, l)
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn update_lanes<R: lanes::LaneReader>(&self, v: VertexId, r: &mut R, out: &mut [u32], live: u32) {
            let mut nb = [0u32; lanes::MAX_LANES];
            for &u in self.g.in_neighbors(v) {
                r.read_group(u, &mut nb[..self.k]);
                lanes::for_each_live(live, |l| out[l] = out[l].max(nb[l]));
            }
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    /// Lane `l` of [`MultiMax`] as an independent single-query program.
    struct SaltedMax<'g> {
        g: &'g Csr,
        l: usize,
    }

    impl VertexProgram for SaltedMax<'_> {
        fn name(&self) -> &'static str {
            "saltedmax"
        }
        fn init(&self, v: VertexId) -> u32 {
            salted(v, self.l)
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = r.read(v);
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    #[test]
    fn batched_lanes_deterministic_and_match_independent_runs() {
        let g = GapGraph::Web.generate(8, 4);
        let k = 8;
        let m = Machine::haswell();
        let oracles: Vec<Vec<u32>> = (0..k)
            .map(|l| crate::engine::native::run_serial_sync(&g, &SaltedMax { g: &g, l }, 10_000).values)
            .collect();
        for mode in [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(32)] {
            for sched in [SchedulePolicy::Dense, SchedulePolicy::Frontier] {
                for steal in [false, true] {
                    let mut cfg = EngineConfig::new(8, mode).with_schedule(sched);
                    if steal {
                        cfg = cfg.with_stealing();
                    }
                    let a = run(&g, &MultiMax { g: &g, k }, &cfg, &m);
                    let b = run(&g, &MultiMax { g: &g, k }, &cfg, &m);
                    assert!(a.result.converged, "{mode:?}/{sched:?} steal={steal}");
                    assert_eq!(a.result.values, b.result.values, "{mode:?}/{sched:?} steal={steal}");
                    assert_eq!(a.metrics, b.metrics, "{mode:?}/{sched:?} steal={steal} nondeterministic");
                    assert_eq!(a.result.lanes, k);
                    for (l, want) in oracles.iter().enumerate() {
                        assert_eq!(&a.result.lane_values(l), want, "lane {l} {mode:?}/{sched:?} steal={steal}");
                    }
                }
            }
        }
    }

    #[test]
    fn batched_lanes_amortize_cycles_per_query() {
        // The tentpole's cost claim, visible in the model: 8 queries in
        // one batched delayed-mode run must cost well under 8 single
        // runs' cycles — each neighbor line transfer is shared by all
        // live lanes. (The `daig experiment batch` acceptance bar of
        // ≥2x queries/sec at k=8 is asserted end-to-end in
        // rust/tests/experiments_smoke.rs.)
        let g = GapGraph::Kron.generate(9, 8);
        let k = 8;
        let m = Machine::haswell();
        let cfg = EngineConfig::new(8, ExecutionMode::Delayed(256));
        let batched = run(&g, &MultiMax { g: &g, k }, &cfg, &m);
        let singles: u64 =
            (0..k).map(|l| run(&g, &SaltedMax { g: &g, l }, &cfg, &m).total_cycles()).sum();
        assert!(
            2 * batched.total_cycles() < singles,
            "batched {} vs {} summed single cycles",
            batched.total_cycles(),
            singles
        );
    }

    #[test]
    fn stealing_reports_steals_on_skewed_work() {
        use crate::engine::PartitionStrategy;
        let g = hub_graph(2048);
        let p = MaxProp { g: &g };
        let m = Machine::haswell();
        let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64))
            .with_partition(PartitionStrategy::EqualVertex)
            .with_stealing();
        let s = run(&g, &p, &cfg, &m);
        assert!(s.result.total_steals() > 0, "straggler chunks must be stolen");
        // Same config without stealing reports zero and the same values.
        let static_cfg =
            EngineConfig::new(4, ExecutionMode::Delayed(64)).with_partition(PartitionStrategy::EqualVertex);
        let st = run(&g, &p, &static_cfg, &m);
        assert_eq!(st.result.total_steals(), 0);
        assert_eq!(s.result.values, st.result.values);
        // Recovered straggler time: the stealing run must finish the same
        // work in strictly fewer simulated cycles.
        assert!(
            s.total_cycles() < st.total_cycles(),
            "stealing {} vs static {} cycles",
            s.total_cycles(),
            st.total_cycles()
        );
    }
}
