//! Metrics collected by the simulator — coherence events and the
//! thread-access matrix of the paper's Fig. 5.

use crate::engine::sim::cache::Access;

/// Aggregate coherence statistics for one simulated run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimMetrics {
    /// Cache-line copies invalidated by stores (the quantity the delay
    /// buffer exists to reduce).
    pub invalidations: u64,
    /// Reads served by forwarding another core's dirty line.
    pub remote_dirty_reads: u64,
    /// Cold DRAM fills.
    pub cold_misses: u64,
    /// L1 hits.
    pub l1_hits: u64,
    /// Total simulated accesses to shared arrays.
    pub accesses: u64,
    /// Row-major `threads × threads` matrix; entry `(reader, owner)`
    /// counts pull reads by simulated thread `reader` on vertex data
    /// owned by partition `owner` (Fig. 5). Flat storage: the increment
    /// is on the simulator's hottest path (§Perf: the nested-Vec layout
    /// cost a second pointer chase per read).
    matrix: Vec<u64>,
    threads: usize,
    /// Simulated cycles per round (max over threads).
    pub round_cycles: Vec<u64>,
}

impl SimMetrics {
    /// Initialize with a `threads × threads` access matrix.
    pub fn new(threads: usize) -> Self {
        Self { matrix: vec![0; threads * threads], threads, ..Default::default() }
    }

    /// Number of simulated threads (matrix dimension).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Record a read access outcome.
    #[inline]
    pub fn on_read(&mut self, a: &Access) {
        self.accesses += 1;
        self.l1_hits += a.hit as u64;
        self.remote_dirty_reads += a.remote_dirty as u64;
        self.cold_misses += a.cold as u64;
    }

    /// Count one pull read by `reader` on data owned by `owner`.
    #[inline]
    pub fn count_read(&mut self, reader: usize, owner: usize) {
        self.matrix[reader * self.threads + owner] += 1;
    }

    /// Record a write access outcome.
    #[inline]
    pub fn on_write(&mut self, a: &Access) {
        self.accesses += 1;
        self.l1_hits += a.hit as u64;
        self.invalidations += a.invalidated as u64;
        self.cold_misses += a.cold as u64;
    }

    /// One row of the access matrix (reads performed by `reader`).
    pub fn matrix_row(&self, reader: usize) -> &[u64] {
        &self.matrix[reader * self.threads..(reader + 1) * self.threads]
    }

    /// The access matrix as rows (convenience for reports).
    pub fn access_matrix(&self) -> Vec<Vec<u64>> {
        (0..self.threads).map(|r| self.matrix_row(r).to_vec()).collect()
    }

    /// Fraction of the access matrix's mass on the diagonal — the §IV-C
    /// clustering statistic (high for Web, low for Kron).
    pub fn diagonal_fraction(&self) -> f64 {
        let total: u64 = self.matrix.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let diag: u64 = (0..self.threads).map(|i| self.matrix[i * self.threads + i]).sum();
        diag as f64 / total as f64
    }

    /// Rows whose diagonal share exceeds `threshold` (the paper marks
    /// boxes receiving ≥ 1/32 of accesses locally with a plus).
    pub fn clustered_rows(&self, threshold: f64) -> usize {
        (0..self.threads)
            .filter(|&i| {
                let row = self.matrix_row(i);
                let total: u64 = row.iter().sum();
                total > 0 && row[i] as f64 / total as f64 >= threshold
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_fraction() {
        let mut m = SimMetrics::new(2);
        m.count_read(0, 0);
        m.count_read(0, 0);
        m.count_read(0, 0);
        m.count_read(0, 1);
        for _ in 0..4 {
            m.count_read(1, 1);
        }
        assert!((m.diagonal_fraction() - 7.0 / 8.0).abs() < 1e-12);
        assert_eq!(m.clustered_rows(0.5), 2);
        assert_eq!(m.clustered_rows(0.8), 1);
        assert_eq!(m.access_matrix(), vec![vec![3, 1], vec![0, 4]]);
        assert_eq!(m.matrix_row(1), &[0, 4]);
    }

    #[test]
    fn empty_matrix() {
        let m = SimMetrics::new(4);
        assert_eq!(m.diagonal_fraction(), 0.0);
        assert_eq!(m.clustered_rows(0.1), 0);
        assert_eq!(m.threads(), 4);
    }

    #[test]
    fn event_recording() {
        use crate::engine::sim::cache::Access;
        let mut m = SimMetrics::new(1);
        m.on_read(&Access { cycles: 4, invalidated: 0, remote_dirty: true, cold: false, hit: false });
        m.on_write(&Access { cycles: 40, invalidated: 3, remote_dirty: false, cold: true, hit: false });
        assert_eq!(m.remote_dirty_reads, 1);
        assert_eq!(m.invalidations, 3);
        assert_eq!(m.cold_misses, 1);
        assert_eq!(m.accesses, 2);
    }
}
