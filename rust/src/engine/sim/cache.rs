//! Line-granularity MESI-style coherence state for the simulator.
//!
//! One [`LineTable`] tracks every 64-byte line of a shared value array:
//! which simulated threads hold a valid copy (sharer bitmask) and whether
//! one of them holds it Modified. The table is the *whole* model — private
//! caches are taken as large enough to hold their working set (capacity
//! misses are identical across the three execution modes and thus cancel
//! out of every ratio the paper reports; coherence misses are what
//! differ). First-ever touch of a line is charged as a DRAM miss.

use crate::VALUES_PER_LINE;

use super::cost::Machine;

/// Maximum simulated threads (two bitmask words).
pub const MAX_THREADS: usize = 128;

/// Coherence state of one cache line.
#[derive(Debug, Clone, Copy, Default)]
struct Line {
    /// Threads holding a valid copy.
    sharers: [u64; 2],
    /// Thread holding the line Modified (also set in `sharers`).
    modified: Option<u16>,
    /// Whether the line has ever been brought in from memory.
    touched: bool,
}

impl Line {
    #[inline]
    fn has(&self, t: usize) -> bool {
        self.sharers[t / 64] & (1u64 << (t % 64)) != 0
    }

    #[inline]
    fn add(&mut self, t: usize) {
        self.sharers[t / 64] |= 1u64 << (t % 64);
    }

    #[inline]
    fn others(&self, t: usize) -> u32 {
        let mut w = self.sharers;
        w[t / 64] &= !(1u64 << (t % 64));
        w[0].count_ones() + w[1].count_ones()
    }

    #[inline]
    fn only(&mut self, t: usize) {
        self.sharers = [0, 0];
        self.add(t);
    }
}

/// Outcome of one simulated access: the latency charged and the
/// coherence events it caused (fed into [`super::trace::SimMetrics`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub cycles: u64,
    /// Copies invalidated in other threads' caches (write only).
    pub invalidated: u32,
    /// Served by forwarding another core's dirty line.
    pub remote_dirty: bool,
    /// Cold DRAM fill.
    pub cold: bool,
    /// Plain L1 hit.
    pub hit: bool,
}

/// Coherence state for one shared array.
pub struct LineTable {
    lines: Vec<Line>,
    /// NUMA home socket per line (first-touch placement), mirroring the
    /// native `--numa` path: a cold DRAM fill from a non-home socket is
    /// charged [`super::cost::CostModel::remote_dram`] instead of
    /// `dram`. `None` (the default) models interleaved/unknown placement
    /// and charges plain `dram` everywhere — bit-identical to the
    /// pre-NUMA simulator.
    homes: Option<Vec<u8>>,
}

impl LineTable {
    /// Table covering `n_values` 32-bit elements.
    pub fn new(n_values: usize) -> Self {
        Self { lines: vec![Line::default(); n_values.div_ceil(VALUES_PER_LINE)], homes: None }
    }

    /// Install per-line home sockets (one entry per line). Placement
    /// survives [`Self::clear`]: pages keep their node across runs.
    pub fn set_homes(&mut self, homes: Vec<u8>) {
        assert_eq!(homes.len(), self.lines.len(), "one home socket per line");
        self.homes = Some(homes);
    }

    /// Cold-fill latency for line `li` as seen by thread `t`: local or
    /// remote DRAM depending on the line's home socket.
    #[inline]
    fn dram_cost(&self, li: usize, t: usize, m: &Machine, active: usize) -> u64 {
        match &self.homes {
            Some(h) if h[li] as usize != m.socket_of(t, active) => m.cost.remote_dram,
            _ => m.cost.dram,
        }
    }

    /// Line index of element `idx`.
    #[inline]
    pub fn line_of(idx: usize) -> usize {
        idx / VALUES_PER_LINE
    }

    /// Number of lines tracked.
    pub fn num_lines(&self) -> usize {
        self.lines.len()
    }

    /// Simulate thread `t` reading element `idx`.
    #[inline]
    pub fn read(&mut self, t: usize, idx: usize, m: &Machine, active: usize) -> Access {
        let li = Self::line_of(idx);
        let dram = self.dram_cost(li, t, m, active);
        let line = &mut self.lines[li];
        if line.has(t) {
            // Valid copy (Shared or our own Modified): L1 hit.
            return Access { cycles: m.cost.l1, invalidated: 0, remote_dirty: false, cold: false, hit: true };
        }
        if let Some(owner) = line.modified {
            // Dirty elsewhere: forward + downgrade to Shared.
            let cycles = m.forward_cost(owner as usize, t, active);
            line.modified = None;
            line.add(t);
            return Access { cycles, invalidated: 0, remote_dirty: true, cold: false, hit: false };
        }
        if line.touched {
            // Clean somewhere in the hierarchy: LLC-class fill.
            line.add(t);
            return Access { cycles: m.cost.llc, invalidated: 0, remote_dirty: false, cold: false, hit: false };
        }
        // Cold: DRAM (local or the home node's, under NUMA placement).
        line.touched = true;
        line.add(t);
        Access { cycles: dram, invalidated: 0, remote_dirty: false, cold: true, hit: false }
    }

    /// Simulate thread `t` writing element `idx` (request-for-ownership).
    #[inline]
    pub fn write(&mut self, t: usize, idx: usize, m: &Machine, active: usize) -> Access {
        let li = Self::line_of(idx);
        let dram = self.dram_cost(li, t, m, active);
        let line = &mut self.lines[li];
        if line.modified == Some(t as u16) {
            // Already exclusive-dirty here: store hits L1.
            return Access { cycles: m.cost.l1, invalidated: 0, remote_dirty: false, cold: false, hit: true };
        }
        let others = line.others(t);
        let was_dirty_elsewhere = line.modified.is_some();
        let cold = !line.touched;
        // Invalidate every other copy; take exclusive ownership.
        let cycles = if was_dirty_elsewhere {
            m.forward_cost(line.modified.unwrap() as usize, t, active)
        } else if others > 0 {
            // Upgrade / RFO with sharers to invalidate.
            m.cost.llc
        } else if line.has(t) {
            // Silent S→M upgrade of our own copy.
            m.cost.l1
        } else if cold {
            dram
        } else {
            m.cost.llc
        };
        line.touched = true;
        line.only(t);
        line.modified = Some(t as u16);
        Access { cycles, invalidated: others, remote_dirty: was_dirty_elsewhere, cold, hit: false }
    }

    /// Reset all coherence state (used between independent runs).
    pub fn clear(&mut self) {
        self.lines.iter_mut().for_each(|l| *l = Line::default());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::haswell()
    }

    #[test]
    fn cold_then_hit() {
        let m = machine();
        let mut lt = LineTable::new(64);
        let a = lt.read(0, 5, &m, 32);
        assert!(a.cold);
        assert_eq!(a.cycles, m.cost.dram);
        let b = lt.read(0, 6, &m, 32); // same line
        assert!(b.hit);
        assert_eq!(b.cycles, m.cost.l1);
    }

    #[test]
    fn write_invalidates_readers() {
        let m = machine();
        let mut lt = LineTable::new(64);
        lt.read(0, 0, &m, 32);
        lt.read(1, 0, &m, 32);
        lt.read(2, 0, &m, 32);
        let w = lt.write(3, 0, &m, 32);
        assert_eq!(w.invalidated, 3);
        // Reader must now pay a dirty-forward, not an L1 hit.
        let r = lt.read(0, 0, &m, 32);
        assert!(r.remote_dirty);
        assert_eq!(r.cycles, m.cost.remote_core); // 0 and 3 share socket 0
    }

    #[test]
    fn own_modified_line_is_cheap() {
        let m = machine();
        let mut lt = LineTable::new(64);
        lt.write(0, 0, &m, 32);
        let w2 = lt.write(0, 1, &m, 32); // same line, still M here
        assert!(w2.hit);
        let r = lt.read(0, 2, &m, 32);
        assert!(r.hit);
    }

    #[test]
    fn cross_socket_forward_costs_more() {
        let m = machine();
        let mut lt = LineTable::new(64);
        lt.write(0, 0, &m, 32); // socket 0
        let r = lt.read(31, 0, &m, 32); // socket 1
        assert_eq!(r.cycles, m.cost.remote_socket);
    }

    #[test]
    fn read_downgrades_modified() {
        let m = machine();
        let mut lt = LineTable::new(64);
        lt.write(0, 0, &m, 32);
        let r = lt.read(1, 0, &m, 32);
        assert!(r.remote_dirty);
        // Next write by 0 must RFO again (line now Shared).
        let w = lt.write(0, 0, &m, 32);
        assert!(!w.hit);
        assert_eq!(w.invalidated, 1);
    }

    #[test]
    fn silent_upgrade_when_sole_sharer() {
        let m = machine();
        let mut lt = LineTable::new(64);
        lt.read(4, 0, &m, 32);
        lt.read(4, 0, &m, 32);
        let w = lt.write(4, 0, &m, 32);
        assert_eq!(w.cycles, m.cost.l1);
        assert_eq!(w.invalidated, 0);
    }

    #[test]
    fn numa_homes_charge_remote_cold_fills() {
        let m = machine();
        let mut lt = LineTable::new(64); // 4 lines
        lt.set_homes(vec![0, 0, 1, 1]);
        // Thread 0 (socket 0) cold-reads a home-0 line: local DRAM.
        let a = lt.read(0, 0, &m, 32);
        assert!(a.cold);
        assert_eq!(a.cycles, m.cost.dram);
        // Same thread cold-reads a home-1 line: remote DRAM.
        let b = lt.read(0, 32, &m, 32);
        assert!(b.cold);
        assert_eq!(b.cycles, m.cost.remote_dram);
        // Cold *write* from socket 1 (thread 31) into a home-0 line.
        let w = lt.write(31, 16, &m, 32);
        assert!(w.cold);
        assert_eq!(w.cycles, m.cost.remote_dram);
        // Once a line is warm, homes are out of the picture: coherence
        // costs take over (same values as the no-homes table).
        let w2 = lt.write(31, 33, &m, 32); // line 2, warm: RFO, not a fill
        assert!(!w2.cold);
        assert_eq!(w2.cycles, m.cost.llc);
        let r = lt.read(0, 34, &m, 32); // dirty on socket 1 now
        assert!(r.remote_dirty);
        assert_eq!(r.cycles, m.cost.remote_socket, "dirty forward, not a DRAM fill");
        // clear() resets coherence but keeps placement.
        lt.clear();
        let c = lt.read(0, 32, &m, 32);
        assert_eq!(c.cycles, m.cost.remote_dram);
    }

    #[test]
    fn no_homes_is_legacy_behavior() {
        // Default table: every cold fill is plain DRAM regardless of
        // accessor socket — the pre-NUMA simulator, bit for bit.
        let m = machine();
        let mut lt = LineTable::new(64);
        assert_eq!(lt.read(31, 0, &m, 32).cycles, m.cost.dram);
        assert_eq!(lt.write(0, 16, &m, 32).cycles, m.cost.dram);
    }

    #[test]
    fn line_math() {
        assert_eq!(LineTable::line_of(0), 0);
        assert_eq!(LineTable::line_of(15), 0);
        assert_eq!(LineTable::line_of(16), 1);
        assert_eq!(LineTable::new(17).num_lines(), 2);
    }
}
