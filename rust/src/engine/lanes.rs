//! Multi-query execution lanes — batching k queries into one sweep.
//!
//! A serving workload answers many *independent* queries over the same
//! graph: SSSP from k sources, personalized PageRank for k teleport
//! sets. Run naively that is k full engine runs, and the delay-buffer
//! machinery amortizes nothing across them. Lanes change the value
//! layout instead: the shared array holds a **lane group** of k 32-bit
//! values per vertex (vertex-major, lanes interleaved), so
//!
//! * one neighbor *read* brings in the cache line carrying all k lanes
//!   of that vertex — the pull loop's coherence traffic is paid once
//!   per edge, not once per edge per query;
//! * one delay-buffer *flush* publishes a contiguous run of whole lane
//!   groups — each invalidation-causing line commit now carries k
//!   queries' updates (the paper's "make every committed line carry
//!   many useful writes", multiplied by k; cf. Maiter's accumulated
//!   batching in PAPERS.md).
//!
//! Layout: lane l of vertex v lives at element `v*k + l`. k must divide
//! [`crate::VALUES_PER_LINE`] (so k ∈ {1, 2, 4, 8, 16} for 64-byte
//! lines), which makes every lane group start and end inside a single
//! cache line — a group never straddles a line boundary, so the
//! flush-lines accounting and the simulator's line-granularity model
//! stay exact without explicit padding. δ keeps its meaning of *32-bit
//! elements*: a buffer of δ elements stages δ/k vertex groups.
//!
//! Convergence is tracked **per lane**: a query whose round residual
//! meets its criterion drops out of the sweep (its lane is masked dead,
//! its values freeze) while the remaining lanes keep iterating — short
//! queries never pay for the longest one. The live mask is a `u32`
//! bitmask re-published by thread 0 between rounds.

use crate::graph::VertexId;
use crate::VALUES_PER_LINE;

/// Largest supported lane count: one full cache line of 32-bit lanes.
pub const MAX_LANES: usize = VALUES_PER_LINE;

/// The lane counts the CLI / sweeps expose (`--batch k`): every k that
/// divides [`VALUES_PER_LINE`], as the module docs promise.
pub const LANE_COUNTS: [usize; 5] = [1, 2, 4, 8, 16];

/// Whether `k` is a legal lane count: non-zero, at most a cache line,
/// and dividing [`VALUES_PER_LINE`] so groups never straddle lines.
pub fn valid_lane_count(k: usize) -> bool {
    k >= 1 && k <= MAX_LANES && VALUES_PER_LINE % k == 0
}

/// First element index of vertex `v`'s lane group under `k` lanes.
#[inline]
pub fn group_base(v: VertexId, k: usize) -> VertexId {
    v * k as VertexId
}

/// Bitmask with the low `k` lane bits live.
#[inline]
pub fn full_mask(k: usize) -> u32 {
    debug_assert!(k <= 32);
    if k == 32 {
        u32::MAX
    } else {
        (1u32 << k) - 1
    }
}

/// Visit every live lane index in `mask`, ascending.
#[inline]
pub fn for_each_live<F: FnMut(usize)>(mask: u32, mut f: F) {
    let mut m = mask;
    while m != 0 {
        let l = m.trailing_zeros() as usize;
        m &= m - 1;
        f(l);
    }
}

/// Lane occupancy tracker for serving workloads
/// ([`crate::serve`]): `k` lane slots, each either free or holding an
/// opaque query id, with a **FIFO freelist** — lanes are refilled in
/// the order they were freed, so no query's lane is double-assigned
/// and a long-running occupant never blocks the rotation of the
/// others. The serve-path batch former packs admitted queries into
/// slots handed out by this allocator; the packing invariants (no
/// double assignment, FIFO refill, legal lane counts only) are
/// property-tested in `rust/tests/prop_serve.rs`.
#[derive(Debug, Clone)]
pub struct LaneSlots {
    /// Occupant query id per lane (`None` = free).
    occupant: Vec<Option<u64>>,
    /// Free lane indices, oldest-freed first.
    free: std::collections::VecDeque<usize>,
}

impl LaneSlots {
    /// Allocator over `k` lanes, all free. Panics unless `k` is a
    /// legal lane count ([`valid_lane_count`]): slots exist to feed
    /// the lane engine, so an unservable width is a caller bug.
    pub fn new(k: usize) -> Self {
        assert!(valid_lane_count(k), "{k} is not a legal lane count (1, 2, 4, 8, or 16)");
        Self { occupant: vec![None; k], free: (0..k).collect() }
    }

    /// Total lanes (free + occupied).
    pub fn lanes(&self) -> usize {
        self.occupant.len()
    }

    /// Currently free lanes.
    pub fn free_lanes(&self) -> usize {
        self.free.len()
    }

    /// Currently occupied lanes.
    pub fn occupied(&self) -> usize {
        self.lanes() - self.free_lanes()
    }

    /// Occupant of `lane`, if any.
    pub fn occupant(&self, lane: usize) -> Option<u64> {
        self.occupant[lane]
    }

    /// Bitmask of occupied lanes (lane l = bit l), the engine's
    /// live-mask convention ([`full_mask`]).
    pub fn live_mask(&self) -> u32 {
        self.occupant.iter().enumerate().fold(0u32, |m, (l, o)| if o.is_some() { m | (1 << l) } else { m })
    }

    /// Assign the oldest-freed lane to query `id`; `None` when every
    /// lane is occupied.
    pub fn assign(&mut self, id: u64) -> Option<usize> {
        let lane = self.free.pop_front()?;
        debug_assert!(self.occupant[lane].is_none(), "freelist handed out an occupied lane");
        self.occupant[lane] = Some(id);
        Some(lane)
    }

    /// Free `lane`, returning the query id it held. The lane goes to
    /// the **back** of the freelist (FIFO refill). Panics if the lane
    /// was already free — releasing twice is how double assignment
    /// starts, so it fails loudly.
    pub fn release(&mut self, lane: usize) -> u64 {
        let id = self.occupant[lane].take().unwrap_or_else(|| panic!("lane {lane} released while free"));
        self.free.push_back(lane);
        id
    }
}

/// Read access to whole lane groups — the batched twin of
/// [`super::program::ValueReader`]. Implementations mirror the
/// single-lane readers: the shared global array (native), the sync-mode
/// front buffer, the simulator's line-charging accessor, and the
/// delay-buffer-patched local reader.
pub trait LaneReader {
    /// Fill `out` (length = lane count) with the current lane group of
    /// vertex `v`.
    fn read_group(&mut self, v: VertexId, out: &mut [u32]);

    /// Hint that vertex `v`'s lane group will be read shortly — the CSR
    /// gather loop calls this a configurable distance ahead of the
    /// neighbor it is consuming. Native readers issue a software
    /// prefetch of the cache line holding the group; the default no-op
    /// serves the simulator (a prefetch is a hint with no memory
    /// effects, so it charges nothing and accounting is unchanged) and
    /// any reader without a stable backing address.
    #[inline]
    fn prefetch_group(&mut self, _v: VertexId) {}
}

/// [`super::program::ValueReader`] view of one lane of a [`LaneReader`]
/// — backs the trait's generic per-lane fallback, and lets single-lane
/// programs run unchanged on the lane engine path.
pub struct LaneProjection<'a, R: LaneReader> {
    pub reader: &'a mut R,
    /// Which lane this projection exposes.
    pub lane: usize,
    /// Total lanes per group.
    pub lanes: usize,
}

impl<R: LaneReader> super::program::ValueReader for LaneProjection<'_, R> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        let mut group = [0u32; MAX_LANES];
        self.reader.read_group(v, &mut group[..self.lanes]);
        group[self.lane]
    }

    #[inline]
    fn prefetch(&mut self, v: VertexId) {
        self.reader.prefetch_group(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_count_validation() {
        for k in LANE_COUNTS {
            assert!(valid_lane_count(k), "{k}");
        }
        assert!(valid_lane_count(2), "2 divides a line");
        for k in [0usize, 3, 5, 6, 7, 9, 12, 17, 32] {
            assert!(!valid_lane_count(k), "{k}");
        }
    }

    #[test]
    fn masks_and_groups() {
        assert_eq!(full_mask(1), 0b1);
        assert_eq!(full_mask(4), 0b1111);
        assert_eq!(full_mask(16), 0xFFFF);
        assert_eq!(group_base(5, 8), 40);
        let mut seen = Vec::new();
        for_each_live(0b1011, |l| seen.push(l));
        assert_eq!(seen, vec![0, 1, 3]);
        for_each_live(0, |_| panic!("empty mask must not visit"));
    }

    #[test]
    fn slots_fifo_refill() {
        let mut s = LaneSlots::new(4);
        assert_eq!((s.lanes(), s.free_lanes(), s.occupied()), (4, 4, 0));
        let a = s.assign(10).unwrap();
        let b = s.assign(11).unwrap();
        let c = s.assign(12).unwrap();
        let d = s.assign(13).unwrap();
        assert_eq!(vec![a, b, c, d], vec![0, 1, 2, 3], "fresh slots hand out lanes in order");
        assert_eq!(s.assign(14), None, "full");
        assert_eq!(s.live_mask(), 0b1111);
        // Free out of order: refill must follow the *free* order.
        assert_eq!(s.release(2), 12);
        assert_eq!(s.release(0), 10);
        assert_eq!(s.live_mask(), 0b1010);
        assert_eq!(s.assign(20), Some(2), "lane 2 freed first, refilled first");
        assert_eq!(s.assign(21), Some(0));
        assert_eq!(s.occupant(2), Some(20));
    }

    #[test]
    #[should_panic(expected = "released while free")]
    fn double_release_rejected() {
        let mut s = LaneSlots::new(2);
        let l = s.assign(1).unwrap();
        s.release(l);
        s.release(l);
    }

    #[test]
    #[should_panic(expected = "not a legal lane count")]
    fn slots_reject_illegal_width() {
        let _ = LaneSlots::new(3);
    }

    #[test]
    fn projection_reads_one_lane() {
        struct Fixed;
        impl LaneReader for Fixed {
            fn read_group(&mut self, v: VertexId, out: &mut [u32]) {
                for (l, o) in out.iter_mut().enumerate() {
                    *o = v * 100 + l as u32;
                }
            }
        }
        use crate::engine::program::ValueReader;
        let mut r = Fixed;
        let mut p = LaneProjection { reader: &mut r, lane: 2, lanes: 4 };
        assert_eq!(p.read(3), 302);
        assert_eq!(p.read(0), 2);
    }
}
