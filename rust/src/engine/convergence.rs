//! Convergence criteria used by the paper's two workloads.
//!
//! * PageRank: "total absolute page rank score change across vertices
//!   from the penultimate iteration totals 1e-4" — an L1-norm threshold.
//! * SSSP: "no update was generated in the last iteration".

/// A reusable convergence policy (value-level deltas are produced by the
/// [`crate::engine::VertexProgram`]; this just interprets the round sum).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Convergence {
    /// Stop when the summed |Δvalue| of a round is below the threshold.
    L1Below(f64),
    /// Stop when no vertex changed in a round.
    NoUpdates,
}

impl Convergence {
    /// Has the run converged given this round's summed delta?
    #[inline]
    pub fn met(&self, round_delta: f64) -> bool {
        match self {
            Convergence::L1Below(eps) => round_delta < *eps,
            Convergence::NoUpdates => round_delta == 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1() {
        let c = Convergence::L1Below(1e-4);
        assert!(!c.met(1e-3));
        assert!(c.met(1e-5));
        assert!(c.met(0.0));
    }

    #[test]
    fn no_updates() {
        let c = Convergence::NoUpdates;
        assert!(!c.met(1.0));
        assert!(c.met(0.0));
    }
}
