//! Intra-round work stealing over cache-line-aligned chunks.
//!
//! The paper's static contiguous partitions make writes cheap, but a
//! barrier per round means every round runs at the speed of its slowest
//! thread — and frontier scheduling makes per-partition work highly
//! skewed (one partition can hold the whole active set). This module
//! recovers that straggler time GAP/Ligra-style: each partition is split
//! into chunks whose interior boundaries are cache-line-aligned
//! ([`crate::partition::chunk_bounds`]) and published in a per-partition
//! claim deque. A worker drains its *own* chunks front-to-back first — a
//! contiguous sweep, so the delay buffer behaves exactly as in static
//! execution — and only then steals *trailing* chunks from the most
//! loaded victim. Stolen chunks are non-contiguous jumps, which
//! [`crate::engine::delay_buffer::DelayBuffer::seek`] already handles:
//! the pending run is published before the jump, so flushed runs stay
//! contiguous and line-aligned no matter who executes a chunk.
//!
//! Claim state is a single packed `(head, tail)` word per partition:
//! owners CAS the head forward, thieves CAS the tail backward, and the
//! two ends meeting means the queue is drained. Within a round the head
//! only grows and the tail only shrinks, so there is no ABA hazard;
//! [`ChunkDeque::reset`] re-arms the deque between round barriers.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::graph::VertexId;
use crate::partition::{chunk_bounds, PartitionMap};
use crate::VALUES_PER_LINE;

/// Default chunk size in elements: 16 cache lines. Large enough that the
/// claim CAS amortizes to noise per vertex, small enough that a skewed
/// partition still splits into many stealable pieces.
pub const DEFAULT_CHUNK: usize = 16 * VALUES_PER_LINE;

/// Pack a `(head, tail)` chunk-index pair into one atomic word.
#[inline]
fn pack(head: u32, tail: u32) -> u64 {
    ((head as u64) << 32) | tail as u64
}

/// Inverse of [`pack`].
#[inline]
fn unpack(state: u64) -> (u32, u32) {
    ((state >> 32) as u32, state as u32)
}

/// A per-partition deque of unclaimed chunks. The owner pops from the
/// front (preserving its contiguous sweep order); thieves pop from the
/// back (the trailing chunks the owner would reach last).
pub struct ChunkDeque {
    /// `bounds[i]..bounds[i+1]` is chunk `i`.
    bounds: Vec<VertexId>,
    /// Packed `(head, tail)`: `head..tail` are the unclaimed chunks.
    state: AtomicU64,
}

impl ChunkDeque {
    /// Deque over `range` split by [`chunk_bounds`] into `chunk`-element
    /// aligned chunks, all initially unclaimed.
    pub fn new(range: Range<VertexId>, chunk: usize) -> Self {
        let bounds = chunk_bounds(&range, chunk);
        let n = (bounds.len() - 1) as u32;
        Self { bounds, state: AtomicU64::new(pack(0, n)) }
    }

    /// Total number of chunks (claimed or not).
    pub fn num_chunks(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Number of currently unclaimed chunks — the "load" a thief compares.
    #[inline]
    pub fn remaining(&self) -> usize {
        let (h, t) = unpack(self.state.load(Ordering::Relaxed));
        (t - h) as usize
    }

    /// Owner side: claim the frontmost unclaimed chunk.
    pub fn pop_front(&self) -> Option<Range<VertexId>> {
        let mut s = self.state.load(Ordering::Relaxed);
        loop {
            let (h, t) = unpack(s);
            if h == t {
                return None;
            }
            match self.state.compare_exchange_weak(s, pack(h + 1, t), Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Some(self.bounds[h as usize]..self.bounds[h as usize + 1]),
                Err(cur) => s = cur,
            }
        }
    }

    /// Thief side: claim the rearmost unclaimed chunk.
    pub fn pop_back(&self) -> Option<Range<VertexId>> {
        let mut s = self.state.load(Ordering::Relaxed);
        loop {
            let (h, t) = unpack(s);
            if h == t {
                return None;
            }
            match self.state.compare_exchange_weak(s, pack(h, t - 1), Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Some(self.bounds[t as usize - 1]..self.bounds[t as usize]),
                Err(cur) => s = cur,
            }
        }
    }

    /// Re-arm every chunk for the next round. Callers must guarantee no
    /// concurrent claims (the executors reset between the round barriers).
    pub fn reset(&self) {
        self.state.store(pack(0, self.num_chunks() as u32), Ordering::Release);
    }
}

/// The whole gang's claim structure: one [`ChunkDeque`] per partition.
pub struct StealGrid {
    parts: Vec<ChunkDeque>,
}

impl StealGrid {
    /// One deque per partition of `pm`, chunked by `chunk` elements.
    pub fn new(pm: &PartitionMap, chunk: usize) -> Self {
        Self { parts: (0..pm.num_parts()).map(|t| ChunkDeque::new(pm.range(t), chunk)).collect() }
    }

    /// Partition `t`'s deque (owner claims).
    #[inline]
    pub fn part(&self, t: usize) -> &ChunkDeque {
        &self.parts[t]
    }

    /// Steal one trailing chunk from the most loaded partition other than
    /// `me` (most unclaimed chunks; ties go to the lowest partition id).
    /// `None` once every queue is drained.
    pub fn steal(&self, me: usize) -> Option<Range<VertexId>> {
        loop {
            let mut best: Option<(usize, usize)> = None;
            for (i, p) in self.parts.iter().enumerate() {
                if i == me {
                    continue;
                }
                let r = p.remaining();
                if r == 0 {
                    continue;
                }
                match best {
                    Some((br, _)) if br >= r => {}
                    _ => best = Some((r, i)),
                }
            }
            let (_, victim) = best?;
            if let Some(c) = self.parts[victim].pop_back() {
                return Some(c);
            }
            // Lost the race for the victim's last chunk(s): rescan. Each
            // retry means a queue drained, so this terminates.
        }
    }

    /// Re-arm every partition (between rounds only).
    pub fn reset(&self) {
        for p in &self.parts {
            p.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_chunk_is_line_multiple() {
        assert_eq!(DEFAULT_CHUNK % VALUES_PER_LINE, 0);
        assert!(DEFAULT_CHUNK > 0);
    }

    #[test]
    fn owner_drains_in_order() {
        let d = ChunkDeque::new(10..100, 32);
        assert_eq!(d.num_chunks(), 4);
        let mut got = Vec::new();
        while let Some(c) = d.pop_front() {
            got.push(c);
        }
        assert_eq!(got, vec![10..32, 32..64, 64..96, 96..100]);
        assert_eq!(d.remaining(), 0);
        assert!(d.pop_back().is_none());
    }

    #[test]
    fn thief_takes_trailing_chunks() {
        let d = ChunkDeque::new(0..96, 32);
        assert_eq!(d.pop_back(), Some(64..96));
        assert_eq!(d.pop_front(), Some(0..32));
        assert_eq!(d.pop_back(), Some(32..64));
        assert!(d.pop_front().is_none());
        d.reset();
        assert_eq!(d.remaining(), 3);
        assert_eq!(d.pop_front(), Some(0..32));
    }

    #[test]
    fn empty_partition_has_no_chunks() {
        let d = ChunkDeque::new(5..5, 32);
        assert_eq!(d.num_chunks(), 0);
        assert!(d.pop_front().is_none());
        assert!(d.pop_back().is_none());
    }

    #[test]
    fn grid_steals_from_most_loaded() {
        let pm = PartitionMap::from_bounds(vec![0, 32, 256]);
        let grid = StealGrid::new(&pm, 32);
        // Partition 1 has 7 chunks, partition 0 has 1: thread 0's first
        // steal must come from partition 1's tail.
        assert_eq!(grid.steal(0), Some(224..256));
        assert_eq!(grid.steal(0), Some(192..224));
        // Partition 1 steals partition 0's only chunk once it is the max.
        while grid.part(1).remaining() > 1 {
            grid.part(1).pop_front();
        }
        assert_eq!(grid.steal(1), Some(0..32));
        // A thread never steals from itself, so the grid is dry for 1 even
        // though partition 1 still holds its own last chunk.
        assert!(grid.steal(1).is_none());
        assert_eq!(grid.part(1).remaining(), 1);
    }

    #[test]
    fn concurrent_claims_cover_exactly_once() {
        // 4 threads hammer one grid: every vertex must be claimed exactly
        // once across owner pops and steals.
        let pm = PartitionMap::from_bounds(vec![0, 100, 2000, 2100, 4096]);
        let grid = StealGrid::new(&pm, 64);
        let claimed: Vec<Vec<Range<VertexId>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let grid = &grid;
                    s.spawn(move || {
                        let mut mine = Vec::new();
                        while let Some(c) = grid.part(t).pop_front() {
                            mine.push(c);
                        }
                        while let Some(c) = grid.steal(t) {
                            mine.push(c);
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut seen = vec![false; 4096];
        for c in claimed.into_iter().flatten() {
            for v in c {
                assert!(!seen[v as usize], "vertex {v} claimed twice");
                seen[v as usize] = true;
            }
        }
        assert!(seen.into_iter().all(|b| b), "some vertex never claimed");
    }
}
