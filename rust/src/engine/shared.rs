//! The globally shared vertex-value array.
//!
//! In asynchronous and delayed modes every thread reads the same array
//! that owners write into. Rust-wise those are data races unless the
//! slots are atomics, so values are `AtomicU32` accessed with `Relaxed`
//! ordering — which compiles to plain loads/stores on x86/ARM, exactly
//! the machine behavior the paper's C++ implementation has, without UB.
//! (The algorithms are chaotic-relaxation-tolerant: any interleaving of
//! 32-bit values converges; see Chazan & Miranker, ref 6 of the paper.)

use std::sync::atomic::{AtomicU32, Ordering};

use memmap2::MmapMut;

use crate::graph::VertexId;
use crate::VALUES_PER_LINE;

use super::program::ValueReader;

/// One cache line of value slots. The `#[repr(align(64))]` makes the
/// 64-byte alignment a *type-level* guarantee: a `Vec<ValueLine>`
/// allocation starts on a cache-line boundary, so every lane group —
/// which never straddles a line (see [`crate::engine::lanes`]) — starts
/// at an address aligned to its own width. The SIMD group loads/stores
/// ([`crate::engine::kernels`]) and the flush-lines accounting both
/// lean on that invariant; `shared::tests` asserts it for every
/// supported lane count.
#[repr(C, align(64))]
pub struct ValueLine([AtomicU32; VALUES_PER_LINE]);

/// Shared value array. Heap layout is genuinely 64-byte aligned (backed
/// by [`ValueLine`]s) so partition ranges map cleanly onto cache lines.
///
/// Under multi-query batching ([`crate::engine::lanes`]) the array holds
/// `lanes` interleaved 32-bit values per vertex (vertex-major lane
/// groups: lane `l` of vertex `v` at element `v*lanes + l`). Element
/// indices — [`Self::load`], [`Self::store`], [`Self::store_run`] — are
/// *flat* indices into that layout, which is what the delay buffer
/// stages and flushes; [`Self::load_group`]/[`Self::store_group`]
/// address whole per-vertex groups. `lanes == 1` is the classic
/// single-query array where element index = vertex id.
/// Backing storage for the line array.
///
/// `Owned` is a regular heap allocation: the constructing thread writes
/// every line, so Linux places all its pages on that thread's NUMA node.
/// `Anon` is a demand-paged anonymous mapping whose pages are zero and
/// **untouched** at construction — each page lands on the node of
/// whichever worker writes it first, which is what `--numa` wants: the
/// executor has every pinned worker initialize its own partition's
/// element range, so each partition's lines live in that socket's DRAM.
enum Lines {
    Owned(Vec<ValueLine>),
    /// Mapping plus line count (the map is sized in whole lines).
    Anon(MmapMut, usize),
}

impl Lines {
    #[inline]
    fn as_slice(&self) -> &[ValueLine] {
        match self {
            Lines::Owned(v) => v,
            // SAFETY: the map holds `nlines * 64` zero-initialized bytes
            // at a 64-byte-aligned base (checked at construction; mmap
            // returns page-aligned memory). Any bit pattern is a valid
            // `[AtomicU32; 16]`, the map is never remapped while
            // borrowed, and all mutation goes through the atomics.
            Lines::Anon(m, nlines) => unsafe {
                std::slice::from_raw_parts(m.as_ptr() as *const ValueLine, *nlines)
            },
        }
    }
}

pub struct SharedValues {
    lines: Lines,
    len: usize,
    lanes: usize,
}

impl SharedValues {
    /// Build from initial raw-bit values (single lane per vertex).
    pub fn from_bits(bits: impl IntoIterator<Item = u32>) -> Self {
        Self::from_bits_lanes(bits, 1)
    }

    /// Build from initial raw-bit values laid out as `lanes`-wide vertex
    /// groups (`bits.len()` must be a multiple of `lanes`). The final
    /// partial line, if any, is zero-padded (the padding is never
    /// addressable through `len`-bounded callers).
    pub fn from_bits_lanes(bits: impl IntoIterator<Item = u32>, lanes: usize) -> Self {
        assert!(crate::engine::lanes::valid_lane_count(lanes), "bad lane count {lanes}");
        let bits: Vec<u32> = bits.into_iter().collect();
        assert_eq!(bits.len() % lanes, 0, "value count must be a multiple of the lane count");
        let len = bits.len();
        let lines: Vec<ValueLine> = (0..len.div_ceil(VALUES_PER_LINE))
            .map(|li| {
                let base = li * VALUES_PER_LINE;
                ValueLine(std::array::from_fn(|i| AtomicU32::new(bits.get(base + i).copied().unwrap_or(0))))
            })
            .collect();
        Self { lines: Lines::Owned(lines), len, lanes }
    }

    /// Zero-initialized array whose pages are **not yet faulted in**:
    /// backed by an anonymous demand-paged mapping, so the first thread
    /// to *write* each 4 KiB page determines which NUMA node its DRAM
    /// comes from. The `--numa` executor allocates both value arrays
    /// this way and has each pinned worker [`Self::store`] its own
    /// partition's initial values before the first round.
    ///
    /// Falls back to the owned (constructing-thread-touched) layout when
    /// the mapping fails or — on the non-Unix vendored fallback — is not
    /// 64-byte aligned; semantics are identical either way, only page
    /// placement differs.
    pub fn zeroed_lanes_first_touch(len: usize, lanes: usize) -> Self {
        assert!(crate::engine::lanes::valid_lane_count(lanes), "bad lane count {lanes}");
        assert_eq!(len % lanes, 0, "value count must be a multiple of the lane count");
        let nlines = len.div_ceil(VALUES_PER_LINE);
        if nlines > 0 {
            if let Ok(m) = MmapMut::map_anon(nlines * crate::CACHE_LINE_BYTES) {
                if m.as_ptr() as usize % crate::CACHE_LINE_BYTES == 0 {
                    return Self { lines: Lines::Anon(m, nlines), len, lanes };
                }
            }
        }
        Self::from_bits_lanes(std::iter::repeat(0).take(len), lanes)
    }

    /// The slot holding element `idx`.
    #[inline]
    fn slot(&self, idx: usize) -> &AtomicU32 {
        debug_assert!(idx < self.len, "element {idx} out of range for len {}", self.len);
        &self.lines.as_slice()[idx / VALUES_PER_LINE].0[idx % VALUES_PER_LINE]
    }

    /// Lanes per vertex group.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of values.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Byte address of element `idx` — for alignment assertions and as
    /// the prefetch target ([`Self::prefetch`]).
    #[inline]
    pub fn addr_of(&self, idx: usize) -> usize {
        self.slot(idx) as *const AtomicU32 as usize
    }

    /// Software-prefetch the cache line holding element `idx` (no-op
    /// off x86-64). A hint only: no memory effects, no ordering.
    #[inline]
    pub fn prefetch(&self, idx: usize) {
        crate::engine::kernels::prefetch_read(self.slot(idx) as *const AtomicU32);
    }

    /// Relaxed load.
    #[inline]
    pub fn load(&self, v: VertexId) -> u32 {
        self.slot(v as usize).load(Ordering::Relaxed)
    }

    /// Relaxed store.
    #[inline]
    pub fn store(&self, v: VertexId, bits: u32) {
        self.slot(v as usize).store(bits, Ordering::Relaxed);
    }

    /// Bulk store of a contiguous run starting at `base` — the delay
    /// buffer flush. Relaxed per-element stores; the compiler vectorizes
    /// this into the aligned wide stores the paper describes.
    #[inline]
    pub fn store_run(&self, base: VertexId, values: &[u32]) {
        for (i, &x) in values.iter().enumerate() {
            self.slot(base as usize + i).store(x, Ordering::Relaxed);
        }
    }

    /// Load vertex `v`'s whole lane group into `out` (length `lanes`).
    #[inline]
    pub fn load_group(&self, v: VertexId, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.lanes);
        let base = v as usize * self.lanes;
        // A group never straddles a line, so one line lookup serves all
        // `lanes` slots.
        let line = &self.lines.as_slice()[base / VALUES_PER_LINE].0;
        let off = base % VALUES_PER_LINE;
        for (l, o) in out.iter_mut().enumerate() {
            *o = line[off + l].load(Ordering::Relaxed);
        }
    }

    /// Store vertex `v`'s whole lane group from `vals` (length `lanes`).
    #[inline]
    pub fn store_group(&self, v: VertexId, vals: &[u32]) {
        debug_assert_eq!(vals.len(), self.lanes);
        let base = v as usize * self.lanes;
        let line = &self.lines.as_slice()[base / VALUES_PER_LINE].0;
        let off = base % VALUES_PER_LINE;
        for (l, &x) in vals.iter().enumerate() {
            line[off + l].store(x, Ordering::Relaxed);
        }
    }

    /// Snapshot into a plain vector.
    pub fn to_vec(&self) -> Vec<u32> {
        (0..self.len).map(|i| self.slot(i).load(Ordering::Relaxed)).collect()
    }

    /// Overwrite all slots from a plain slice (used at sync-round swap).
    pub fn copy_from(&self, bits: &[u32]) {
        assert_eq!(bits.len(), self.len);
        for (i, &b) in bits.iter().enumerate() {
            self.slot(i).store(b, Ordering::Relaxed);
        }
    }
}

/// Reader over the shared array (async + delayed global reads).
pub struct SharedReader<'a>(pub &'a SharedValues);

impl ValueReader for SharedReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }

    #[inline]
    fn prefetch(&mut self, v: VertexId) {
        self.0.prefetch(v as usize);
    }
}

/// Reader over an immutable snapshot (sync mode front buffer).
pub struct SliceReader<'a>(pub &'a [u32]);

impl ValueReader for SliceReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0[v as usize]
    }

    #[inline]
    fn prefetch(&mut self, v: VertexId) {
        crate::engine::kernels::prefetch_read(&self.0[v as usize] as *const u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip() {
        let s = SharedValues::from_bits([1, 2, 3]);
        s.store(1, 42);
        assert_eq!(s.load(1), 42);
        assert_eq!(s.to_vec(), vec![1, 42, 3]);
    }

    #[test]
    fn store_run() {
        let s = SharedValues::from_bits(vec![0; 8]);
        s.store_run(2, &[9, 8, 7]);
        assert_eq!(s.to_vec(), vec![0, 0, 9, 8, 7, 0, 0, 0]);
    }

    #[test]
    fn readers() {
        let s = SharedValues::from_bits([10, 20]);
        let mut r = SharedReader(&s);
        assert_eq!(r.read(1), 20);
        let snap = s.to_vec();
        let mut sr = SliceReader(&snap);
        assert_eq!(sr.read(0), 10);
    }

    #[test]
    fn lane_groups_roundtrip() {
        // 3 vertices × 4 lanes.
        let s = SharedValues::from_bits_lanes(vec![0; 12], 4);
        assert_eq!(s.lanes(), 4);
        s.store_group(1, &[10, 11, 12, 13]);
        let mut g = [0u32; 4];
        s.load_group(1, &mut g);
        assert_eq!(g, [10, 11, 12, 13]);
        // Element addressing sees the same interleaved slots.
        assert_eq!(s.load(4), 10);
        assert_eq!(s.load(7), 13);
        s.load_group(0, &mut g);
        assert_eq!(g, [0, 0, 0, 0], "neighboring groups untouched");
    }

    #[test]
    #[should_panic(expected = "multiple of the lane count")]
    fn lane_length_mismatch_rejected() {
        let _ = SharedValues::from_bits_lanes(vec![0; 10], 4);
    }

    #[test]
    fn value_line_type_is_exactly_one_cache_line() {
        assert_eq!(std::mem::align_of::<ValueLine>(), crate::CACHE_LINE_BYTES, "#[repr(align(64))]");
        assert_eq!(std::mem::size_of::<ValueLine>(), crate::CACHE_LINE_BYTES, "no padding between lines");
    }

    #[test]
    fn lane_groups_start_cache_line_aligned_for_every_k() {
        // The SIMD group loads assume every lane group starts at an
        // address aligned to its own width and never crosses a line.
        use crate::engine::lanes;
        for k in lanes::LANE_COUNTS {
            // Odd vertex count: the last line is partial, exercising the
            // zero-padded tail.
            let n = 97usize;
            let s = SharedValues::from_bits_lanes(vec![0u32; n * k], k);
            assert_eq!(s.addr_of(0) % crate::CACHE_LINE_BYTES, 0, "k={k}: base must open a line");
            for v in 0..n as VertexId {
                let a = s.addr_of(lanes::group_base(v, k) as usize);
                assert_eq!(a % (k * 4), 0, "k={k} v={v}: group start unaligned to group width");
                let off = a % crate::CACHE_LINE_BYTES;
                assert!(off + k * 4 <= crate::CACHE_LINE_BYTES, "k={k} v={v}: group straddles a line");
                if (v as usize * k) % crate::VALUES_PER_LINE == 0 {
                    assert_eq!(off, 0, "k={k} v={v}: line-opening group must start the line");
                }
            }
        }
    }

    #[test]
    fn partial_tail_line_is_padded_not_lost() {
        // 5 values with k=1: one line backs them, padding unaddressed.
        let s = SharedValues::from_bits([1, 2, 3, 4, 5]);
        assert_eq!(s.len(), 5);
        assert_eq!(s.to_vec(), vec![1, 2, 3, 4, 5]);
        s.store(4, 99);
        assert_eq!(s.load(4), 99);
    }

    #[test]
    fn prefetch_is_a_pure_hint() {
        let s = SharedValues::from_bits([7, 8, 9]);
        s.prefetch(0);
        s.prefetch(2);
        assert_eq!(s.to_vec(), vec![7, 8, 9], "prefetch must not move bits");
    }

    #[test]
    fn first_touch_array_is_zero_and_fully_functional() {
        // 97 vertices × 4 lanes: partial tail line, lane addressing, and
        // the same alignment guarantees as the owned backing.
        let n = 97usize;
        let s = SharedValues::zeroed_lanes_first_touch(n * 4, 4);
        assert_eq!(s.len(), n * 4);
        assert_eq!(s.lanes(), 4);
        assert_eq!(s.addr_of(0) % crate::CACHE_LINE_BYTES, 0, "base must open a line");
        assert!(s.to_vec().iter().all(|&x| x == 0), "anon pages read as zero");
        s.store(5, 42);
        s.store_run(16, &[1, 2, 3]);
        s.store_group(90, &[7, 8, 9, 10]);
        assert_eq!(s.load(5), 42);
        let mut g = [0u32; 4];
        s.load_group(90, &mut g);
        assert_eq!(g, [7, 8, 9, 10]);
        let v = s.to_vec();
        assert_eq!(&v[16..19], &[1, 2, 3]);
        // Empty array: valid, no mapping needed.
        let e = SharedValues::zeroed_lanes_first_touch(0, 1);
        assert!(e.is_empty());
    }

    #[test]
    fn first_touch_matches_owned_zero_array() {
        // The two backings must be observationally identical — the numa
        // flag can never change results, only page placement.
        let a = SharedValues::zeroed_lanes_first_touch(64, 2);
        let b = SharedValues::from_bits_lanes(vec![0u32; 64], 2);
        for i in 0..64u32 {
            a.store(i, i * 3);
            b.store(i, i * 3);
        }
        assert_eq!(a.to_vec(), b.to_vec());
    }

    #[test]
    fn concurrent_store_load_is_safe() {
        // Smoke test: hammer the same slots from two threads.
        let s = SharedValues::from_bits(vec![0; 64]);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for i in 0..10_000u32 {
                    s.store((i % 64) as u32, i);
                }
            });
            scope.spawn(|| {
                let mut acc = 0u64;
                for i in 0..10_000u32 {
                    acc += s.load((i % 64) as u32) as u64;
                }
                std::hint::black_box(acc);
            });
        });
    }
}
