//! The globally shared vertex-value array.
//!
//! In asynchronous and delayed modes every thread reads the same array
//! that owners write into. Rust-wise those are data races unless the
//! slots are atomics, so values are `AtomicU32` accessed with `Relaxed`
//! ordering — which compiles to plain loads/stores on x86/ARM, exactly
//! the machine behavior the paper's C++ implementation has, without UB.
//! (The algorithms are chaotic-relaxation-tolerant: any interleaving of
//! 32-bit values converges; see Chazan & Miranker, ref 6 of the paper.)

use std::sync::atomic::{AtomicU32, Ordering};

use crate::graph::VertexId;

use super::program::ValueReader;

/// Shared value array. Heap layout is 64-byte aligned so partition ranges
/// map cleanly onto cache lines.
///
/// Under multi-query batching ([`crate::engine::lanes`]) the array holds
/// `lanes` interleaved 32-bit values per vertex (vertex-major lane
/// groups: lane `l` of vertex `v` at element `v*lanes + l`). Element
/// indices — [`Self::load`], [`Self::store`], [`Self::store_run`] — are
/// *flat* indices into that layout, which is what the delay buffer
/// stages and flushes; [`Self::load_group`]/[`Self::store_group`]
/// address whole per-vertex groups. `lanes == 1` is the classic
/// single-query array where element index = vertex id.
pub struct SharedValues {
    slots: Vec<AtomicU32>,
    lanes: usize,
}

impl SharedValues {
    /// Build from initial raw-bit values (single lane per vertex).
    pub fn from_bits(bits: impl IntoIterator<Item = u32>) -> Self {
        Self::from_bits_lanes(bits, 1)
    }

    /// Build from initial raw-bit values laid out as `lanes`-wide vertex
    /// groups (`bits.len()` must be a multiple of `lanes`).
    pub fn from_bits_lanes(bits: impl IntoIterator<Item = u32>, lanes: usize) -> Self {
        assert!(crate::engine::lanes::valid_lane_count(lanes), "bad lane count {lanes}");
        let slots: Vec<AtomicU32> = bits.into_iter().map(AtomicU32::new).collect();
        assert_eq!(slots.len() % lanes, 0, "value count must be a multiple of the lane count");
        Self { slots, lanes }
    }

    /// Lanes per vertex group.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of values.
    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Relaxed load.
    #[inline]
    pub fn load(&self, v: VertexId) -> u32 {
        self.slots[v as usize].load(Ordering::Relaxed)
    }

    /// Relaxed store.
    #[inline]
    pub fn store(&self, v: VertexId, bits: u32) {
        self.slots[v as usize].store(bits, Ordering::Relaxed);
    }

    /// Bulk store of a contiguous run starting at `base` — the delay
    /// buffer flush. Relaxed per-element stores; the compiler vectorizes
    /// this into the aligned wide stores the paper describes.
    #[inline]
    pub fn store_run(&self, base: VertexId, values: &[u32]) {
        for (i, &x) in values.iter().enumerate() {
            self.slots[base as usize + i].store(x, Ordering::Relaxed);
        }
    }

    /// Load vertex `v`'s whole lane group into `out` (length `lanes`).
    #[inline]
    pub fn load_group(&self, v: VertexId, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.lanes);
        let base = v as usize * self.lanes;
        for (l, o) in out.iter_mut().enumerate() {
            *o = self.slots[base + l].load(Ordering::Relaxed);
        }
    }

    /// Store vertex `v`'s whole lane group from `vals` (length `lanes`).
    #[inline]
    pub fn store_group(&self, v: VertexId, vals: &[u32]) {
        debug_assert_eq!(vals.len(), self.lanes);
        let base = v as usize * self.lanes;
        for (l, &x) in vals.iter().enumerate() {
            self.slots[base + l].store(x, Ordering::Relaxed);
        }
    }

    /// Snapshot into a plain vector.
    pub fn to_vec(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.load(Ordering::Relaxed)).collect()
    }

    /// Overwrite all slots from a plain slice (used at sync-round swap).
    pub fn copy_from(&self, bits: &[u32]) {
        assert_eq!(bits.len(), self.slots.len());
        for (s, &b) in self.slots.iter().zip(bits) {
            s.store(b, Ordering::Relaxed);
        }
    }
}

/// Reader over the shared array (async + delayed global reads).
pub struct SharedReader<'a>(pub &'a SharedValues);

impl ValueReader for SharedReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }
}

/// Reader over an immutable snapshot (sync mode front buffer).
pub struct SliceReader<'a>(pub &'a [u32]);

impl ValueReader for SliceReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip() {
        let s = SharedValues::from_bits([1, 2, 3]);
        s.store(1, 42);
        assert_eq!(s.load(1), 42);
        assert_eq!(s.to_vec(), vec![1, 42, 3]);
    }

    #[test]
    fn store_run() {
        let s = SharedValues::from_bits(vec![0; 8]);
        s.store_run(2, &[9, 8, 7]);
        assert_eq!(s.to_vec(), vec![0, 0, 9, 8, 7, 0, 0, 0]);
    }

    #[test]
    fn readers() {
        let s = SharedValues::from_bits([10, 20]);
        let mut r = SharedReader(&s);
        assert_eq!(r.read(1), 20);
        let snap = s.to_vec();
        let mut sr = SliceReader(&snap);
        assert_eq!(sr.read(0), 10);
    }

    #[test]
    fn lane_groups_roundtrip() {
        // 3 vertices × 4 lanes.
        let s = SharedValues::from_bits_lanes(vec![0; 12], 4);
        assert_eq!(s.lanes(), 4);
        s.store_group(1, &[10, 11, 12, 13]);
        let mut g = [0u32; 4];
        s.load_group(1, &mut g);
        assert_eq!(g, [10, 11, 12, 13]);
        // Element addressing sees the same interleaved slots.
        assert_eq!(s.load(4), 10);
        assert_eq!(s.load(7), 13);
        s.load_group(0, &mut g);
        assert_eq!(g, [0, 0, 0, 0], "neighboring groups untouched");
    }

    #[test]
    #[should_panic(expected = "multiple of the lane count")]
    fn lane_length_mismatch_rejected() {
        let _ = SharedValues::from_bits_lanes(vec![0; 10], 4);
    }

    #[test]
    fn concurrent_store_load_is_safe() {
        // Smoke test: hammer the same slots from two threads.
        let s = SharedValues::from_bits(vec![0; 64]);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for i in 0..10_000u32 {
                    s.store((i % 64) as u32, i);
                }
            });
            scope.spawn(|| {
                let mut acc = 0u64;
                for i in 0..10_000u32 {
                    acc += s.load((i % 64) as u32) as u64;
                }
                std::hint::black_box(acc);
            });
        });
    }
}
