//! The globally shared vertex-value array.
//!
//! In asynchronous and delayed modes every thread reads the same array
//! that owners write into. Rust-wise those are data races unless the
//! slots are atomics, so values are `AtomicU32` accessed with `Relaxed`
//! ordering — which compiles to plain loads/stores on x86/ARM, exactly
//! the machine behavior the paper's C++ implementation has, without UB.
//! (The algorithms are chaotic-relaxation-tolerant: any interleaving of
//! 32-bit values converges; see Chazan & Miranker, ref 6 of the paper.)

use std::sync::atomic::{AtomicU32, Ordering};

use crate::graph::VertexId;

use super::program::ValueReader;

/// Shared value array. Heap layout is 64-byte aligned so partition ranges
/// map cleanly onto cache lines.
pub struct SharedValues {
    slots: Vec<AtomicU32>,
}

impl SharedValues {
    /// Build from initial raw-bit values.
    pub fn from_bits(bits: impl IntoIterator<Item = u32>) -> Self {
        Self { slots: bits.into_iter().map(AtomicU32::new).collect() }
    }

    /// Number of values.
    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Relaxed load.
    #[inline]
    pub fn load(&self, v: VertexId) -> u32 {
        self.slots[v as usize].load(Ordering::Relaxed)
    }

    /// Relaxed store.
    #[inline]
    pub fn store(&self, v: VertexId, bits: u32) {
        self.slots[v as usize].store(bits, Ordering::Relaxed);
    }

    /// Bulk store of a contiguous run starting at `base` — the delay
    /// buffer flush. Relaxed per-element stores; the compiler vectorizes
    /// this into the aligned wide stores the paper describes.
    #[inline]
    pub fn store_run(&self, base: VertexId, values: &[u32]) {
        for (i, &x) in values.iter().enumerate() {
            self.slots[base as usize + i].store(x, Ordering::Relaxed);
        }
    }

    /// Snapshot into a plain vector.
    pub fn to_vec(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.load(Ordering::Relaxed)).collect()
    }

    /// Overwrite all slots from a plain slice (used at sync-round swap).
    pub fn copy_from(&self, bits: &[u32]) {
        assert_eq!(bits.len(), self.slots.len());
        for (s, &b) in self.slots.iter().zip(bits) {
            s.store(b, Ordering::Relaxed);
        }
    }
}

/// Reader over the shared array (async + delayed global reads).
pub struct SharedReader<'a>(pub &'a SharedValues);

impl ValueReader for SharedReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0.load(v)
    }
}

/// Reader over an immutable snapshot (sync mode front buffer).
pub struct SliceReader<'a>(pub &'a [u32]);

impl ValueReader for SliceReader<'_> {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self.0[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip() {
        let s = SharedValues::from_bits([1, 2, 3]);
        s.store(1, 42);
        assert_eq!(s.load(1), 42);
        assert_eq!(s.to_vec(), vec![1, 42, 3]);
    }

    #[test]
    fn store_run() {
        let s = SharedValues::from_bits(vec![0; 8]);
        s.store_run(2, &[9, 8, 7]);
        assert_eq!(s.to_vec(), vec![0, 0, 9, 8, 7, 0, 0, 0]);
    }

    #[test]
    fn readers() {
        let s = SharedValues::from_bits([10, 20]);
        let mut r = SharedReader(&s);
        assert_eq!(r.read(1), 20);
        let snap = s.to_vec();
        let mut sr = SliceReader(&snap);
        assert_eq!(sr.read(0), 10);
    }

    #[test]
    fn concurrent_store_load_is_safe() {
        // Smoke test: hammer the same slots from two threads.
        let s = SharedValues::from_bits(vec![0; 64]);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for i in 0..10_000u32 {
                    s.store((i % 64) as u32, i);
                }
            });
            scope.spawn(|| {
                let mut acc = 0u64;
                for i in 0..10_000u32 {
                    acc += s.load((i % 64) as u32) as u64;
                }
                std::hint::black_box(acc);
            });
        });
    }
}
