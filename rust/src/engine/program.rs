//! The vertex-program abstraction consumed by both executors.
//!
//! All vertex values are 32-bit (`u32` raw bits) exactly as in the paper
//! (PageRank f32 scores, SSSP u32 distances): δ is specified in 32-bit
//! elements and a cache line holds [`crate::VALUES_PER_LINE`] of them.

use crate::graph::VertexId;

/// Read access to the current vertex values. Implementations: the shared
/// global array (native engine), the double-buffer front (sync mode), the
/// simulator's cache-tracking accessor, and the delay-buffer-aware local
/// reader (§III-C variant).
pub trait ValueReader {
    /// Current value of `v` as raw bits.
    fn read(&mut self, v: VertexId) -> u32;

    /// Hint that `v` will be read shortly (single-lane twin of
    /// [`crate::engine::lanes::LaneReader::prefetch_group`]). Native
    /// readers issue a software prefetch; the default no-op serves the
    /// simulator — a prefetch is a hint, charges nothing — and closure
    /// readers.
    #[inline]
    fn prefetch(&mut self, _v: VertexId) {}
}

/// Blanket impl so plain closures can be readers in tests.
impl<F: FnMut(VertexId) -> u32> ValueReader for F {
    #[inline]
    fn read(&mut self, v: VertexId) -> u32 {
        self(v)
    }
}

/// A pull-style iterative algorithm.
///
/// Programs are immutable and shared across threads; per-vertex state
/// lives in the engine's value array(s).
pub trait VertexProgram: Sync {
    /// Report label ("pagerank", "sssp"…).
    fn name(&self) -> &'static str;

    /// Initial value of vertex `v` (raw bits).
    fn init(&self, v: VertexId) -> u32;

    /// Recompute `v`'s value by pulling current neighbor values through
    /// `reader`. Must read *only* via `reader` so the simulator can
    /// observe every access.
    fn update<R: ValueReader>(&self, v: VertexId, reader: &mut R) -> u32;

    /// Per-vertex contribution to the round's convergence metric.
    /// PageRank: |new − old|; SSSP: 1.0 if changed else 0.0.
    fn delta(&self, old: u32, new: u32) -> f64;

    /// Whether the run has converged given the summed delta of the round.
    fn converged(&self, round_delta: f64) -> bool;

    /// §V future-work extension: when true, values identical to the old
    /// value are not stored at all (no buffer slot, no global write).
    /// The paper's evaluation stores unconditionally; default matches.
    fn conditional_writes(&self) -> bool {
        false
    }

    /// Frontier-scheduling activation semantics: after `v` is updated
    /// from `old` to `new`, should `v`'s out-neighbors be re-swept next
    /// round? The default — activate exactly when the stored bits
    /// changed — preserves the dense sweep's results for every pure pull
    /// program: a vertex none of whose in-neighbors changed recomputes
    /// the identical value, so skipping it is exact. Dense scheduling
    /// never calls this.
    #[inline]
    fn activates(&self, old: u32, new: u32) -> bool {
        old != new
    }

    // ---- batched multi-query lanes (see `engine::lanes`) -------------

    /// Number of value lanes per vertex: 1 for single-query programs,
    /// k for batched programs answering k independent queries in one
    /// sweep. Must satisfy [`crate::engine::lanes::valid_lane_count`].
    fn lanes(&self) -> usize {
        1
    }

    /// Initial value of lane `lane` of vertex `v`. Single-query default:
    /// lane 0 is [`Self::init`].
    fn init_lane(&self, v: VertexId, lane: usize) -> u32 {
        debug_assert_eq!(lane, 0, "single-lane program asked for lane {lane}");
        self.init(v)
    }

    /// Batched update path: recompute the **live** lanes of `v` into
    /// `out` (length [`Self::lanes`]), pulling neighbor lane groups
    /// through `reader`. `out` arrives pre-loaded with `v`'s current
    /// lane values; dead lanes (bits clear in `live`) must be left
    /// untouched so the engine republishes identical bits for them.
    ///
    /// The default recomputes each live lane independently through a
    /// one-lane projection of `reader` — correct for any program, but it
    /// re-reads every neighbor group once per lane. Batched programs
    /// override it to pull each neighbor group **once** and feed all
    /// lanes from it; that amortization is the whole point of lanes.
    fn update_lanes<R: super::lanes::LaneReader>(&self, v: VertexId, reader: &mut R, out: &mut [u32], live: u32) {
        let k = out.len();
        super::lanes::for_each_live(live, |l| {
            let mut proj = super::lanes::LaneProjection { reader: &mut *reader, lane: l, lanes: k };
            out[l] = self.update(v, &mut proj);
        });
    }

    /// Per-lane contribution to lane `lane`'s convergence metric.
    /// Default: the single-query [`Self::delta`] (all lanes share it).
    #[inline]
    fn lane_delta(&self, _lane: usize, old: u32, new: u32) -> f64 {
        self.delta(old, new)
    }

    /// Whether lane `lane` has converged given its summed round delta —
    /// a converged lane drops out of subsequent sweeps (its query is
    /// answered). Default: the single-query [`Self::converged`].
    #[inline]
    fn lane_converged(&self, _lane: usize, lane_round_delta: f64) -> bool {
        self.converged(lane_round_delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal program: value = max of in-neighbors' values (label prop).
    struct MaxProp<'g> {
        g: &'g crate::graph::Csr,
    }

    impl VertexProgram for MaxProp<'_> {
        fn name(&self) -> &'static str {
            "maxprop"
        }
        fn init(&self, v: VertexId) -> u32 {
            v
        }
        fn update<R: ValueReader>(&self, v: VertexId, r: &mut R) -> u32 {
            let mut best = v;
            for &u in self.g.in_neighbors(v) {
                best = best.max(r.read(u));
            }
            best
        }
        fn delta(&self, old: u32, new: u32) -> f64 {
            (old != new) as u32 as f64
        }
        fn converged(&self, d: f64) -> bool {
            d == 0.0
        }
    }

    #[test]
    fn closure_reader_works() {
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (2, 1)]).build();
        let p = MaxProp { g: &g };
        let vals = [5u32, 0, 9];
        let mut reader = |v: VertexId| vals[v as usize];
        assert_eq!(p.update(1, &mut reader), 9);
        assert_eq!(p.update(0, &mut reader), 0);
    }

    #[test]
    fn default_activation_is_on_change() {
        let g = crate::graph::GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let p = MaxProp { g: &g };
        assert!(p.activates(1, 2));
        assert!(!p.activates(7, 7));
    }

    #[test]
    fn default_lane_path_matches_update() {
        // The generic per-lane fallback must reproduce `update` on lane
        // 0 of a single-lane program and leave dead lanes untouched.
        use crate::engine::lanes::LaneReader;
        struct OneLane<'v>(&'v [u32]);
        impl LaneReader for OneLane<'_> {
            fn read_group(&mut self, v: VertexId, out: &mut [u32]) {
                out[0] = self.0[v as usize];
            }
        }
        let g = crate::graph::GraphBuilder::new(3).edges(&[(0, 1), (2, 1)]).build();
        let p = MaxProp { g: &g };
        assert_eq!(p.lanes(), 1);
        let vals = [5u32, 0, 9];
        let mut out = [0u32];
        p.update_lanes(1, &mut OneLane(&vals), &mut out, 0b1);
        assert_eq!(out, [9]);
        let mut frozen = [77u32];
        p.update_lanes(1, &mut OneLane(&vals), &mut frozen, 0b0);
        assert_eq!(frozen, [77], "dead lanes stay frozen");
        assert_eq!(p.init_lane(2, 0), p.init(2));
        assert_eq!(p.lane_delta(0, 1, 2), p.delta(1, 2));
        assert!(p.lane_converged(0, 0.0));
    }
}
