//! The delay buffer — the mechanism behind the paper's δ parameter.
//!
//! Each thread owns one buffer of capacity δ (rounded **up** to a whole
//! number of cache lines, §III-B: "δ is sized … to a multiple of the
//! cache line size so that flushing a full buffer makes maximal use of
//! bringing a cache line in"). As the thread sweeps its contiguous vertex
//! range it pushes each newly computed value; when the buffer fills (or
//! the range ends) the values are copied in one contiguous run into the
//! shared array — a single burst of stores instead of one shared-line
//! invalidation per element.

use crate::graph::VertexId;
use crate::util::aligned::AlignedBuf;
use crate::VALUES_PER_LINE;

use super::shared::SharedValues;

/// Per-thread delay buffer tracking which global range it mirrors.
pub struct DelayBuffer {
    buf: AlignedBuf,
    /// Global index of the first buffered element.
    base: VertexId,
    /// Number of flushes performed (reported in RunResult).
    flushes: u64,
    /// Cache lines dirtied by those flushes (adaptive-δ telemetry).
    lines_flushed: u64,
    /// When true, wall time spent inside [`Self::flush`] accumulates in
    /// `flush_secs` — the adaptive controller's flush-burst cost signal.
    /// Off by default: static modes pay no timing overhead.
    timed: bool,
    flush_secs: f64,
}

/// Round δ up to a whole number of cache lines (and at least one line),
/// as the paper prescribes. δ=0 stays 0 (asynchronous: no buffer).
pub fn round_delta(delta: usize) -> usize {
    if delta == 0 {
        0
    } else {
        delta.div_ceil(VALUES_PER_LINE) * VALUES_PER_LINE
    }
}

impl DelayBuffer {
    /// Buffer with capacity [`round_delta`]`(delta)` elements.
    pub fn new(delta: usize) -> Self {
        Self {
            buf: AlignedBuf::with_capacity(round_delta(delta)),
            base: 0,
            flushes: 0,
            lines_flushed: 0,
            timed: false,
            flush_secs: 0.0,
        }
    }

    /// Capacity after cache-line rounding.
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Replace the backing storage with one of capacity
    /// [`round_delta`]`(delta)`, preserving the flush counters.
    ///
    /// Any values still staged are published to `global` first (charged
    /// to the flush telemetry like every other flush), so a resize can
    /// never lose updates: the adaptive path calls this between rounds
    /// right after the end-of-range flush, but one missed `flush()` in a
    /// future call site must degrade to an extra flush, not abort a
    /// long-lived serving worker. The empty-buffer invariant that used
    /// to be a hard `assert!` survives as a `debug_assert!` on the
    /// post-flush state.
    pub fn resize(&mut self, global: &SharedValues, delta: usize) {
        if !self.buf.is_empty() {
            self.flush(global);
        }
        debug_assert!(self.buf.is_empty(), "flush() must leave the buffer empty");
        let cap = round_delta(delta);
        if cap != self.buf.capacity() {
            self.buf = AlignedBuf::with_capacity(cap);
        }
    }

    /// Enable or disable flush wall-time accounting (see `timed` field).
    pub fn set_timed(&mut self, on: bool) {
        self.timed = on;
    }

    /// Drain the accumulated flush wall time (seconds) since last taken.
    pub fn take_flush_secs(&mut self) -> f64 {
        std::mem::take(&mut self.flush_secs)
    }

    /// Prepare for a sweep that will next write global index `start`.
    pub fn begin(&mut self, start: VertexId) {
        debug_assert!(self.buf.is_empty(), "begin() with unflushed data");
        self.base = start;
    }

    /// Record the newly computed value for the *next* vertex in the
    /// thread's contiguous sweep; flushes first if full. Returns `true`
    /// if a flush happened (callers count contention events).
    ///
    /// With capacity 0 (async mode) the value is stored straight through.
    #[inline]
    pub fn push(&mut self, global: &SharedValues, value: u32) -> bool {
        if self.buf.capacity() == 0 {
            global.store(self.base, value);
            self.base += 1;
            return false;
        }
        let mut flushed = false;
        if self.buf.is_full() {
            self.flush(global);
            flushed = true;
        }
        self.buf.push(value);
        flushed
    }

    /// Publish all buffered values to the shared array.
    pub fn flush(&mut self, global: &SharedValues) {
        if self.buf.is_empty() {
            return;
        }
        let t0 = self.timed.then(std::time::Instant::now);
        let len = self.buf.len();
        global.store_run(self.base, &self.buf);
        let first = self.base as usize / VALUES_PER_LINE;
        let last = (self.base as usize + len - 1) / VALUES_PER_LINE;
        self.lines_flushed += (last - first + 1) as u64;
        self.base += len as VertexId;
        self.buf.clear();
        self.flushes += 1;
        if let Some(t0) = t0 {
            self.flush_secs += t0.elapsed().as_secs_f64();
        }
    }

    /// Conditional-write extension (§V future work): the next vertex in
    /// the sweep keeps its old value, so nothing is staged for it — but
    /// buffered runs must stay contiguous, so any pending values are
    /// published first and the base advances past the skipped slot.
    #[inline]
    pub fn skip(&mut self, global: &SharedValues) {
        self.skip_n(global, 1);
    }

    /// Skip `n` consecutive elements — the lane-group form of
    /// [`Self::skip`]: a batched conditional write skips a whole
    /// `lanes`-wide vertex group at once.
    #[inline]
    pub fn skip_n(&mut self, global: &SharedValues, n: usize) {
        if self.buf.capacity() != 0 {
            self.flush(global);
        }
        self.base += n as VertexId;
    }

    /// Generalized skip for non-contiguous (frontier-scheduled) sweeps:
    /// reposition so the *next* push writes global index `v`. A no-op
    /// when the sweep is already contiguous; otherwise pending values are
    /// published first so flushed runs stay contiguous, exactly like
    /// [`Self::skip`] but jumping an arbitrary gap in O(1).
    #[inline]
    pub fn seek(&mut self, global: &SharedValues, v: VertexId) {
        if self.base + self.buf.len() as VertexId == v {
            return;
        }
        self.flush(global);
        self.base = v;
    }

    /// §III-C local-read variant: if `v` is buffered but unflushed,
    /// return its pending value.
    #[inline]
    pub fn pending(&self, v: VertexId) -> Option<u32> {
        let off = v.checked_sub(self.base)? as usize;
        if off < self.buf.len() {
            Some(self.buf[off])
        } else {
            None
        }
    }

    /// Number of elements currently buffered.
    pub fn pending_len(&self) -> usize {
        self.buf.len()
    }

    /// Flush count so far.
    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    /// Cache lines dirtied by flushes so far.
    pub fn lines_flushed(&self) -> u64 {
        self.lines_flushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding() {
        assert_eq!(round_delta(0), 0);
        assert_eq!(round_delta(1), 16);
        assert_eq!(round_delta(16), 16);
        assert_eq!(round_delta(17), 32);
        assert_eq!(round_delta(32768), 32768);
    }

    #[test]
    fn no_loss_across_flushes() {
        let g = SharedValues::from_bits(vec![0; 100]);
        let mut b = DelayBuffer::new(16);
        b.begin(10);
        for i in 0..50u32 {
            b.push(&g, 1000 + i);
        }
        b.flush(&g);
        let v = g.to_vec();
        for i in 0..50usize {
            assert_eq!(v[10 + i], 1000 + i as u32, "index {i}");
        }
        assert_eq!(v[9], 0);
        assert_eq!(v[60], 0);
        // 50 values, capacity 16: flushes at 16/32/48 + final = 4.
        assert_eq!(b.flushes(), 4);
    }

    #[test]
    fn zero_capacity_is_writethrough() {
        let g = SharedValues::from_bits(vec![0; 8]);
        let mut b = DelayBuffer::new(0);
        b.begin(2);
        b.push(&g, 7);
        b.push(&g, 8);
        assert_eq!(g.to_vec(), vec![0, 0, 7, 8, 0, 0, 0, 0]);
        assert_eq!(b.flushes(), 0);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn pending_lookup() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.begin(5);
        b.push(&g, 100);
        b.push(&g, 101);
        assert_eq!(b.pending(5), Some(100));
        assert_eq!(b.pending(6), Some(101));
        assert_eq!(b.pending(7), None); // not yet written
        assert_eq!(b.pending(4), None); // before base
        b.flush(&g);
        assert_eq!(b.pending(5), None); // flushed
        assert_eq!(g.load(5), 100);
    }

    #[test]
    fn flush_on_empty_is_noop() {
        let g = SharedValues::from_bits(vec![0; 4]);
        let mut b = DelayBuffer::new(16);
        b.begin(0);
        b.flush(&g);
        assert_eq!(b.flushes(), 0);
    }

    #[test]
    fn seek_contiguous_is_noop() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.begin(3);
        b.push(&g, 100);
        b.seek(&g, 4); // next contiguous slot: nothing published
        assert_eq!(b.pending_len(), 1);
        assert_eq!(b.flushes(), 0);
        b.push(&g, 101);
        b.flush(&g);
        assert_eq!(g.load(3), 100);
        assert_eq!(g.load(4), 101);
    }

    #[test]
    fn seek_gap_flushes_then_rebases() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.begin(0);
        b.push(&g, 10);
        b.push(&g, 11);
        b.seek(&g, 40); // jump: pending run [0,1] must publish contiguously
        assert_eq!(b.flushes(), 1);
        assert_eq!(g.load(0), 10);
        assert_eq!(g.load(1), 11);
        b.push(&g, 42);
        b.flush(&g);
        assert_eq!(g.load(40), 42);
        assert_eq!(g.load(2), 0, "gap untouched");
        assert_eq!(g.load(39), 0, "gap untouched");
    }

    #[test]
    fn seek_writethrough_capacity_zero() {
        let g = SharedValues::from_bits(vec![0; 16]);
        let mut b = DelayBuffer::new(0);
        b.begin(0);
        b.seek(&g, 5);
        b.push(&g, 7);
        b.seek(&g, 9);
        b.push(&g, 8);
        assert_eq!(g.load(5), 7);
        assert_eq!(g.load(9), 8);
        assert_eq!(b.flushes(), 0);
    }

    #[test]
    fn resize_preserves_counters() {
        let g = SharedValues::from_bits(vec![0; 128]);
        let mut b = DelayBuffer::new(16);
        b.begin(0);
        for i in 0..20u32 {
            b.push(&g, i);
        }
        b.flush(&g);
        let (f, l) = (b.flushes(), b.lines_flushed());
        assert!(f > 0 && l > 0);
        b.resize(&g, 64);
        assert_eq!(b.capacity(), 64);
        assert_eq!(b.flushes(), f, "counters survive resize");
        assert_eq!(b.lines_flushed(), l);
        b.resize(&g, 0);
        assert_eq!(b.capacity(), 0);
        // Write-through still works after shrinking to async.
        b.begin(100);
        b.push(&g, 7);
        assert_eq!(g.load(100), 7);
        assert_eq!(b.flushes(), f, "δ=0 charges no flushes");
        b.resize(&g, 30);
        assert_eq!(b.capacity(), 32, "resize is cache-line rounded");
    }

    #[test]
    fn resize_with_pending_data_self_flushes() {
        // One missed flush() before a resize must cost an extra flush,
        // not a worker abort: the staged run is published first and the
        // flush is charged to the telemetry counters.
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.set_timed(true);
        b.begin(3);
        b.push(&g, 30);
        b.push(&g, 31);
        b.resize(&g, 32);
        assert_eq!(b.capacity(), 32);
        assert_eq!(g.load(3), 30, "pending values published, not lost");
        assert_eq!(g.load(4), 31);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flushes(), 1, "self-flush charged to telemetry");
        assert_eq!(b.lines_flushed(), 1);
        assert!(b.take_flush_secs() >= 0.0);
        // The next contiguous push lands after the published run.
        b.push(&g, 32);
        b.flush(&g);
        assert_eq!(g.load(5), 32);
    }

    #[test]
    fn skip_n_flushes_and_jumps_group() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.begin(0);
        b.push(&g, 10);
        b.push(&g, 11);
        // Skip a whole 4-lane group: pending run publishes, base jumps 4.
        b.skip_n(&g, 4);
        assert_eq!(b.flushes(), 1);
        assert_eq!(g.load(0), 10);
        assert_eq!(g.load(1), 11);
        b.push(&g, 60);
        b.flush(&g);
        assert_eq!(g.load(6), 60, "base advanced past the skipped group");
        assert_eq!(g.load(2), 0, "skipped slots untouched");
    }

    #[test]
    fn lines_flushed_counts_spanned_lines() {
        let g = SharedValues::from_bits(vec![0; 128]);
        let mut b = DelayBuffer::new(32);
        b.begin(0);
        for i in 0..32u32 {
            b.push(&g, i);
        }
        b.flush(&g);
        assert_eq!(b.flushes(), 1);
        assert_eq!(b.lines_flushed(), 2, "32 aligned values = 2 lines");
        // An unaligned run spanning a line boundary counts both lines.
        b.begin(40);
        b.push(&g, 1);
        b.push(&g, 2);
        b.flush(&g);
        assert_eq!(b.lines_flushed(), 3, "40..42 stays inside one line");
        b.begin(47);
        b.push(&g, 1);
        b.push(&g, 2);
        b.flush(&g);
        assert_eq!(b.lines_flushed(), 5, "47..49 spans two lines");
    }

    #[test]
    fn timed_flushes_accumulate_and_drain() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.set_timed(true);
        b.begin(0);
        for i in 0..40u32 {
            b.push(&g, i);
        }
        b.flush(&g);
        let t = b.take_flush_secs();
        assert!(t >= 0.0);
        assert_eq!(b.take_flush_secs(), 0.0, "drained");
    }

    #[test]
    fn push_signals_flush() {
        let g = SharedValues::from_bits(vec![0; 64]);
        let mut b = DelayBuffer::new(16);
        b.begin(0);
        let mut flushes = 0;
        for i in 0..33u32 {
            if b.push(&g, i) {
                flushes += 1;
            }
        }
        assert_eq!(flushes, 2); // on the 17th and 33rd push
    }
}
