//! Run-level and round-level statistics — what Table I and every figure
//! are built from.

use super::schedule::SchedulePolicy;
use super::ExecutionMode;

/// Per-round record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundStats {
    /// Wall-clock seconds (native) or simulated cycles ÷ clock (sim).
    pub time_s: f64,
    /// Summed convergence metric of the round.
    pub delta: f64,
    /// Delay-buffer flushes across all threads this round.
    pub flushes: u64,
    /// Vertices the round actually swept. Dense rounds touch every
    /// vertex; frontier rounds only the active set — the shrinking
    /// trajectory of this column is the whole point of sparse scheduling.
    pub active: u64,
    /// Chunks executed by a thread other than their owner this round
    /// (zero under the paper's static schedule; see `engine::steal`).
    pub steals: u64,
    /// Per-thread δ in effect during this round under
    /// [`ExecutionMode::Adaptive`] (`delta_trace[t]` = thread `t`'s
    /// delay-buffer capacity, cache-line rounded, 0 = asynchronous).
    /// Empty for every other mode: static δ never changes, so a trace
    /// would carry no information.
    pub delta_trace: Vec<usize>,
    /// Per-lane summed convergence metric of the round under batched
    /// multi-query execution (`lane_deltas[l]` = query l's residual;
    /// exactly 0.0 once the lane has dropped out). Empty for
    /// single-lane runs, where [`Self::delta`] carries the same
    /// information.
    pub lane_deltas: Vec<f64>,
}

/// Result of one engine run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Final vertex values (raw bits; decode via the algorithm wrapper).
    pub values: Vec<u32>,
    pub rounds: Vec<RoundStats>,
    pub mode: ExecutionMode,
    /// Which vertices each round swept (dense / frontier / adaptive).
    pub schedule: SchedulePolicy,
    pub threads: usize,
    /// Value lanes per vertex: 1 for single-query runs, k when the run
    /// batched k queries ([`crate::engine::lanes`]). `values` then holds
    /// `n × lanes` elements, vertex-major (decode via
    /// [`Self::lane_values`]).
    pub lanes: usize,
    /// True if the convergence criterion was met (false = hit max_rounds).
    pub converged: bool,
}

impl RunResult {
    /// Number of rounds executed.
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Total time (sum of round times).
    pub fn total_time(&self) -> f64 {
        self.rounds.iter().map(|r| r.time_s).sum()
    }

    /// Average time per round — the paper's Table I column.
    pub fn avg_round_time(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.total_time() / self.rounds.len() as f64
        }
    }

    /// Total delay-buffer flushes.
    pub fn total_flushes(&self) -> u64 {
        self.rounds.iter().map(|r| r.flushes).sum()
    }

    /// Total stolen chunks across all rounds (zero without `stealing`).
    pub fn total_steals(&self) -> u64 {
        self.rounds.iter().map(|r| r.steals).sum()
    }

    /// Total vertex updates across all rounds. For a dense schedule this
    /// is `rounds × n`; frontier schedules do strictly less work on any
    /// workload that converges non-uniformly.
    pub fn total_active(&self) -> u64 {
        self.rounds.iter().map(|r| r.active).sum()
    }

    /// Per-round active-vertex counts (convenience for reports/tests).
    pub fn active_counts(&self) -> Vec<u64> {
        self.rounds.iter().map(|r| r.active).collect()
    }

    /// Values decoded as f32 (PageRank scores).
    pub fn values_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&b| f32::from_bits(b)).collect()
    }

    /// De-interleave lane `l`'s per-vertex values out of the lane-group
    /// layout (the identity copy for single-lane runs' lane 0).
    pub fn lane_values(&self, l: usize) -> Vec<u32> {
        assert!(l < self.lanes, "lane {l} out of range for {} lanes", self.lanes);
        self.values.iter().skip(l).step_by(self.lanes).copied().collect()
    }

    /// Per-round residuals of lane `l` (empty for single-lane runs) —
    /// the visible evidence that finished queries drop out: a dead
    /// lane's entries are exactly 0.0 from its drop-out round on.
    pub fn lane_delta_trace(&self, l: usize) -> Vec<f64> {
        self.rounds.iter().filter_map(|r| r.lane_deltas.get(l).copied()).collect()
    }

    /// The round after which lane `l` went quiet — its last round with
    /// a non-zero residual (0 for a lane that never produced an
    /// update). This is each query's *settle point*: the serving layer
    /// reports it per query, and the gap between a lane's settle round
    /// and [`Self::num_rounds`] is iteration the per-lane drop-out
    /// saved it from paying.
    pub fn lane_settle_round(&self, l: usize) -> usize {
        let trace = self.lane_delta_trace(l);
        trace.iter().rposition(|&d| d != 0.0).map_or(0, |i| i + 1)
    }

    /// Thread `t`'s per-round δ under the adaptive controller (empty for
    /// non-adaptive runs or out-of-range `t`).
    pub fn delta_trace_of(&self, t: usize) -> Vec<usize> {
        self.rounds.iter().filter_map(|r| r.delta_trace.get(t).copied()).collect()
    }

    /// Package this run's final values as a warm-start seed for an
    /// incremental re-run after graph mutations: values are carried
    /// over verbatim, `dirty` (sorted, deduplicated) becomes the
    /// round-0 frontier. Single-lane runs only — lane groups interleave
    /// k queries whose dirty sets would differ.
    ///
    /// This is the *generic* constructor; it does not apply any
    /// algorithm reset rule. SSSP after deletions needs
    /// [`crate::algorithms::sssp::resume_seed`] (delete-monotonicity
    /// reset); PageRank wants
    /// [`crate::algorithms::pagerank::resume_seed`] (out-degree-aware
    /// dirty expansion).
    pub fn resume_from(&self, dirty: &[u32]) -> super::ResumeSeed {
        assert_eq!(self.lanes, 1, "resume_from requires a single-lane run (got {} lanes)", self.lanes);
        let mut dirty = dirty.to_vec();
        dirty.sort_unstable();
        dirty.dedup();
        super::ResumeSeed { values: self.values.clone(), dirty }
    }

    /// Median δ across threads in the final round — the operating point
    /// the adaptive controller settled on (`None` for non-adaptive runs).
    pub fn final_delta_median(&self) -> Option<usize> {
        let last = self.rounds.last()?;
        if last.delta_trace.is_empty() {
            return None;
        }
        let mut v = last.delta_trace.clone();
        v.sort_unstable();
        Some(v[v.len() / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> RunResult {
        RunResult {
            values: vec![1f32.to_bits(), 2f32.to_bits()],
            rounds: vec![
                RoundStats {
                    time_s: 0.5,
                    delta: 1.0,
                    flushes: 3,
                    active: 2,
                    steals: 1,
                    delta_trace: vec![64, 32],
                    lane_deltas: Vec::new(),
                },
                RoundStats {
                    time_s: 1.5,
                    delta: 0.0,
                    flushes: 2,
                    active: 1,
                    steals: 0,
                    delta_trace: vec![32, 32],
                    lane_deltas: Vec::new(),
                },
            ],
            mode: ExecutionMode::Delayed(64),
            schedule: SchedulePolicy::Frontier,
            threads: 4,
            lanes: 1,
            converged: true,
        }
    }

    #[test]
    fn aggregates() {
        let r = mk();
        assert_eq!(r.num_rounds(), 2);
        assert!((r.total_time() - 2.0).abs() < 1e-12);
        assert!((r.avg_round_time() - 1.0).abs() < 1e-12);
        assert_eq!(r.total_flushes(), 5);
        assert_eq!(r.total_active(), 3);
        assert_eq!(r.total_steals(), 1);
        assert_eq!(r.active_counts(), vec![2, 1]);
        assert_eq!(r.values_f32(), vec![1.0, 2.0]);
        assert_eq!(r.delta_trace_of(0), vec![64, 32]);
        assert_eq!(r.delta_trace_of(1), vec![32, 32]);
        assert!(r.delta_trace_of(2).is_empty());
        assert_eq!(r.final_delta_median(), Some(32));
    }

    #[test]
    fn lane_accessors() {
        let mut r = mk();
        assert_eq!(r.lane_values(0), r.values, "single lane is the identity view");
        assert!(r.lane_delta_trace(0).is_empty(), "single-lane rounds carry no lane residuals");
        // Re-interpret as a 2-lane run over one vertex.
        r.lanes = 2;
        r.rounds[0].lane_deltas = vec![1.0, 0.5];
        r.rounds[1].lane_deltas = vec![0.0, 0.5];
        assert_eq!(r.lane_values(0), vec![1f32.to_bits()]);
        assert_eq!(r.lane_values(1), vec![2f32.to_bits()]);
        assert_eq!(r.lane_delta_trace(0), vec![1.0, 0.0], "lane 0 dropped out after round 0");
        assert_eq!(r.lane_delta_trace(1), vec![0.5, 0.5]);
    }

    #[test]
    fn resume_from_sorts_and_dedups_dirty() {
        let r = mk();
        let seed = r.resume_from(&[1, 0, 1]);
        assert_eq!(seed.values, r.values);
        assert_eq!(seed.dirty, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "single-lane")]
    fn resume_from_rejects_lane_groups() {
        let mut r = mk();
        r.lanes = 2;
        let _ = r.resume_from(&[0]);
    }

    #[test]
    fn empty_rounds() {
        let mut r = mk();
        r.rounds.clear();
        assert_eq!(r.avg_round_time(), 0.0);
        assert_eq!(r.total_active(), 0);
        assert_eq!(r.final_delta_median(), None);
        assert!(r.delta_trace_of(0).is_empty());
    }
}
