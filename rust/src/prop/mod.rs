//! In-tree property-based testing mini-framework.
//!
//! `proptest`/`quickcheck` are unavailable in this offline environment, so
//! this module provides the subset the test suites need: seeded generators
//! built on [`crate::util::rng::SplitMix64`], a `forall` runner that
//! reports the failing case and its seed, and simple linear shrinking for
//! integer-vector inputs.
//!
//! ```
//! use daig::prop::{forall, Gen};
//! forall(64, |g| {
//!     let xs = g.vec_u32(0..100, 0, 1_000);
//!     let mut s = xs.clone();
//!     s.sort_unstable();
//!     s.len() == xs.len()
//! });
//! ```

use crate::util::rng::SplitMix64;
use std::ops::Range;

/// A seeded input generator handed to each property iteration.
pub struct Gen {
    rng: SplitMix64,
    /// Seed that produced this case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), case_seed: seed }
    }

    /// Uniform usize in `range`.
    pub fn usize(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end);
        range.start + self.rng.index(range.end - range.start)
    }

    /// Uniform u32 in `range`.
    pub fn u32(&mut self, range: Range<u32>) -> u32 {
        assert!(range.start < range.end);
        range.start + self.rng.next_below((range.end - range.start) as u64) as u32
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform f32 in [0,1).
    pub fn unit_f32(&mut self) -> f32 {
        self.rng.next_f64() as f32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of u32 with length in `[min_len, max_len]`.
    pub fn vec_u32(&mut self, each: Range<u32>, min_len: usize, max_len: usize) -> Vec<u32> {
        let n = self.usize(min_len..max_len + 1);
        (0..n).map(|_| self.u32(each.clone())).collect()
    }

    /// Random edge list over `n` vertices with `m` edges (may contain
    /// duplicates and self-loops — builders must tolerate both).
    pub fn edges(&mut self, n: usize, m: usize) -> Vec<(u32, u32)> {
        (0..m).map(|_| (self.u32(0..n as u32), self.u32(0..n as u32))).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `prop` for `cases` seeded iterations; panic with the seed of the
/// first failing case. The master seed can be overridden with the
/// `DAIG_PROP_SEED` environment variable to replay a failure.
pub fn forall<F: FnMut(&mut Gen) -> bool>(cases: u32, mut prop: F) {
    let master: u64 = std::env::var("DAIG_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDA16_2021);
    let mut root = SplitMix64::new(master);
    for i in 0..cases {
        let seed = root.next_u64();
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            panic!(
                "property failed on case {i} (case seed {seed:#x}); replay with DAIG_PROP_SEED={master} \
                 and a breakpoint on that case"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so
/// failures can carry a message.
pub fn forall_res<F: FnMut(&mut Gen) -> Result<(), String>>(cases: u32, mut prop: F) {
    let master: u64 = std::env::var("DAIG_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDA16_2021);
    let mut root = SplitMix64::new(master);
    for i in 0..cases {
        let seed = root.next_u64();
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property failed on case {i} (case seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(32, |g| g.usize(1..10) < 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(32, |g| g.u32(0..100) < 90);
    }

    #[test]
    fn generators_respect_bounds() {
        forall(64, |g| {
            let v = g.vec_u32(5..7, 2, 4);
            (2..=4).contains(&v.len()) && v.iter().all(|&x| (5..7).contains(&x))
        });
    }

    #[test]
    fn edges_in_range() {
        forall(16, |g| {
            let n = g.usize(1..50);
            let es = g.edges(n, 100);
            es.iter().all(|&(u, v)| (u as usize) < n && (v as usize) < n)
        });
    }

    #[test]
    fn forall_res_message() {
        let r = std::panic::catch_unwind(|| {
            forall_res(4, |_| Err("boom".to_string()));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("boom"));
    }
}
