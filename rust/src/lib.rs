#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # daig — Delayed Asynchronous Iterative Graph Algorithms
//!
//! A reproduction of *"Delayed Asynchronous Iterative Graph Algorithms"*
//! (Blanco, McMillan, Low — CS.DC 2021) as a production-shaped library.
//!
//! The paper's contribution is a **hybrid execution mode** for pull-style
//! iterative graph algorithms on shared-memory multicores: each thread
//! accumulates its vertex updates in a thread-local, cache-line-aligned
//! *delay buffer* of capacity `δ` elements and flushes it to the globally
//! shared value array when full (or at end of its assigned range). This
//! coalesces the writes that cause cache-line invalidations in fully
//! asynchronous execution, while still propagating fresh values *within*
//! an iteration — unlike the fully synchronous (double-buffered) mode.
//!
//! `δ = 0` ⇒ asynchronous; `δ ≥ per-thread range` ⇒ synchronous.
//!
//! ## Crate layout
//!
//! | module | contents |
//! |---|---|
//! | [`graph`] | CSR/CSC storage, GAP-analog generators, IO, weights, topology metrics |
//! | [`partition`] | static blocked in-degree-balanced partitioning (+ ablations) |
//! | [`engine`] | the three execution modes over a [`engine::VertexProgram`]: a real threaded executor and a deterministic multicore cache simulator |
//! | [`algorithms`] | PageRank, Bellman-Ford SSSP, connected components, BFS + serial oracles |
//! | [`runtime`] | PJRT loader for the AOT-compiled JAX/Pallas dense-block kernels |
//! | [`serve`] | always-on batched query serving: admission, lane packing, version-keyed result cache, latency SLOs, load generation |
//! | [`shard`] | multi-process serving: router + N shard workers, delay-buffer halo exchange over sockets or a deterministic loopback |
//! | [`coordinator`] | experiment orchestration regenerating every table/figure of the paper |
//! | [`util`] | in-tree substrates: deterministic RNG, aligned buffers, JSON, CLI, table formatting |
//! | [`prop`] | in-tree property-based testing mini-framework |
//!
//! ## Quickstart
//!
//! ```
//! use daig::graph::gap::GapGraph;
//! use daig::engine::{ExecutionMode, EngineConfig};
//! use daig::algorithms::pagerank;
//!
//! // A small Kronecker-style graph (GAP "kron" analog), scale 8.
//! let g = GapGraph::Kron.generate(8, 8);
//! let cfg = EngineConfig::new(4, ExecutionMode::Delayed(64));
//! let result = pagerank::run_native(&g, &cfg, &pagerank::PrConfig::default());
//! assert!(result.run.converged);
//! // Dangling mass is redistributed at decode: scores sum to 1 ± ε.
//! let mass: f64 = result.values.iter().map(|v| *v as f64).sum();
//! assert!((mass - 1.0).abs() < 1e-3);
//! ```

pub mod algorithms;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod partition;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod util;

/// Cache line size (bytes) assumed throughout: both evaluation platforms in
/// the paper (Haswell, Cascade Lake) and essentially all x86 parts use 64.
pub const CACHE_LINE_BYTES: usize = 64;

/// Number of 32-bit vertex values per cache line. The paper sizes δ in
/// *elements* as a multiple of this so a flush dirties whole lines.
pub const VALUES_PER_LINE: usize = CACHE_LINE_BYTES / 4;
