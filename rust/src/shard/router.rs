//! The router process: query admission, the per-round barrier, halo
//! relay, and graceful degradation.
//!
//! The cluster is a star — every shard connects only to the router, so
//! a halo from shard A to shard B is one relayed frame and there are no
//! inter-shard wait cycles to deadlock. A job runs as a sequence of
//! global rounds: the router collects one [`Msg::RoundDone`] per live
//! shard (relaying [`Msg::Halo`] frames to their `dest` as they
//! appear), sums the residuals, and either declares convergence
//! ([`wire::JobClass::job_converged`]) or broadcasts [`Msg::Continue`].
//! Because each shard link is FIFO and every halo of round r is relayed
//! before any `Continue`, shards observe a consistent round boundary —
//! on sockets and on the loopback alike.
//!
//! Failure handling: any link-level error (timeout, disconnect, bad
//! frame) marks that shard **dead**. A job in flight when a shard dies
//! is aborted (survivors get [`Msg::Finish`], the caller gets
//! [`ShardError::DeadShard`]); subsequent queries are admitted only if
//! their parameter vertices are owned by live shards, and their results
//! are **degraded**: dead ranges hold the program's initial values,
//! live ranges keep serving ([`JobResult::degraded`]).

use std::time::Duration;

use super::wire::{JobClass, Msg, WIRE_VERSION};
use super::{ShardError, Transport};
use crate::algorithms::{bfs, cc, pagerank, sssp};
use crate::engine::lanes;
use crate::engine::program::VertexProgram;
use crate::graph::{GraphStore, VertexId};
use crate::partition::PartitionMap;

/// One completed sharded job, stitched from per-shard `Values` frames.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Final values, `n × lanes` vertex-major (same layout as
    /// [`crate::engine::RunResult::values`]). Ranges owned by dead
    /// shards hold the program's initial values.
    pub values: Vec<u32>,
    /// Value lanes per vertex.
    pub lanes: usize,
    /// Global rounds executed.
    pub rounds: u32,
    /// Whether the job met its convergence criterion.
    pub converged: bool,
    /// True when at least one shard was dead while the job ran — the
    /// values in dead ranges are init values, not answers.
    pub degraded: bool,
    /// The dead shards at serve time.
    pub dead: Vec<u32>,
    /// Total halo messages shipped by live shards over the job.
    pub halo_msgs: u64,
    /// Total halo entries (vertex lane groups) shipped.
    pub halo_entries: u64,
}

impl JobResult {
    /// De-interleave lane `l` (mirrors
    /// [`crate::engine::RunResult::lane_values`]).
    pub fn lane_values(&self, l: usize) -> Vec<u32> {
        assert!(l < self.lanes);
        self.values.iter().skip(l).step_by(self.lanes).copied().collect()
    }
}

struct ShardLink<T> {
    t: T,
    alive: bool,
}

/// Router over `N` shard links (socket or loopback).
pub struct Router<'g, G, T> {
    g: &'g G,
    pm: PartitionMap,
    links: Vec<ShardLink<T>>,
    /// Per-receive timeout; a shard that stays silent longer is dead.
    pub timeout: Duration,
    /// Safety valve on global rounds per job.
    pub max_rounds: usize,
    next_job: u64,
    nonce: u64,
}

impl<'g, G: GraphStore, T: Transport> Router<'g, G, T> {
    /// Router over `transports` (one per shard, any order — the
    /// handshake sorts them by the shard id each `Hello` declares).
    pub fn new(g: &'g G, transports: Vec<T>) -> Self {
        let shards = transports.len();
        let pm = super::shard_partition(g, shards);
        Self {
            g,
            pm,
            links: transports.into_iter().map(|t| ShardLink { t, alive: true }).collect(),
            timeout: Duration::from_secs(30),
            max_rounds: 10_000,
            next_job: 0,
            nonce: 0,
        }
    }

    /// Collect every shard's `Hello`, verify protocol version and graph
    /// size, and order the links by shard id. Must be called once,
    /// before the first job.
    pub fn handshake(&mut self) -> Result<(), ShardError> {
        let shards = self.links.len();
        let mut by_id: Vec<Option<ShardLink<T>>> = (0..shards).map(|_| None).collect();
        for mut link in self.links.drain(..) {
            let msg = link.t.recv(Some(self.timeout))?;
            let Msg::Hello { shard, n, version } = msg else {
                return Err(ShardError::Protocol(format!("expected Hello, got {msg:?}")));
            };
            if version != WIRE_VERSION {
                return Err(ShardError::Protocol(format!("wire version {version} != {WIRE_VERSION}")));
            }
            if n as usize != self.g.num_vertices() {
                return Err(ShardError::Protocol(format!(
                    "shard {shard} built a {n}-vertex graph, router has {} — generation parameters differ",
                    self.g.num_vertices()
                )));
            }
            let slot = by_id
                .get_mut(shard as usize)
                .ok_or_else(|| ShardError::Protocol(format!("shard id {shard} out of range 0..{shards}")))?;
            if slot.replace(link).is_some() {
                return Err(ShardError::Protocol(format!("duplicate shard id {shard}")));
            }
        }
        self.links = by_id.into_iter().map(Option::unwrap).collect();
        Ok(())
    }

    /// Live shard count.
    pub fn live(&self) -> usize {
        self.links.iter().filter(|l| l.alive).count()
    }

    /// Dead shard ids, ascending.
    pub fn dead(&self) -> Vec<u32> {
        (0..self.links.len() as u32).filter(|&s| !self.links[s as usize].alive).collect()
    }

    /// Whether shard `s` is currently considered alive.
    pub fn is_alive(&self, s: u32) -> bool {
        self.links.get(s as usize).is_some_and(|l| l.alive)
    }

    /// Ping every live shard and mark the silent ones dead. Returns the
    /// live count afterwards. Call between jobs (the links are quiet).
    pub fn heartbeat(&mut self) -> usize {
        self.nonce += 1;
        let nonce = self.nonce;
        for i in 0..self.links.len() {
            if !self.links[i].alive {
                continue;
            }
            let ok = self.links[i].t.send(&Msg::Ping(nonce)).is_ok()
                && matches!(self.links[i].t.recv(Some(self.timeout)), Ok(Msg::Pong(x)) if x == nonce);
            if !ok {
                self.links[i].alive = false;
            }
        }
        self.live()
    }

    /// Failure drill: order shard `s` to exit and mark it dead, so the
    /// degradation path can be exercised deterministically (CI does
    /// this instead of racing a `kill` against the round loop).
    pub fn drill_kill(&mut self, s: u32) {
        if self.is_alive(s) {
            let _ = self.links[s as usize].t.send(&Msg::Shutdown);
            self.links[s as usize].alive = false;
        }
    }

    /// Order every live shard to exit cleanly.
    pub fn shutdown(&mut self) {
        for link in self.links.iter_mut().filter(|l| l.alive) {
            let _ = link.t.send(&Msg::Shutdown);
        }
    }

    /// Run one job to convergence (or `max_rounds`) across the live
    /// shards. Query-level failures ([`ShardError::BadQuery`],
    /// [`ShardError::DeadShard`], [`ShardError::NoLiveShards`]) leave
    /// the cluster serving; a shard dying mid-job aborts the job with
    /// [`ShardError::DeadShard`] and the survivors move on.
    pub fn run_job(&mut self, class: &JobClass) -> Result<JobResult, ShardError> {
        self.validate(class)?;
        if self.live() == 0 {
            return Err(ShardError::NoLiveShards);
        }
        // Admission: every parameter vertex must have a live owner.
        for v in class.param_vertices() {
            let owner = self.pm.owner(v);
            if !self.is_alive(owner) {
                return Err(ShardError::DeadShard { shard: owner });
            }
        }

        let job = self.next_job;
        self.next_job += 1;
        let lanes = class.lanes();

        for i in 0..self.links.len() {
            if self.links[i].alive && self.links[i].t.send(&Msg::Start { job, class: class.clone() }).is_err() {
                self.links[i].alive = false;
                // The dead shard never saw the job; only its ownership
                // matters, and that was checked above — re-check.
                for v in class.param_vertices() {
                    if self.pm.owner(v) == i as u32 {
                        return Err(ShardError::DeadShard { shard: i as u32 });
                    }
                }
            }
        }
        if self.live() == 0 {
            return Err(ShardError::NoLiveShards);
        }

        // Round barrier: one RoundDone per live shard, halos relayed as
        // they appear, then converge-or-Continue.
        let mut rounds = 0u32;
        let mut converged = false;
        let (mut halo_msgs, mut halo_entries) = (0u64, 0u64);
        for round in 0..self.max_rounds as u32 {
            let mut total = 0.0f64;
            let mut lane_sums = vec![0.0f64; lanes];
            halo_msgs = 0;
            halo_entries = 0;
            for i in 0..self.links.len() {
                if !self.links[i].alive {
                    continue;
                }
                match self.collect_round_done(i, job, round) {
                    Ok((delta, lane_deltas, msgs, entries)) => {
                        total += delta;
                        if lane_deltas.len() == lanes {
                            for (s, d) in lane_sums.iter_mut().zip(&lane_deltas) {
                                *s += d;
                            }
                        } else {
                            // Single-lane shards report no lane split.
                            lane_sums[0] += delta;
                        }
                        halo_msgs += msgs;
                        halo_entries += entries;
                    }
                    Err(e) => {
                        self.links[i].alive = false;
                        self.abort_job(job);
                        return Err(match e {
                            ShardError::Timeout | ShardError::Disconnected | ShardError::Io(_) | ShardError::Protocol(_) => {
                                ShardError::DeadShard { shard: i as u32 }
                            }
                            other => other,
                        });
                    }
                }
            }
            rounds = round + 1;
            if class.job_converged(total, &lane_sums) {
                converged = true;
                break;
            }
            if rounds as usize >= self.max_rounds {
                break;
            }
            for i in 0..self.links.len() {
                if self.links[i].alive && self.links[i].t.send(&Msg::Continue { job, round: round + 1 }).is_err() {
                    self.links[i].alive = false;
                    self.abort_job(job);
                    return Err(ShardError::DeadShard { shard: i as u32 });
                }
            }
        }

        // Collect the final values; dead ranges stay at init.
        let mut values = init_values(self.g, class);
        for i in 0..self.links.len() {
            if !self.links[i].alive {
                continue;
            }
            if self.links[i].t.send(&Msg::Finish { job, converged, rounds }).is_err() {
                self.links[i].alive = false;
                continue;
            }
            match self.collect_values(i, job) {
                Ok((start, vals)) => {
                    let base = start as usize * lanes;
                    values[base..base + vals.len()].copy_from_slice(&vals);
                }
                Err(_) => self.links[i].alive = false,
            }
        }
        if self.live() == 0 {
            return Err(ShardError::NoLiveShards);
        }

        let dead = self.dead();
        Ok(JobResult {
            values,
            lanes,
            rounds,
            converged,
            degraded: !dead.is_empty(),
            dead,
            halo_msgs,
            halo_entries,
        })
    }

    /// Receive from link `i` until its `RoundDone`, relaying halos.
    #[allow(clippy::type_complexity)]
    fn collect_round_done(
        &mut self,
        i: usize,
        job: u64,
        round: u32,
    ) -> Result<(f64, Vec<f64>, u64, u64), ShardError> {
        loop {
            match self.links[i].t.recv(Some(self.timeout))? {
                msg @ Msg::Halo { .. } => {
                    let dest = match &msg {
                        Msg::Halo { dest, .. } => *dest as usize,
                        _ => unreachable!(),
                    };
                    // Updates for a dead shard fall on the floor; its
                    // range is frozen anyway.
                    if self.links[dest].alive && self.links[dest].t.send(&msg).is_err() {
                        self.links[dest].alive = false;
                    }
                }
                Msg::RoundDone { job: j, round: r, delta, lane_deltas, halo_msgs, halo_entries, .. } => {
                    if j != job || r != round {
                        return Err(ShardError::Protocol(format!(
                            "RoundDone for job {j} round {r}, expected job {job} round {round}"
                        )));
                    }
                    return Ok((delta, lane_deltas, halo_msgs, halo_entries));
                }
                Msg::Pong(_) => {}
                m => return Err(ShardError::Protocol(format!("unexpected {m:?} awaiting RoundDone"))),
            }
        }
    }

    /// Receive from link `i` until its `Values` frame.
    fn collect_values(&mut self, i: usize, job: u64) -> Result<(VertexId, Vec<u32>), ShardError> {
        loop {
            match self.links[i].t.recv(Some(self.timeout))? {
                Msg::Values { job: j, start, values, .. } if j == job => return Ok((start, values)),
                // Stragglers from the final round are harmless here:
                // the job is over, their effect is already in `values`.
                Msg::Halo { .. } | Msg::RoundDone { .. } | Msg::Pong(_) => {}
                m => return Err(ShardError::Protocol(format!("unexpected {m:?} awaiting Values"))),
            }
        }
    }

    /// A shard died mid-job: wind the survivors down (they get
    /// `Finish`, answer `Values`, and return to their serve loop ready
    /// for the next job).
    fn abort_job(&mut self, job: u64) {
        for i in 0..self.links.len() {
            if !self.links[i].alive {
                continue;
            }
            if self.links[i].t.send(&Msg::Finish { job, converged: false, rounds: 0 }).is_err() {
                self.links[i].alive = false;
                continue;
            }
            if self.collect_values(i, job).is_err() {
                self.links[i].alive = false;
            }
        }
    }

    /// Query-level validation, before anything is sent.
    fn validate(&self, class: &JobClass) -> Result<(), ShardError> {
        let n = self.g.num_vertices();
        let bad = |s: String| Err(ShardError::BadQuery(s));
        if !lanes::valid_lane_count(class.lanes()) {
            return bad(format!("{} lanes is not a legal lane count", class.lanes()));
        }
        if class.weighted() && !self.g.is_weighted() {
            return bad("SSSP requires a weighted graph".into());
        }
        if let JobClass::Ppr { teleports, .. } = class {
            if teleports.iter().any(|t| t.is_empty()) {
                return bad("empty PPR teleport set".into());
            }
        }
        for v in class.param_vertices() {
            if v as usize >= n {
                return bad(format!("vertex {v} out of range for {n} vertices"));
            }
        }
        Ok(())
    }
}

/// The program's initial values for every vertex and lane — what a dead
/// shard's range reports in a degraded result. Must construct the same
/// programs the worker dispatches to, so frozen ranges are bitwise the
/// worker's round-0 state.
fn init_values<G: GraphStore>(g: &G, class: &JobClass) -> Vec<u32> {
    fn fill<G: GraphStore, P: VertexProgram>(g: &G, p: &P) -> Vec<u32> {
        let (n, k) = (g.num_vertices(), p.lanes());
        let mut out = Vec::with_capacity(n * k);
        for v in 0..n as VertexId {
            for l in 0..k {
                out.push(p.init_lane(v, l));
            }
        }
        out
    }
    match class {
        JobClass::Sssp { sources } if sources.len() == 1 => fill(g, &sssp::Sssp::new(g, sources[0])),
        JobClass::Sssp { sources } => fill(g, &sssp::MultiSssp::new(g, sources)),
        JobClass::Ppr { teleports, damping, epsilon } => {
            let pc = pagerank::PrConfig { damping: *damping, epsilon: *epsilon };
            fill(g, &pagerank::MultiPageRank::new(g, &pc, teleports))
        }
        JobClass::PageRank { damping, epsilon } => {
            let pc = pagerank::PrConfig { damping: *damping, epsilon: *epsilon };
            fill(g, &pagerank::PageRank::new(g, &pc))
        }
        JobClass::Cc => fill(g, &cc::Components::new(g)),
        JobClass::Bfs { source } => fill(g, &bfs::Bfs::new(g, *source)),
    }
}
