//! Message transports: real sockets and a deterministic in-process
//! loopback behind one trait.
//!
//! Both implementations move the *same* [`wire`] frames — the loopback
//! encodes and decodes through the real wire format rather than
//! passing `Msg` values around, so the differential harness exercises
//! every byte of the protocol the sockets do. That is the loopback
//! determinism argument of DESIGN.md §13: channel delivery is FIFO per
//! link exactly like a socket stream, and the router's round barrier
//! (collect every `RoundDone` before any `Continue`) makes cross-link
//! interleaving invisible to the computation.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::Duration;

use super::wire::{self, Msg};
use super::ShardError;

/// One end of a bidirectional, FIFO, framed message link.
pub trait Transport: Send {
    /// Ship one message. Failure means the link is unusable.
    fn send(&mut self, msg: &Msg) -> Result<(), ShardError>;

    /// Receive the next message. `None` blocks until a message or a
    /// link failure; `Some(d)` additionally returns
    /// [`ShardError::Timeout`] if nothing arrives within `d`.
    fn recv(&mut self, timeout: Option<Duration>) -> Result<Msg, ShardError>;
}

// ---- loopback ----

/// In-process transport over byte channels; [`LoopbackTransport::pair`]
/// yields the two connected ends.
pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl LoopbackTransport {
    /// Two connected ends: what one sends, the other receives, in order.
    pub fn pair() -> (Self, Self) {
        let (atx, brx) = mpsc::channel();
        let (btx, arx) = mpsc::channel();
        (Self { tx: atx, rx: arx }, Self { tx: btx, rx: brx })
    }
}

impl Transport for LoopbackTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), ShardError> {
        // Encode through the real wire format so loopback runs cover
        // the same serialization path as socket runs.
        self.tx.send(wire::encode(msg)).map_err(|_| ShardError::Disconnected)
    }

    fn recv(&mut self, timeout: Option<Duration>) -> Result<Msg, ShardError> {
        let payload = match timeout {
            None => self.rx.recv().map_err(|_| ShardError::Disconnected)?,
            Some(d) => self.rx.recv_timeout(d).map_err(|e| match e {
                RecvTimeoutError::Timeout => ShardError::Timeout,
                RecvTimeoutError::Disconnected => ShardError::Disconnected,
            })?,
        };
        wire::decode(&payload)
    }
}

// ---- sockets ----

enum Sock {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Sock {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Sock::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Sock::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl std::io::Read for Sock {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Sock::Unix(s) => s.read(buf),
        }
    }
}

impl std::io::Write for Sock {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Sock::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Sock::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Sock::Unix(s) => s.flush(),
        }
    }
}

/// An address containing `:` is `host:port` TCP; anything else is a
/// Unix-domain socket path.
fn is_tcp(addr: &str) -> bool {
    addr.contains(':')
}

/// Framed message link over TCP or (on Unix) a Unix-domain socket.
pub struct SocketTransport {
    sock: Sock,
    timeout: Option<Duration>,
}

impl SocketTransport {
    /// Connect once to `addr` (`host:port` → TCP, otherwise a
    /// Unix-domain path).
    pub fn connect(addr: &str) -> Result<Self, ShardError> {
        let io = |e: std::io::Error| ShardError::Io(format!("connect {addr}: {e}"));
        let sock = if is_tcp(addr) {
            Sock::Tcp(TcpStream::connect(addr).map_err(io)?)
        } else {
            #[cfg(unix)]
            {
                Sock::Unix(UnixStream::connect(addr).map_err(io)?)
            }
            #[cfg(not(unix))]
            {
                return Err(ShardError::Io(format!("unix-domain path {addr} unsupported on this platform")));
            }
        };
        if let Sock::Tcp(s) = &sock {
            let _ = s.set_nodelay(true); // frames are small; don't batch them
        }
        Ok(Self { sock, timeout: None })
    }

    /// Connect with bounded exponential backoff: `attempts` tries,
    /// sleeping `base`, 2·`base`, 4·`base`, … (capped at 2 s) between
    /// them. This is both the shard's initial connect (the router may
    /// not be up yet) and its rejoin path after a restart.
    pub fn connect_retry(addr: &str, attempts: u32, base: Duration) -> Result<Self, ShardError> {
        assert!(attempts >= 1);
        let mut wait = base;
        let mut last = ShardError::Io("unreachable".into());
        for attempt in 0..attempts {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => last = e,
            }
            if attempt + 1 < attempts {
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_secs(2));
            }
        }
        Err(last)
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), ShardError> {
        wire::write_msg(&mut self.sock, msg)
    }

    fn recv(&mut self, timeout: Option<Duration>) -> Result<Msg, ShardError> {
        if self.timeout != timeout {
            self.sock
                .set_read_timeout(timeout)
                .map_err(|e| ShardError::Io(e.to_string()))?;
            self.timeout = timeout;
        }
        wire::read_msg(&mut self.sock)
    }
}

/// Accepts shard connections for the router side of `daig route`.
pub enum SocketListener {
    /// TCP listener (`host:port` addresses).
    Tcp(TcpListener),
    /// Unix-domain listener (path addresses).
    #[cfg(unix)]
    Unix(UnixListener),
}

impl SocketListener {
    /// Bind `addr` (`host:port` → TCP, otherwise a Unix-domain path; a
    /// stale path from a previous run is removed first).
    pub fn bind(addr: &str) -> Result<Self, ShardError> {
        let io = |e: std::io::Error| ShardError::Io(format!("bind {addr}: {e}"));
        if is_tcp(addr) {
            Ok(SocketListener::Tcp(TcpListener::bind(addr).map_err(io)?))
        } else {
            #[cfg(unix)]
            {
                if std::path::Path::new(addr).exists() {
                    let _ = std::fs::remove_file(addr);
                }
                Ok(SocketListener::Unix(UnixListener::bind(addr).map_err(io)?))
            }
            #[cfg(not(unix))]
            {
                Err(ShardError::Io(format!("unix-domain path {addr} unsupported on this platform")))
            }
        }
    }

    /// Block until the next shard connects.
    pub fn accept(&self) -> Result<SocketTransport, ShardError> {
        let io = |e: std::io::Error| ShardError::Io(format!("accept: {e}"));
        let sock = match self {
            SocketListener::Tcp(l) => {
                let (s, _) = l.accept().map_err(io)?;
                let _ = s.set_nodelay(true);
                Sock::Tcp(s)
            }
            #[cfg(unix)]
            SocketListener::Unix(l) => {
                let (s, _) = l.accept().map_err(io)?;
                Sock::Unix(s)
            }
        };
        Ok(SocketTransport { sock, timeout: None })
    }
}

/// Drain any messages already queued on a loopback link without
/// blocking — the router uses this to scavenge straggler messages after
/// marking a shard dead.
pub fn drain_pending(t: &mut LoopbackTransport) -> usize {
    let mut n = 0;
    loop {
        match t.rx.try_recv() {
            Ok(_) => n += 1,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_is_fifo_and_typed() {
        let (mut a, mut b) = LoopbackTransport::pair();
        a.send(&Msg::Ping(1)).unwrap();
        a.send(&Msg::Ping(2)).unwrap();
        assert_eq!(b.recv(None).unwrap(), Msg::Ping(1));
        assert_eq!(b.recv(None).unwrap(), Msg::Ping(2));
        b.send(&Msg::Pong(2)).unwrap();
        assert_eq!(a.recv(Some(Duration::from_secs(1))).unwrap(), Msg::Pong(2));
    }

    #[test]
    fn loopback_timeout_and_disconnect() {
        let (mut a, b) = LoopbackTransport::pair();
        assert_eq!(a.recv(Some(Duration::from_millis(10))), Err(ShardError::Timeout));
        drop(b);
        assert_eq!(a.recv(Some(Duration::from_millis(10))), Err(ShardError::Disconnected));
        assert_eq!(a.send(&Msg::Shutdown), Err(ShardError::Disconnected));
    }

    #[test]
    fn tcp_roundtrip_and_peer_death() {
        let listener = SocketListener::bind("127.0.0.1:0").unwrap();
        let addr = match &listener {
            SocketListener::Tcp(l) => l.local_addr().unwrap().to_string(),
            #[cfg(unix)]
            _ => unreachable!(),
        };
        let client = std::thread::spawn(move || {
            let mut t = SocketTransport::connect_retry(&addr, 5, Duration::from_millis(10)).unwrap();
            t.send(&Msg::Hello { shard: 0, n: 64, version: wire::WIRE_VERSION }).unwrap();
            assert_eq!(t.recv(Some(Duration::from_secs(5))).unwrap(), Msg::Shutdown);
            // Drop: the server sees Disconnected.
        });
        let mut srv = listener.accept().unwrap();
        assert_eq!(
            srv.recv(Some(Duration::from_secs(5))).unwrap(),
            Msg::Hello { shard: 0, n: 64, version: wire::WIRE_VERSION }
        );
        srv.send(&Msg::Shutdown).unwrap();
        client.join().unwrap();
        assert_eq!(srv.recv(Some(Duration::from_secs(5))), Err(ShardError::Disconnected));
    }

    #[test]
    fn connect_retry_gives_up_with_last_error() {
        // A port that refuses connections immediately.
        let err = SocketTransport::connect_retry("127.0.0.1:1", 2, Duration::from_millis(1));
        assert!(matches!(err, Err(ShardError::Io(_))));
    }

    #[cfg(unix)]
    #[test]
    fn unix_domain_roundtrip() {
        let path = std::env::temp_dir().join(format!("daig-transport-test-{}.sock", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let listener = SocketListener::bind(&path).unwrap();
        let addr = path.clone();
        let client = std::thread::spawn(move || {
            let mut t = SocketTransport::connect_retry(&addr, 5, Duration::from_millis(10)).unwrap();
            t.send(&Msg::Ping(7)).unwrap();
            assert_eq!(t.recv(Some(Duration::from_secs(5))).unwrap(), Msg::Pong(7));
        });
        let mut srv = listener.accept().unwrap();
        assert_eq!(srv.recv(Some(Duration::from_secs(5))).unwrap(), Msg::Ping(7));
        srv.send(&Msg::Pong(7)).unwrap();
        client.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
