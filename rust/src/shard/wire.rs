//! Length-prefixed binary wire format for router↔shard links.
//!
//! Framing (std-only, little-endian throughout):
//!
//! ```text
//! ┌──────────────┬──────────────────────────────────────────────┐
//! │ len: u32 LE  │ payload (len bytes)                          │
//! └──────────────┴──────────────────────────────────────────────┘
//! payload = tag: u8, then the variant's fields in declaration order;
//! Vec<T> = count: u32 LE, then count elements.
//! ```
//!
//! Decoding follows the same discipline as `graph::io`: every length
//! that will size an allocation is validated against the bytes
//! actually present *before* allocating, a frame longer than
//! [`MAX_FRAME`] is rejected at the header, and trailing bytes after a
//! complete message are a hard [`ShardError::Protocol`] error — a
//! truncated or hostile peer produces a typed error, never a panic or
//! an over-allocation.

use std::io::{Read, Write};

use super::ShardError;
use crate::graph::VertexId;

/// Protocol revision carried in `Hello`; bump on any incompatible
/// change to this file.
pub const WIRE_VERSION: u32 = 1;

/// Largest accepted frame payload (64 MiB): comfortably above any
/// `Values` message at supported scales, far below an allocation bomb.
pub const MAX_FRAME: usize = 64 << 20;

/// What one lane group computes — the sharded twin of
/// [`crate::serve::Query`], extended with the single-lane algorithms
/// the differential harness compares (CC, BFS, global PageRank).
#[derive(Debug, Clone, PartialEq)]
pub enum JobClass {
    /// k-lane batched SSSP, one source per lane (weighted graphs only).
    Sssp {
        /// Lane l runs from `sources[l]`.
        sources: Vec<VertexId>,
    },
    /// k-lane personalized PageRank, one teleport set per lane.
    Ppr {
        /// Lane l teleports uniformly into `teleports[l]`.
        teleports: Vec<Vec<VertexId>>,
        /// Damping factor d.
        damping: f32,
        /// Per-lane round-sum |Δ| convergence threshold.
        epsilon: f64,
    },
    /// Global (single-lane) PageRank.
    PageRank {
        /// Damping factor d.
        damping: f32,
        /// Round-sum |Δ| convergence threshold.
        epsilon: f64,
    },
    /// Connected components by min-label propagation.
    Cc,
    /// Level-relaxation BFS.
    Bfs {
        /// Root vertex.
        source: VertexId,
    },
}

impl JobClass {
    /// Value lanes per vertex this job runs with.
    pub fn lanes(&self) -> usize {
        match self {
            JobClass::Sssp { sources } => sources.len(),
            JobClass::Ppr { teleports, .. } => teleports.len(),
            JobClass::PageRank { .. } | JobClass::Cc | JobClass::Bfs { .. } => 1,
        }
    }

    /// Short label for logs and reports.
    pub fn label(&self) -> &'static str {
        match self {
            JobClass::Sssp { .. } => "sssp",
            JobClass::Ppr { .. } => "ppr",
            JobClass::PageRank { .. } => "pagerank",
            JobClass::Cc => "cc",
            JobClass::Bfs { .. } => "bfs",
        }
    }

    /// Every vertex the job's parameters name — the set whose owners
    /// must be alive for the query to be admissible.
    pub fn param_vertices(&self) -> Vec<VertexId> {
        match self {
            JobClass::Sssp { sources } => sources.clone(),
            JobClass::Ppr { teleports, .. } => teleports.iter().flatten().copied().collect(),
            JobClass::Bfs { source } => vec![*source],
            JobClass::PageRank { .. } | JobClass::Cc => Vec::new(),
        }
    }

    /// Whether the job needs edge weights.
    pub fn weighted(&self) -> bool {
        matches!(self, JobClass::Sssp { .. })
    }

    /// Did the summed per-shard round residuals converge? Exact
    /// (min-propagation) classes stop at a zero round; PageRank classes
    /// stop when every lane's round sum is under ε.
    pub fn job_converged(&self, total: f64, lane_sums: &[f64]) -> bool {
        match self {
            JobClass::Sssp { .. } | JobClass::Cc | JobClass::Bfs { .. } => total == 0.0,
            JobClass::PageRank { epsilon, .. } => total < *epsilon,
            JobClass::Ppr { epsilon, .. } => {
                if lane_sums.is_empty() {
                    total < *epsilon
                } else {
                    lane_sums.iter().all(|&s| s < *epsilon)
                }
            }
        }
    }
}

/// Every message the router↔shard protocol exchanges. See the module
/// docs of [`crate::shard`] for who sends what when.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Shard → router, once per connection: identity + graph cross-check.
    Hello {
        /// Sender's shard id.
        shard: u32,
        /// Sender's vertex count (must match the router's graph).
        n: u64,
        /// Sender's [`WIRE_VERSION`].
        version: u32,
    },
    /// Router → shards: begin a job.
    Start {
        /// Job id (monotone per router).
        job: u64,
        /// What to compute.
        class: JobClass,
    },
    /// Shard → router → shard: boundary lane groups. The router relays
    /// by `dest`; `values` is `verts.len() × lanes` elements,
    /// vertex-major.
    Halo {
        /// Job id.
        job: u64,
        /// Shard that should apply these groups.
        dest: u32,
        /// Shard that owns (computed) them.
        src: u32,
        /// Global round the values were produced in.
        round: u32,
        /// Lane width of each entry.
        lanes: u32,
        /// Boundary vertices, in shipping order.
        verts: Vec<VertexId>,
        /// Their lane groups, concatenated.
        values: Vec<u32>,
    },
    /// Shard → router: my part of the round is swept and my halos are
    /// shipped.
    RoundDone {
        /// Job id.
        job: u64,
        /// Sender.
        shard: u32,
        /// Global round just finished.
        round: u32,
        /// Summed convergence metric over the sender's swept vertices.
        delta: f64,
        /// Per-lane residual split of `delta` (empty when lanes = 1).
        lane_deltas: Vec<f64>,
        /// Vertices the sender swept this round.
        active: u64,
        /// Halo messages the sender has shipped so far this job
        /// (cumulative — the final round's value is the job total).
        halo_msgs: u64,
        /// Halo entries (lane groups) shipped so far this job.
        halo_entries: u64,
    },
    /// Router → shards: all halos of the round are relayed; run the
    /// next one.
    Continue {
        /// Job id.
        job: u64,
        /// The round to run next.
        round: u32,
    },
    /// Router → shards: the job is over; reply with `Values`.
    Finish {
        /// Job id.
        job: u64,
        /// Whether the job met its convergence criterion.
        converged: bool,
        /// Global rounds executed.
        rounds: u32,
    },
    /// Shard → router: final owned values (`values` =
    /// owned-range-length × lanes elements starting at vertex `start`).
    Values {
        /// Job id.
        job: u64,
        /// Sender.
        shard: u32,
        /// First owned vertex.
        start: VertexId,
        /// Lane width.
        lanes: u32,
        /// The owned lane groups.
        values: Vec<u32>,
    },
    /// Router → shard: liveness probe (the heartbeat).
    Ping(u64),
    /// Shard → router: heartbeat answer, echoing the nonce.
    Pong(u64),
    /// Router → shard: exit cleanly.
    Shutdown,
    /// Either direction: a typed failure the peer should surface.
    Err {
        /// Coarse machine-readable code.
        code: u32,
        /// Human-readable description.
        text: String,
    },
}

// ---- encoding ----

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f32(&mut self, x: f32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn vec_u32(&mut self, xs: &[u32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x);
        }
    }
    fn vec_f64(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

fn encode_class(e: &mut Enc, c: &JobClass) {
    match c {
        JobClass::Sssp { sources } => {
            e.u8(0);
            e.vec_u32(sources);
        }
        JobClass::Ppr { teleports, damping, epsilon } => {
            e.u8(1);
            e.u32(teleports.len() as u32);
            for t in teleports {
                e.vec_u32(t);
            }
            e.f32(*damping);
            e.f64(*epsilon);
        }
        JobClass::PageRank { damping, epsilon } => {
            e.u8(2);
            e.f32(*damping);
            e.f64(*epsilon);
        }
        JobClass::Cc => e.u8(3),
        JobClass::Bfs { source } => {
            e.u8(4);
            e.u32(*source);
        }
    }
}

/// Serialize `msg` to a payload (no frame header).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut e = Enc(Vec::new());
    match msg {
        Msg::Hello { shard, n, version } => {
            e.u8(1);
            e.u32(*shard);
            e.u64(*n);
            e.u32(*version);
        }
        Msg::Start { job, class } => {
            e.u8(2);
            e.u64(*job);
            encode_class(&mut e, class);
        }
        Msg::Halo { job, dest, src, round, lanes, verts, values } => {
            e.u8(3);
            e.u64(*job);
            e.u32(*dest);
            e.u32(*src);
            e.u32(*round);
            e.u32(*lanes);
            e.vec_u32(verts);
            e.vec_u32(values);
        }
        Msg::RoundDone { job, shard, round, delta, lane_deltas, active, halo_msgs, halo_entries } => {
            e.u8(4);
            e.u64(*job);
            e.u32(*shard);
            e.u32(*round);
            e.f64(*delta);
            e.vec_f64(lane_deltas);
            e.u64(*active);
            e.u64(*halo_msgs);
            e.u64(*halo_entries);
        }
        Msg::Continue { job, round } => {
            e.u8(5);
            e.u64(*job);
            e.u32(*round);
        }
        Msg::Finish { job, converged, rounds } => {
            e.u8(6);
            e.u64(*job);
            e.u8(*converged as u8);
            e.u32(*rounds);
        }
        Msg::Values { job, shard, start, lanes, values } => {
            e.u8(7);
            e.u64(*job);
            e.u32(*shard);
            e.u32(*start);
            e.u32(*lanes);
            e.vec_u32(values);
        }
        Msg::Ping(x) => {
            e.u8(8);
            e.u64(*x);
        }
        Msg::Pong(x) => {
            e.u8(9);
            e.u64(*x);
        }
        Msg::Shutdown => e.u8(10),
        Msg::Err { code, text } => {
            e.u8(11);
            e.u32(*code);
            e.str(text);
        }
    }
    e.0
}

// ---- decoding ----

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

type DResult<T> = Result<T, ShardError>;

fn perr<T>(what: &str) -> DResult<T> {
    Err(ShardError::Protocol(what.to_string()))
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> DResult<&'a [u8]> {
        if self.b.len() - self.pos < n {
            return perr("frame truncated");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> DResult<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> DResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> DResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> DResult<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> DResult<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Read a count and validate it against the bytes actually present
    /// (`elem_bytes` per element) *before* any allocation.
    fn count(&mut self, elem_bytes: usize) -> DResult<usize> {
        let c = self.u32()? as usize;
        let fits = c.checked_mul(elem_bytes).is_some_and(|bytes| bytes <= self.b.len() - self.pos);
        if !fits {
            return perr("count exceeds frame");
        }
        Ok(c)
    }
    fn vec_u32(&mut self) -> DResult<Vec<u32>> {
        let c = self.count(4)?;
        (0..c).map(|_| self.u32()).collect()
    }
    fn vec_f64(&mut self) -> DResult<Vec<f64>> {
        let c = self.count(8)?;
        (0..c).map(|_| self.f64()).collect()
    }
    fn str(&mut self) -> DResult<String> {
        let c = self.count(1)?;
        match std::str::from_utf8(self.take(c)?) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => perr("string is not utf-8"),
        }
    }
}

fn decode_class(d: &mut Dec) -> DResult<JobClass> {
    Ok(match d.u8()? {
        0 => JobClass::Sssp { sources: d.vec_u32()? },
        1 => {
            let k = d.count(4)?; // each set costs at least its count field
            let teleports = (0..k).map(|_| d.vec_u32()).collect::<DResult<Vec<_>>>()?;
            JobClass::Ppr { teleports, damping: d.f32()?, epsilon: d.f64()? }
        }
        2 => JobClass::PageRank { damping: d.f32()?, epsilon: d.f64()? },
        3 => JobClass::Cc,
        4 => JobClass::Bfs { source: d.u32()? },
        t => return perr(&format!("unknown job class tag {t}")),
    })
}

/// Deserialize one payload produced by [`encode`]. Trailing bytes are
/// an error: a frame carries exactly one message.
pub fn decode(payload: &[u8]) -> Result<Msg, ShardError> {
    let mut d = Dec { b: payload, pos: 0 };
    let msg = match d.u8()? {
        1 => Msg::Hello { shard: d.u32()?, n: d.u64()?, version: d.u32()? },
        2 => Msg::Start { job: d.u64()?, class: decode_class(&mut d)? },
        3 => Msg::Halo {
            job: d.u64()?,
            dest: d.u32()?,
            src: d.u32()?,
            round: d.u32()?,
            lanes: d.u32()?,
            verts: d.vec_u32()?,
            values: d.vec_u32()?,
        },
        4 => Msg::RoundDone {
            job: d.u64()?,
            shard: d.u32()?,
            round: d.u32()?,
            delta: d.f64()?,
            lane_deltas: d.vec_f64()?,
            active: d.u64()?,
            halo_msgs: d.u64()?,
            halo_entries: d.u64()?,
        },
        5 => Msg::Continue { job: d.u64()?, round: d.u32()? },
        6 => Msg::Finish { job: d.u64()?, converged: d.u8()? != 0, rounds: d.u32()? },
        7 => Msg::Values { job: d.u64()?, shard: d.u32()?, start: d.u32()?, lanes: d.u32()?, values: d.vec_u32()? },
        8 => Msg::Ping(d.u64()?),
        9 => Msg::Pong(d.u64()?),
        10 => Msg::Shutdown,
        11 => Msg::Err { code: d.u32()?, text: d.str()? },
        t => return perr(&format!("unknown message tag {t}")),
    };
    if d.pos != payload.len() {
        return perr("trailing bytes after message");
    }
    Ok(msg)
}

/// Write one framed message (`len` header + payload) and flush.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<(), ShardError> {
    let payload = encode(msg);
    assert!(payload.len() <= MAX_FRAME, "outgoing frame of {} bytes exceeds MAX_FRAME", payload.len());
    let io = |e: std::io::Error| ShardError::Io(e.to_string());
    w.write_all(&(payload.len() as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&payload).map_err(io)?;
    w.flush().map_err(io)
}

/// Read one framed message. EOF at a frame boundary is
/// [`ShardError::Disconnected`]; EOF inside a frame is a protocol
/// error; a header longer than [`MAX_FRAME`] is rejected before any
/// allocation.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg, ShardError> {
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Err(ShardError::Disconnected),
            Ok(0) => return perr("eof inside frame header"),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) =>
            {
                return Err(ShardError::Timeout)
            }
            Err(e) => return Err(ShardError::Io(e.to_string())),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return perr(&format!("frame of {len} bytes exceeds MAX_FRAME"));
    }
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut payload[got..]) {
            Ok(0) => return perr("eof inside frame payload"),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) =>
            {
                // A read timeout mid-frame still counts as a peer
                // timeout; the caller marks the link dead either way.
                return Err(ShardError::Timeout);
            }
            Err(e) => return Err(ShardError::Io(e.to_string())),
        }
    }
    decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Msg) {
        let bytes = encode(&m);
        assert_eq!(decode(&bytes).unwrap(), m, "roundtrip of {m:?}");
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { shard: 3, n: 1 << 20, version: WIRE_VERSION });
        roundtrip(Msg::Start { job: 7, class: JobClass::Sssp { sources: vec![1, 2, 3] } });
        roundtrip(Msg::Start {
            job: 8,
            class: JobClass::Ppr { teleports: vec![vec![5], vec![6, 7]], damping: 0.85, epsilon: 1e-3 },
        });
        roundtrip(Msg::Start { job: 9, class: JobClass::PageRank { damping: 0.85, epsilon: 1e-4 } });
        roundtrip(Msg::Start { job: 10, class: JobClass::Cc });
        roundtrip(Msg::Start { job: 11, class: JobClass::Bfs { source: 42 } });
        roundtrip(Msg::Halo {
            job: 7,
            dest: 1,
            src: 0,
            round: 4,
            lanes: 2,
            verts: vec![10, 20],
            values: vec![1, 2, 3, 4],
        });
        roundtrip(Msg::RoundDone {
            job: 7,
            shard: 0,
            round: 4,
            delta: 12.5,
            lane_deltas: vec![6.25, 6.25],
            active: 99,
            halo_msgs: 2,
            halo_entries: 17,
        });
        roundtrip(Msg::Continue { job: 7, round: 5 });
        roundtrip(Msg::Finish { job: 7, converged: true, rounds: 9 });
        roundtrip(Msg::Values { job: 7, shard: 1, start: 512, lanes: 2, values: vec![0, 1, 2, 3] });
        roundtrip(Msg::Ping(1234));
        roundtrip(Msg::Pong(1234));
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Err { code: 2, text: "shard 1 is dead".into() });
    }

    #[test]
    fn framed_stream_roundtrip() {
        let msgs =
            vec![Msg::Ping(1), Msg::Start { job: 1, class: JobClass::Cc }, Msg::Shutdown];
        let mut buf = Vec::new();
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        assert!(matches!(read_msg(&mut r), Err(ShardError::Disconnected)), "clean eof at frame boundary");
    }

    #[test]
    fn corrupt_frames_are_typed_errors() {
        // Truncated payload.
        assert!(matches!(decode(&[1, 0, 0]), Err(ShardError::Protocol(_))));
        // Unknown tag.
        assert!(matches!(decode(&[200]), Err(ShardError::Protocol(_))));
        // Count pointing past the frame: must error before allocating.
        let mut bomb = vec![7u8]; // Values
        bomb.extend_from_slice(&0u64.to_le_bytes());
        bomb.extend_from_slice(&0u32.to_le_bytes());
        bomb.extend_from_slice(&0u32.to_le_bytes());
        bomb.extend_from_slice(&1u32.to_le_bytes());
        bomb.extend_from_slice(&u32::MAX.to_le_bytes()); // count = 4 billion
        assert!(matches!(decode(&bomb), Err(ShardError::Protocol(_))));
        // Trailing garbage after a complete message.
        let mut trailing = encode(&Msg::Shutdown);
        trailing.push(0);
        assert!(matches!(decode(&trailing), Err(ShardError::Protocol(_))));
        // Oversized frame header rejected before allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(read_msg(&mut &huge[..]), Err(ShardError::Protocol(_))));
        // Eof inside the header.
        assert!(matches!(read_msg(&mut &[1u8, 0][..]), Err(ShardError::Protocol(_))));
    }

    #[test]
    fn job_class_helpers() {
        let s = JobClass::Sssp { sources: vec![4, 9] };
        assert_eq!(s.lanes(), 2);
        assert!(s.weighted());
        assert_eq!(s.param_vertices(), vec![4, 9]);
        assert!(s.job_converged(0.0, &[0.0, 0.0]));
        assert!(!s.job_converged(1.0, &[1.0, 0.0]));
        let p = JobClass::Ppr { teleports: vec![vec![1], vec![2, 3]], damping: 0.85, epsilon: 1e-3 };
        assert_eq!(p.lanes(), 2);
        assert_eq!(p.param_vertices(), vec![1, 2, 3]);
        assert!(p.job_converged(9.0, &[1e-4, 9e-4]), "per-lane rule, not the total");
        assert!(!p.job_converged(0.0, &[1e-4, 2e-3]));
        assert_eq!(JobClass::Cc.lanes(), 1);
        assert!(JobClass::Cc.param_vertices().is_empty());
    }
}
