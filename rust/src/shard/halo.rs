//! Halo exchange: per-remote-shard delay buffers for boundary lane
//! groups.
//!
//! A vertex is a **boundary vertex** of shard S for remote shard R when
//! it is owned by S and has at least one out-neighbor owned by R — its
//! value feeds R's next sweep. [`BoundaryMap`] classifies every owned
//! vertex once per (graph, partition) into a bitmask of interested
//! remote shards (one bit per shard, hence [`super::MAX_SHARDS`] = 32).
//!
//! [`HaloBuffer`] is the delay buffer of the paper lifted from cache
//! lines to messages: updates destined for one remote shard accumulate
//! locally and ship as a single [`Msg::Halo`] frame when δ elements
//! fill ([`super::halo_delta`]) or the round ends (`flush`). δ = 0
//! degenerates to one message per boundary update (the asynchronous
//! extreme), δ ≥ owned range to one message per round (the synchronous
//! extreme) — the same two poles the in-memory `DelayBuffer` spans,
//! with message count standing in for coherence traffic.

use super::wire::Msg;
use super::{ShardError, Transport};
use crate::engine::delay_buffer::round_delta;
use crate::graph::{GraphStore, VertexId};
use crate::partition::PartitionMap;

/// Which remote shards each owned vertex feeds, as one bitmask per
/// owned vertex (bit R set ⇔ some out-neighbor is owned by shard R).
pub struct BoundaryMap {
    start: VertexId,
    masks: Vec<u32>,
}

impl BoundaryMap {
    /// Classify shard `shard`'s owned range under `pm`. One pass over
    /// the owned vertices' out-edges; out-edges must already be
    /// materialized (`ensure_out_edges`).
    pub fn build<G: GraphStore>(g: &G, pm: &PartitionMap, shard: u32) -> Self {
        let range = pm.range(shard as usize);
        let mut masks = vec![0u32; range.len()];
        for v in range.clone() {
            let mut m = 0u32;
            for u in g.out_neighbors(v) {
                let o = pm.owner(u);
                if o != shard {
                    m |= 1 << o;
                }
            }
            masks[(v - range.start) as usize] = m;
        }
        Self { start: range.start, masks }
    }

    /// Remote-shard bitmask of owned vertex `v` (0 for interior
    /// vertices).
    #[inline]
    pub fn mask(&self, v: VertexId) -> u32 {
        self.masks[(v - self.start) as usize]
    }

    /// How many owned vertices feed at least one remote shard.
    pub fn boundary_count(&self) -> usize {
        self.masks.iter().filter(|&&m| m != 0).count()
    }
}

/// Outgoing halo updates for one (src shard → dest shard) direction of
/// one job: buffered locally, shipped as one `Msg::Halo` per δ-full or
/// flush.
pub struct HaloBuffer {
    job: u64,
    src: u32,
    dest: u32,
    lanes: u32,
    /// Ship threshold in 32-bit elements; 0 ships on every push.
    cap_elems: usize,
    verts: Vec<VertexId>,
    values: Vec<u32>,
    msgs: u64,
    entries: u64,
}

impl HaloBuffer {
    /// Buffer for `src`→`dest` with shipping threshold δ =
    /// [`round_delta`]`(delta)` elements (line-rounded exactly like the
    /// in-memory delay buffer; 0 stays 0).
    pub fn new(job: u64, src: u32, dest: u32, lanes: usize, delta: usize) -> Self {
        Self {
            job,
            src,
            dest,
            lanes: lanes as u32,
            cap_elems: round_delta(delta),
            verts: Vec::new(),
            values: Vec::new(),
            msgs: 0,
            entries: 0,
        }
    }

    /// Buffer vertex `v`'s lane group; ship a message if δ elements are
    /// now pending (or immediately when δ = 0).
    pub fn push<T: Transport>(
        &mut self,
        t: &mut T,
        round: u32,
        v: VertexId,
        group: &[u32],
    ) -> Result<(), ShardError> {
        debug_assert_eq!(group.len(), self.lanes as usize);
        self.verts.push(v);
        self.values.extend_from_slice(group);
        if self.values.len() >= self.cap_elems.max(1) {
            self.ship(t, round)?;
        }
        Ok(())
    }

    /// Ship whatever is pending (the end-of-round flush).
    pub fn flush<T: Transport>(&mut self, t: &mut T, round: u32) -> Result<(), ShardError> {
        if !self.verts.is_empty() {
            self.ship(t, round)?;
        }
        Ok(())
    }

    fn ship<T: Transport>(&mut self, t: &mut T, round: u32) -> Result<(), ShardError> {
        self.msgs += 1;
        self.entries += self.verts.len() as u64;
        let msg = Msg::Halo {
            job: self.job,
            dest: self.dest,
            src: self.src,
            round,
            lanes: self.lanes,
            verts: std::mem::take(&mut self.verts),
            values: std::mem::take(&mut self.values),
        };
        t.send(&msg)
    }

    /// Halo messages shipped so far.
    pub fn msgs(&self) -> u64 {
        self.msgs
    }

    /// Halo entries (vertex lane groups) shipped so far.
    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Entries currently buffered, not yet shipped.
    pub fn pending(&self) -> usize {
        self.verts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, GraphBuilder};
    use crate::partition::PartitionMap;
    use crate::shard::transport::LoopbackTransport;

    /// 0→1→2→3→4→5 path; cut between 2|3 makes vertex 2 the only
    /// boundary vertex of shard 0, feeding shard 1.
    fn path6() -> Csr {
        GraphBuilder::new(6).edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]).build()
    }

    #[test]
    fn boundary_classification() {
        let g = path6();
        g.ensure_out_edges();
        let pm = PartitionMap::from_bounds(vec![0, 3, 6]);
        let b0 = BoundaryMap::build(&g, &pm, 0);
        assert_eq!(b0.mask(0), 0);
        assert_eq!(b0.mask(1), 0);
        assert_eq!(b0.mask(2), 1 << 1, "vertex 2 feeds shard 1");
        assert_eq!(b0.boundary_count(), 1);
        let b1 = BoundaryMap::build(&g, &pm, 1);
        assert_eq!(b1.boundary_count(), 0, "shard 1's range has no out-edges leaving it");
    }

    #[test]
    fn delta_zero_ships_every_push() {
        let (mut tx, mut rx) = LoopbackTransport::pair();
        let mut h = HaloBuffer::new(1, 0, 1, 2, 0);
        h.push(&mut tx, 0, 5, &[10, 11]).unwrap();
        h.push(&mut tx, 0, 6, &[12, 13]).unwrap();
        assert_eq!(h.msgs(), 2);
        assert_eq!(h.entries(), 2);
        for (v, vals) in [(5u32, vec![10u32, 11]), (6, vec![12, 13])] {
            match rx.recv(None).unwrap() {
                Msg::Halo { dest, src, lanes, verts, values, .. } => {
                    assert_eq!((dest, src, lanes), (1, 0, 2));
                    assert_eq!(verts, vec![v]);
                    assert_eq!(values, vals);
                }
                m => panic!("unexpected {m:?}"),
            }
        }
    }

    #[test]
    fn delta_buffers_until_full_then_flushes_rest() {
        let (mut tx, mut rx) = LoopbackTransport::pair();
        // δ = 16 elements (one line) at 8 lanes ⇒ ships every 2 groups.
        let mut h = HaloBuffer::new(1, 0, 1, 8, 16);
        let group = [7u32; 8];
        h.push(&mut tx, 3, 0, &group).unwrap();
        assert_eq!(h.msgs(), 0, "below δ: buffered, not shipped");
        assert_eq!(h.pending(), 1);
        h.push(&mut tx, 3, 1, &group).unwrap();
        assert_eq!(h.msgs(), 1, "δ filled: shipped");
        h.push(&mut tx, 3, 2, &group).unwrap();
        h.flush(&mut tx, 3).unwrap();
        assert_eq!((h.msgs(), h.entries(), h.pending()), (2, 3, 0));
        match rx.recv(None).unwrap() {
            Msg::Halo { verts, values, round, .. } => {
                assert_eq!(verts, vec![0, 1]);
                assert_eq!(values.len(), 16);
                assert_eq!(round, 3);
            }
            m => panic!("unexpected {m:?}"),
        }
        match rx.recv(None).unwrap() {
            Msg::Halo { verts, .. } => assert_eq!(verts, vec![2]),
            m => panic!("unexpected {m:?}"),
        }
        // Empty flush ships nothing.
        h.flush(&mut tx, 4).unwrap();
        assert_eq!(h.msgs(), 2);
    }
}
