//! In-process loopback cluster: the differential harness's way to run
//! the full sharded protocol — wire encoding included — without
//! processes or sockets.
//!
//! [`with_cluster`] spawns one thread per shard running the real
//! [`worker::serve_loop`] over [`LoopbackTransport`] channel pairs,
//! hands the caller a connected, handshaken [`Router`], and joins the
//! shard threads on the way out. Delivery per link is FIFO exactly like
//! a socket stream, and the router's round barrier makes cross-link
//! interleaving invisible — so results here are the results a socket
//! deployment produces, which is what lets the test suite bit-compare
//! sharded runs against single-box runs.

use super::router::{JobResult, Router};
use super::transport::LoopbackTransport;
use super::wire::JobClass;
use super::{ShardError, WorkerCfg};
use crate::engine::EngineConfig;
use crate::graph::GraphStore;

/// Run `f` against a live loopback cluster of `shards` workers, each
/// executing owned sweeps under `ecfg`. The router is already
/// handshaken; shard threads are shut down and joined before this
/// returns. A panicking shard thread propagates its panic here.
pub fn with_cluster<G, R>(
    g: &G,
    shards: usize,
    ecfg: &EngineConfig,
    f: impl FnOnce(&mut Router<'_, G, LoopbackTransport>) -> R,
) -> R
where
    G: GraphStore + Sync,
{
    std::thread::scope(|scope| {
        let mut router_ends = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for shard in 0..shards as u32 {
            let (router_end, worker_end) = LoopbackTransport::pair();
            router_ends.push(router_end);
            let wcfg = WorkerCfg { shard, shards, ecfg: ecfg.clone(), halo_delta: None };
            handles.push(scope.spawn(move || {
                let mut t = worker_end;
                super::worker::serve_loop(&mut t, g, &wcfg)
            }));
        }
        let mut router = Router::new(g, router_ends);
        router.handshake().expect("loopback handshake cannot fail");
        let out = f(&mut router);
        router.shutdown();
        drop(router); // hang up so workers waiting on a dead link exit too
        for h in handles {
            // A worker whose link the router abandoned mid-job exits
            // with a link error; that is not a harness failure.
            let _ = h.join().expect("shard thread panicked");
        }
        out
    })
}

/// One sharded job over a loopback cluster — the single-call form the
/// differential suite and sweeps use.
pub fn run_job_loopback<G: GraphStore + Sync>(
    g: &G,
    shards: usize,
    ecfg: &EngineConfig,
    class: &JobClass,
) -> Result<JobResult, ShardError> {
    with_cluster(g, shards, ecfg, |r| r.run_job(class))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::sssp;
    use crate::engine::ExecutionMode;
    use crate::graph::gap::GapGraph;

    #[test]
    fn loopback_sssp_matches_single_box() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let ecfg = EngineConfig::new(2, ExecutionMode::Synchronous);
        let source = sssp::default_source(&g);
        let sharded = run_job_loopback(&g, 3, &ecfg, &JobClass::Sssp { sources: vec![source] }).unwrap();
        let single = sssp::run_native(&g, source, &ecfg);
        assert_eq!(sharded.values, single.dist, "sharded sync SSSP must be bit-exact");
        assert!(sharded.converged);
        assert!(!sharded.degraded);
    }

    #[test]
    fn cluster_serves_multiple_jobs_and_heartbeats() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let ecfg = EngineConfig::new(2, ExecutionMode::Delayed(64));
        with_cluster(&g, 2, &ecfg, |r| {
            assert_eq!(r.heartbeat(), 2);
            let a = r.run_job(&JobClass::Cc).unwrap();
            let b = r.run_job(&JobClass::Cc).unwrap();
            assert_eq!(a.values, b.values, "same job twice is deterministic");
            assert_eq!(r.heartbeat(), 2, "cluster still alive after jobs");
        });
    }

    #[test]
    fn bad_queries_are_typed_and_non_fatal() {
        let g = GapGraph::Kron.generate(8, 8); // unweighted
        let ecfg = EngineConfig::new(1, ExecutionMode::Asynchronous);
        with_cluster(&g, 2, &ecfg, |r| {
            assert!(matches!(
                r.run_job(&JobClass::Sssp { sources: vec![0] }),
                Err(ShardError::BadQuery(_))
            ));
            assert!(matches!(
                r.run_job(&JobClass::Bfs { source: u32::MAX - 1 }),
                Err(ShardError::BadQuery(_))
            ));
            assert!(matches!(
                r.run_job(&JobClass::Sssp { sources: vec![0, 1, 2] }),
                Err(ShardError::BadQuery(_)) // 3 is not a lane count
            ));
            // The cluster shrugged all of that off.
            let ok = r.run_job(&JobClass::Bfs { source: 0 }).unwrap();
            assert!(ok.converged);
        });
    }
}
