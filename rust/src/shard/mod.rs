//! Sharded multi-process serving: the delay-buffer discipline applied
//! to cross-shard messages (DESIGN.md §13).
//!
//! One **router** process owns query admission and batching (reusing
//! [`crate::serve::BatchFormer`]) and scatters each formed lane group
//! to N **shard** processes as a [`JobClass`]. Every shard owns a
//! contiguous vertex range of the same line-aligned, ownership-exact
//! partition map ([`shard_partition`]) and executes each *global*
//! round over its owned range only, through the engine's restricted
//! sweep ([`crate::engine::EngineConfig`] `restrict`), keeping the rest
//! of the value array as a mirror of the remote shards.
//!
//! Cross-shard value propagation goes through [`halo::HaloBuffer`] — a
//! per-remote-shard delay-buffer variant that accumulates boundary
//! lane groups locally and ships them as length-prefixed binary
//! messages ([`wire`]) when δ lines fill or the round ends. The
//! paper's contention argument (commit whole lines, rarely) becomes a
//! message-amortization argument (commit whole messages, rarely):
//! δ = 0 is one message per boundary update, δ ≥ range is one message
//! per round.
//!
//! Two transports implement one [`transport::Transport`] trait: real
//! TCP/Unix-domain sockets for `daig shard` / `daig route`, and a
//! deterministic in-process loopback ([`cluster`]) the differential
//! harness uses to bit-compare sharded SSSP/CC/BFS against single-box
//! runs across the mode × schedule × stealing matrix.
//!
//! Failure model: the router heartbeats shards, marks one dead on a
//! timeout or socket error, fails queries whose parameters live on a
//! dead shard with the typed [`ShardError::DeadShard`], and keeps
//! serving the rest with the dead range frozen at the program's
//! initial values ([`router::JobResult::degraded`]). A restarted shard
//! reconnects with bounded exponential backoff
//! ([`transport::SocketTransport::connect_retry`]) and re-enters the
//! cluster at its next `Hello` — jobs are stateless across queries, so
//! rejoin needs no state transfer.

pub mod cluster;
pub mod halo;
pub mod router;
pub mod transport;
pub mod wire;
pub mod worker;

pub use cluster::{run_job_loopback, with_cluster};
pub use halo::{BoundaryMap, HaloBuffer};
pub use router::{JobResult, Router};
pub use transport::{LoopbackTransport, SocketListener, SocketTransport, Transport};
pub use wire::{JobClass, Msg};
pub use worker::{serve_loop, WorkerCfg};

use crate::graph::{GraphStore, VertexId};
use crate::partition::PartitionMap;

/// Most shards a cluster supports: boundary-vertex classification keeps
/// one bit per remote shard in a `u32` ([`BoundaryMap`]).
pub const MAX_SHARDS: usize = 32;

/// Typed sharding failures. Query-level errors (`DeadShard`,
/// `BadQuery`, `NoLiveShards`) fail one query while the cluster keeps
/// serving; link-level errors (`Timeout`, `Disconnected`, `Io`,
/// `Protocol`) additionally mark the offending shard dead.
#[derive(Debug, Clone, PartialEq)]
pub enum ShardError {
    /// The query touches vertices owned by a shard that is marked dead.
    DeadShard {
        /// The dead owner.
        shard: u32,
    },
    /// Every shard is dead — nothing can be served.
    NoLiveShards,
    /// A peer did not answer within the configured timeout.
    Timeout,
    /// The peer's connection closed (process exit, kill, network drop).
    Disconnected,
    /// A frame arrived that does not decode to a valid message.
    Protocol(String),
    /// Socket-level failure (bind, connect, read, write).
    Io(String),
    /// The query itself is invalid for this graph (out-of-range vertex,
    /// weighted algorithm on an unweighted graph, too many lanes).
    BadQuery(String),
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::DeadShard { shard } => write!(f, "shard {shard} is dead"),
            ShardError::NoLiveShards => write!(f, "no live shards"),
            ShardError::Timeout => write!(f, "peer timed out"),
            ShardError::Disconnected => write!(f, "peer disconnected"),
            ShardError::Protocol(s) => write!(f, "protocol error: {s}"),
            ShardError::Io(s) => write!(f, "io error: {s}"),
            ShardError::BadQuery(s) => write!(f, "bad query: {s}"),
        }
    }
}

impl std::error::Error for ShardError {}

/// The cluster's vertex→shard ownership map: the paper's contiguous
/// in-degree-balanced blocks with interior bounds rounded to whole
/// value lines — so no cache line of the value array spans two shards
/// for any lane count, and every halo entry's lane group has exactly
/// one owner. Router and shards compute this independently from the
/// same deterministically generated graph and must agree; `Hello`
/// carries the vertex count as a cheap cross-check.
pub fn shard_partition<G: GraphStore>(g: &G, shards: usize) -> PartitionMap {
    assert!(
        (1..=MAX_SHARDS).contains(&shards),
        "shard count {shards} out of range (1..={MAX_SHARDS}: boundary masks are one bit per shard)"
    );
    crate::partition::numa::line_align(crate::partition::blocked::partition(g, shards), g.num_vertices())
}

/// Halo-shipping δ for a shard, in 32-bit elements, derived from the
/// execution mode exactly like the engine's
/// [`crate::engine::EngineConfig::effective_delta`]: synchronous (and
/// adaptive) ship only at round end, asynchronous ships every boundary
/// group immediately, `Delayed(δ)` ships every δ buffered elements.
pub fn halo_delta(mode: crate::engine::ExecutionMode, owned_elems: usize) -> usize {
    use crate::engine::ExecutionMode;
    match mode {
        ExecutionMode::Synchronous | ExecutionMode::Adaptive => owned_elems,
        ExecutionMode::Asynchronous => 0,
        ExecutionMode::Delayed(d) => d.min(owned_elems),
    }
}

/// Owned element range of `shard` under `pm` for `lanes`-wide jobs
/// (start/end scaled into the vertex-major lane-group layout).
pub fn owned_elems(pm: &PartitionMap, shard: u32, lanes: usize) -> std::ops::Range<usize> {
    let r = pm.range(shard as usize);
    r.start as usize * lanes..r.end as usize * lanes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::VALUES_PER_LINE;

    #[test]
    fn shard_partition_is_line_aligned_and_exact() {
        let g = GapGraph::Kron.generate(10, 8);
        for shards in [1, 2, 3, 8] {
            let pm = shard_partition(&g, shards);
            assert_eq!(pm.num_parts(), shards);
            assert_eq!(pm.num_vertices(), g.num_vertices());
            let b = pm.bounds();
            assert_eq!(b[0], 0);
            for &cut in &b[1..shards] {
                assert_eq!(cut as usize % VALUES_PER_LINE, 0, "interior cut {cut} not line-aligned");
            }
            // Ownership-exact: every vertex has exactly one owner.
            for v in [0u32, 1, (g.num_vertices() / 2) as u32, g.num_vertices() as u32 - 1] {
                let o = pm.owner(v) as usize;
                assert!(pm.range(o).contains(&v));
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shard_count_cap() {
        let g = GapGraph::Kron.generate(8, 4);
        shard_partition(&g, MAX_SHARDS + 1);
    }

    #[test]
    fn halo_delta_mirrors_effective_delta() {
        use crate::engine::ExecutionMode as M;
        assert_eq!(halo_delta(M::Synchronous, 500), 500);
        assert_eq!(halo_delta(M::Adaptive, 500), 500);
        assert_eq!(halo_delta(M::Asynchronous, 500), 0);
        assert_eq!(halo_delta(M::Delayed(64), 500), 64);
        assert_eq!(halo_delta(M::Delayed(9999), 500), 500);
    }
}
