//! The shard process: owns one partition, executes global rounds over
//! it through the engine's restricted sweep, ships boundary updates as
//! halos.
//!
//! A shard keeps the **full** `n × lanes` value array; the slice
//! outside its owned range is a mirror of the remote shards, refreshed
//! from inbound [`Msg::Halo`] frames between rounds. Each global round
//! is one `native::run` call with `max_rounds = 1`, `restrict` set to
//! the owned range, and a `ResumeSeed` carrying the mirror plus the
//! round's dirty frontier — so the single-box engine (modes, schedules,
//! stealing, SIMD lane kernels) is reused verbatim; sharding only
//! decides *which* vertices a process sweeps and how updates travel.
//!
//! The per-round protocol, from the shard's side:
//!
//! 1. sweep the owned range (skipped when the dirty set is empty — a
//!    resweep from unchanged inputs recomputes identical values),
//! 2. diff against the mirror, ship changed boundary groups through the
//!    per-remote-shard [`HaloBuffer`]s (δ-full mid-sweep, flush at end),
//! 3. send [`Msg::RoundDone`] with the round's residuals,
//! 4. apply inbound halos until the router's [`Msg::Continue`]
//!    (halos → mirror + next round's frontier) or [`Msg::Finish`]
//!    (reply [`Msg::Values`] with the owned slice).
//!
//! Because the link to the router is FIFO and the router relays every
//! halo of a round before `Continue`, a shard entering round r+1 has
//! applied every remote update from round r — the loopback and socket
//! transports behave identically here, which is what makes the
//! differential harness's bit-comparisons meaningful.

use std::sync::Arc;

use super::halo::{BoundaryMap, HaloBuffer};
use super::wire::{JobClass, Msg, WIRE_VERSION};
use super::{ShardError, Transport};
use crate::algorithms::{bfs, cc, pagerank, sssp};
use crate::engine::{kernels, native, EngineConfig, ResumeSeed, VertexProgram};
use crate::graph::{GraphStore, VertexId};
use crate::partition::PartitionMap;

/// Shard-side configuration for [`serve_loop`].
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    /// This shard's id (0-based).
    pub shard: u32,
    /// Cluster width; must match the router's.
    pub shards: usize,
    /// Engine configuration for the owned sweeps (threads, mode,
    /// schedule, stealing…). `restrict`, `resume`, and `max_rounds` are
    /// overwritten per round.
    pub ecfg: EngineConfig,
    /// Halo-shipping δ override in 32-bit elements; `None` derives it
    /// from the execution mode via [`super::halo_delta`].
    pub halo_delta: Option<usize>,
}

/// How a job ended, from the worker's perspective.
enum JobEnd {
    /// Router sent `Finish`; values were returned. Serve the next job.
    Finished,
    /// Router sent `Shutdown` mid-job; exit the serve loop.
    Shutdown,
}

/// Run the shard protocol over `t` until the router says `Shutdown` or
/// the link dies: `Hello`, then serve `Start`ed jobs one at a time,
/// answering `Ping`s throughout.
pub fn serve_loop<G: GraphStore, T: Transport>(t: &mut T, g: &G, cfg: &WorkerCfg) -> Result<u64, ShardError> {
    let pm = super::shard_partition(g, cfg.shards);
    g.ensure_out_edges();
    let bmap = BoundaryMap::build(g, &pm, cfg.shard);
    t.send(&Msg::Hello { shard: cfg.shard, n: g.num_vertices() as u64, version: WIRE_VERSION })?;
    let mut served = 0u64;
    loop {
        match t.recv(None) {
            Ok(Msg::Start { job, class }) => {
                let end = run_job(t, g, cfg, &pm, &bmap, job, &class)?;
                served += 1;
                if matches!(end, JobEnd::Shutdown) {
                    return Ok(served);
                }
            }
            Ok(Msg::Ping(x)) => t.send(&Msg::Pong(x))?,
            Ok(Msg::Shutdown) | Err(ShardError::Disconnected) => return Ok(served),
            Ok(m) => {
                return Err(ShardError::Protocol(format!("unexpected {m:?} between jobs")));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Dispatch a job class to the generic round driver with the right
/// vertex program. Sharded jobs trust the router's validation (vertex
/// bounds, weightedness) — both sides assert anyway via the program
/// constructors.
fn run_job<G: GraphStore, T: Transport>(
    t: &mut T,
    g: &G,
    cfg: &WorkerCfg,
    pm: &PartitionMap,
    bmap: &BoundaryMap,
    job: u64,
    class: &JobClass,
) -> Result<JobEnd, ShardError> {
    match class {
        JobClass::Sssp { sources } => {
            if sources.len() == 1 {
                drive(t, g, cfg, pm, bmap, job, &sssp::Sssp::new(g, sources[0]))
            } else {
                drive(t, g, cfg, pm, bmap, job, &sssp::MultiSssp::new(g, sources))
            }
        }
        JobClass::Ppr { teleports, damping, epsilon } => {
            let pc = pagerank::PrConfig { damping: *damping, epsilon: *epsilon };
            drive(t, g, cfg, pm, bmap, job, &pagerank::MultiPageRank::new(g, &pc, teleports))
        }
        JobClass::PageRank { damping, epsilon } => {
            let pc = pagerank::PrConfig { damping: *damping, epsilon: *epsilon };
            drive(t, g, cfg, pm, bmap, job, &pagerank::PageRank::new(g, &pc))
        }
        JobClass::Cc => drive(t, g, cfg, pm, bmap, job, &cc::Components::new(g)),
        JobClass::Bfs { source } => drive(t, g, cfg, pm, bmap, job, &bfs::Bfs::new(g, *source)),
    }
}

/// The round driver: one restricted engine call per router `Continue`.
fn drive<G: GraphStore, P: VertexProgram, T: Transport>(
    t: &mut T,
    g: &G,
    cfg: &WorkerCfg,
    pm: &PartitionMap,
    bmap: &BoundaryMap,
    job: u64,
    prog: &P,
) -> Result<JobEnd, ShardError> {
    let n = g.num_vertices();
    let lanes = prog.lanes();
    let owned = pm.range(cfg.shard as usize);
    let owned_elems = super::owned_elems(pm, cfg.shard, lanes);

    // Full-length mirror: owned slice is ours, the rest tracks remote
    // shards through halos.
    let mut mirror: Vec<u32> = Vec::with_capacity(n * lanes);
    for v in 0..n as VertexId {
        for l in 0..lanes {
            mirror.push(prog.init_lane(v, l));
        }
    }

    // Per-remote-shard outgoing buffers, δ from the execution mode (the
    // message-amortization twin of the engine's delay buffers).
    let delta = cfg.halo_delta.unwrap_or_else(|| super::halo_delta(cfg.ecfg.mode, owned_elems.len()));
    let mut halos: Vec<Option<HaloBuffer>> = (0..cfg.shards as u32)
        .map(|r| (r != cfg.shard).then(|| HaloBuffer::new(job, cfg.shard, r, lanes, delta)))
        .collect();

    // Round 0 sweeps the whole owned range, like a cold single-box run.
    let mut dirty: Vec<VertexId> = owned.clone().collect();
    let mut round: u32 = 0;
    loop {
        // 1. Sweep. An empty frontier means every input is unchanged, so
        // the sweep would recompute identical values — skip it.
        let (round_delta, lane_deltas, active) = if dirty.is_empty() {
            (0.0, vec![0.0; if lanes > 1 { lanes } else { 0 }], 0)
        } else {
            let mut ecfg = cfg.ecfg.clone();
            ecfg.max_rounds = 1;
            ecfg.restrict = Some(owned.clone());
            ecfg.resume = Some(Arc::new(ResumeSeed { values: mirror.clone(), dirty: std::mem::take(&mut dirty) }));
            let run = native::run(g, prog, &ecfg);
            let stats = &run.rounds[0];
            let (rd, ld, act) = (stats.delta, stats.lane_deltas.clone(), stats.active);

            // 2. Diff the owned range against the mirror: changed
            // vertices feed next round's frontier and, where the
            // boundary map says so, the halo buffers.
            let mut next = Vec::new();
            for v in owned.clone() {
                let base = v as usize * lanes;
                let group = &run.values[base..base + lanes];
                if group != &mirror[base..base + lanes] {
                    kernels::activate_out_neighbors(g, v, |u| {
                        if owned.contains(&u) {
                            next.push(u);
                        }
                    });
                    let mut mask = bmap.mask(v);
                    while mask != 0 {
                        let r = mask.trailing_zeros();
                        mask &= mask - 1;
                        halos[r as usize].as_mut().unwrap().push(t, round, v, group)?;
                    }
                }
            }
            for h in halos.iter_mut().flatten() {
                h.flush(t, round)?;
            }
            mirror = run.values;
            dirty = next;
            (rd, ld, act)
        };

        // 3. Report the round.
        let (total_msgs, total_entries) = halo_totals(&halos);
        t.send(&Msg::RoundDone {
            job,
            shard: cfg.shard,
            round,
            delta: round_delta,
            lane_deltas,
            active,
            halo_msgs: total_msgs,
            halo_entries: total_entries,
        })?;

        // 4. Absorb halos until the router decides the job's fate.
        loop {
            match t.recv(None)? {
                Msg::Halo { verts, values, lanes: hl, .. } => {
                    debug_assert_eq!(hl as usize, lanes);
                    for (i, &v) in verts.iter().enumerate() {
                        let base = v as usize * lanes;
                        let group = &values[i * lanes..(i + 1) * lanes];
                        if group != &mirror[base..base + lanes] {
                            mirror[base..base + lanes].copy_from_slice(group);
                            kernels::activate_out_neighbors(g, v, |u| {
                                if owned.contains(&u) {
                                    dirty.push(u);
                                }
                            });
                        }
                    }
                }
                Msg::Continue { round: r, .. } => {
                    round = r;
                    dirty.sort_unstable();
                    dirty.dedup();
                    break;
                }
                Msg::Finish { .. } => {
                    t.send(&Msg::Values {
                        job,
                        shard: cfg.shard,
                        start: owned.start,
                        lanes: lanes as u32,
                        values: mirror[owned_elems.clone()].to_vec(),
                    })?;
                    return Ok(JobEnd::Finished);
                }
                Msg::Ping(x) => t.send(&Msg::Pong(x))?,
                Msg::Shutdown => return Ok(JobEnd::Shutdown),
                m => return Err(ShardError::Protocol(format!("unexpected {m:?} mid-job"))),
            }
        }
    }
}

fn halo_totals(halos: &[Option<HaloBuffer>]) -> (u64, u64) {
    halos
        .iter()
        .flatten()
        .fold((0, 0), |(m, e), h| (m + h.msgs(), e + h.entries()))
}
