//! Static vertex→thread partitioning.
//!
//! The paper (§III-A) assigns vertices to threads in **contiguous blocks
//! balanced by aggregate in-degree**, statically for the whole run. That
//! choice is load-bearing: contiguous blocks mean each thread's outputs
//! occupy contiguous memory (so a delay-buffer flush dirties a minimal,
//! contiguous set of cache lines), and in-degree balance equalizes pull
//! work. [`blocked`] implements it; [`equal_vertex`] and [`stripe`] are
//! ablations referenced in DESIGN.md (stripe deliberately destroys flush
//! contiguity to quantify how much the paper's layout matters).

pub mod blocked;
pub mod equal_vertex;
pub mod numa;
pub mod stripe;

use crate::graph::VertexId;

/// A partition of `0..n` into `p` contiguous ranges.
///
/// Invariants (checked by asserts + property tests): ranges are disjoint,
/// cover `0..n`, and are sorted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMap {
    /// `bounds[t]..bounds[t+1]` is thread `t`'s range; len = parts+1.
    bounds: Vec<VertexId>,
}

impl PartitionMap {
    /// Build from explicit bounds (must start at 0, be non-decreasing).
    pub fn from_bounds(bounds: Vec<VertexId>) -> Self {
        assert!(bounds.len() >= 2, "need at least one part");
        assert_eq!(bounds[0], 0);
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds must be sorted");
        Self { bounds }
    }

    /// Build from bounds that may start anywhere — a partition of the
    /// sub-range `bounds[0]..bounds[last]` rather than of `0..n`. Used
    /// by restricted engine runs ([`crate::engine::EngineConfig`]
    /// `restrict`), where one shard's worker gang sweeps only the
    /// vertex range that shard owns. [`Self::owner`] stays valid for
    /// vertices inside the covered range only.
    pub fn from_offset_bounds(bounds: Vec<VertexId>) -> Self {
        assert!(bounds.len() >= 2, "need at least one part");
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds must be sorted");
        Self { bounds }
    }

    /// Number of parts.
    #[inline]
    pub fn num_parts(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total number of vertices covered.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        *self.bounds.last().unwrap() as usize
    }

    /// Range assigned to part `t`.
    #[inline]
    pub fn range(&self, t: usize) -> std::ops::Range<VertexId> {
        self.bounds[t]..self.bounds[t + 1]
    }

    /// Number of vertices in part `t`.
    #[inline]
    pub fn len(&self, t: usize) -> usize {
        (self.bounds[t + 1] - self.bounds[t]) as usize
    }

    /// True if part `t` is empty.
    pub fn is_empty(&self, t: usize) -> bool {
        self.len(t) == 0
    }

    /// Owner of vertex `v` (binary search over bounds).
    #[inline]
    pub fn owner(&self, v: VertexId) -> u32 {
        debug_assert!((v as usize) < self.num_vertices());
        // partition_point returns the first bound > v; minus one is the
        // owning range index.
        (self.bounds.partition_point(|&b| b <= v) - 1) as u32
    }

    /// Largest part size (elements) — used to size "synchronous" δ.
    pub fn max_len(&self) -> usize {
        (0..self.num_parts()).map(|t| self.len(t)).max().unwrap_or(0)
    }

    /// The raw bounds array.
    pub fn bounds(&self) -> &[VertexId] {
        &self.bounds
    }
}

/// Split `range` into chunks of `chunk` elements whose *interior*
/// boundaries sit at global multiples of `chunk` — so when `chunk` is a
/// multiple of the cache line, every boundary between two chunks is
/// line-aligned no matter where the partition starts. The first and last
/// chunk absorb the unaligned edges. Returns the boundary array
/// (`bounds[i]..bounds[i+1]` is chunk `i`); an empty range yields zero
/// chunks.
pub fn chunk_bounds(range: &std::ops::Range<VertexId>, chunk: usize) -> Vec<VertexId> {
    assert!(chunk > 0, "chunk size must be positive");
    if range.start >= range.end {
        return vec![range.start];
    }
    let (start, end) = (range.start as usize, range.end as usize);
    let mut bounds = vec![range.start];
    let mut b = (start / chunk + 1) * chunk;
    while b < end {
        bounds.push(b as VertexId);
        b += chunk;
    }
    bounds.push(range.end);
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_lookup() {
        let pm = PartitionMap::from_bounds(vec![0, 3, 3, 10]);
        assert_eq!(pm.num_parts(), 3);
        assert_eq!(pm.owner(0), 0);
        assert_eq!(pm.owner(2), 0);
        assert_eq!(pm.owner(3), 2); // part 1 is empty
        assert_eq!(pm.owner(9), 2);
        assert!(pm.is_empty(1));
        assert_eq!(pm.max_len(), 7);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_bounds_rejected() {
        PartitionMap::from_bounds(vec![0, 5, 3]);
    }

    #[test]
    fn ranges_cover() {
        let pm = PartitionMap::from_bounds(vec![0, 4, 8, 12]);
        let total: usize = (0..3).map(|t| pm.len(t)).sum();
        assert_eq!(total, pm.num_vertices());
    }

    #[test]
    fn chunk_bounds_aligned_interior() {
        // Partition starting off-alignment: first chunk is short, every
        // interior boundary is a global multiple of the chunk size.
        let b = chunk_bounds(&(10..100), 32);
        assert_eq!(b, vec![10, 32, 64, 96, 100]);
        for w in b.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(chunk_bounds(&(0..64), 32), vec![0, 32, 64]);
        // Range smaller than one chunk: a single chunk.
        assert_eq!(chunk_bounds(&(5..9), 32), vec![5, 9]);
        // Empty range: zero chunks.
        assert_eq!(chunk_bounds(&(7..7), 32), vec![7]);
    }
}
