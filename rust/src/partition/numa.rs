//! NUMA-aware placement: socket topology detection, worker pinning, and
//! line-aligned partition bounds.
//!
//! The engine's memory traffic is dominated by the shared value array,
//! and the paper's contiguous blocked partitions give it a natural
//! placement: thread `t` writes (almost) only its own partition's value
//! lines, so those lines should live in DRAM attached to the socket
//! running `t`. Linux places an anonymous page on the node of the CPU
//! that **first touches** it, so placement needs no allocation API at
//! all — just three ingredients, all here:
//!
//! 1. [`line_align`] — round partition bounds to whole value lines so no
//!    cache line (hence no page) of the value array spans two partitions;
//! 2. [`Topology::detect`] + [`pin_worker`] — pin each worker to the
//!    CPUs of the node that owns its partition (contiguous split, the
//!    same shape as the sim's `Machine::socket_of`);
//! 3. the native executor then writes each partition's initial values
//!    *from its own pinned worker* (and each worker's delay buffer is
//!    already thread-local, so it first-touches correctly for free).
//!
//! Everything degrades gracefully: no `/sys` topology, a single node, or
//! a denied `sched_setaffinity` all turn pinning into a no-op, leaving
//! results and round structure unchanged (placement is a pure
//! performance hint — the differential suite asserts exactly that).
//! There is no libnuma dependency; sysfs + `sched_setaffinity(2)` are
//! all Linux needs, and other platforms compile the no-op path.

use std::path::Path;

use super::PartitionMap;
use crate::graph::VertexId;

/// Upper bound on CPU ids we can pin to (a 1024-bit `cpu_set_t`).
const MAX_CPUS: usize = 1024;

/// CPU lists per NUMA node, indexed by node id (memory-only nodes keep
/// an empty list).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    pub nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// Read the host topology from `/sys/devices/system/node`. `None`
    /// when the hierarchy is absent (non-Linux, containers with a masked
    /// sysfs) or unparsable — callers treat that as "no placement".
    pub fn detect() -> Option<Topology> {
        Self::from_sysfs(Path::new("/sys/devices/system/node"))
    }

    /// Parse a sysfs-shaped directory (`node<K>/cpulist` files). Split
    /// out for tests, which synthesize the hierarchy in a temp dir.
    pub fn from_sysfs(root: &Path) -> Option<Topology> {
        let mut found: Vec<(usize, Vec<usize>)> = Vec::new();
        for entry in std::fs::read_dir(root).ok()? {
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else { continue };
            let Some(cpus) = parse_cpulist(list.trim()) else { continue };
            found.push((idx, cpus));
        }
        if found.is_empty() {
            return None;
        }
        found.sort_by_key(|&(i, _)| i);
        Some(Topology { nodes: found.into_iter().map(|(_, c)| c).collect() })
    }

    /// Nodes that actually have CPUs (placement targets).
    pub fn cpu_nodes(&self) -> Vec<&[usize]> {
        self.nodes.iter().filter(|c| !c.is_empty()).map(|c| c.as_slice()).collect()
    }
}

/// Parse a sysfs cpulist (`"0-15,32-47"`, `"3"`, `""`). `None` on
/// malformed input; an empty string is a valid empty list (memory-only
/// nodes have one).
pub fn parse_cpulist(s: &str) -> Option<Vec<usize>> {
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((a, b)) => {
                let a: usize = a.trim().parse().ok()?;
                let b: usize = b.trim().parse().ok()?;
                if b < a || b >= MAX_CPUS {
                    return None;
                }
                cpus.extend(a..=b);
            }
            None => {
                let c: usize = part.parse().ok()?;
                if c >= MAX_CPUS {
                    return None;
                }
                cpus.push(c);
            }
        }
    }
    Some(cpus)
}

/// Node owning partition `t` of `parts`: contiguous even split, the same
/// shape as the sim's `Machine::socket_of` (threads 0..parts/nodes on
/// node 0, and so on).
pub fn node_of_part(t: usize, parts: usize, nodes: usize) -> usize {
    debug_assert!(t < parts && nodes > 0);
    (t * nodes / parts.max(1)).min(nodes - 1)
}

/// Pin the calling thread to `cpus`. Returns whether the kernel accepted
/// the mask; `false` (no CPUs in range, syscall denied, non-Linux) means
/// the thread keeps its previous affinity — placement silently off.
#[cfg(target_os = "linux")]
pub fn pin_to_cpus(cpus: &[usize]) -> bool {
    const SET_WORDS: usize = MAX_CPUS / 64;
    let mut mask = [0u64; SET_WORDS];
    let mut any = false;
    for &c in cpus {
        if c < MAX_CPUS {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    extern "C" {
        // glibc/musl: pid 0 = the calling thread. std already links libc.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: mask points at SET_WORDS initialized words and the length
    // matches; the call only reads it.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux: affinity control unavailable; placement is a no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_to_cpus(_cpus: &[usize]) -> bool {
    false
}

/// Pin worker `t` of `parts` to the CPUs of the node owning its
/// partition. `false` = nothing pinned (no topology, a single node — on
/// which first-touch is trivially correct already — or a denied
/// syscall); the caller proceeds identically either way.
pub fn pin_worker(t: usize, parts: usize) -> bool {
    let Some(topo) = Topology::detect() else { return false };
    let nodes = topo.cpu_nodes();
    if nodes.len() < 2 {
        return false;
    }
    pin_to_cpus(nodes[node_of_part(t, parts, nodes.len())])
}

/// Round interior partition bounds to whole value lines
/// ([`crate::VALUES_PER_LINE`] vertices), so no cache line of the value
/// array spans two partitions for *any* lane count k: a lane group
/// boundary at element `v·k` with `v ≡ 0 (mod 16)` is a multiple of
/// `16k`, itself a line multiple for every k dividing 16. This is the
/// precondition that makes per-partition first-touch meaningful —
/// otherwise a page-straddling line would be written by two sockets no
/// matter where its page lives. Nearest-multiple rounding keeps the
/// in-degree balance within half a line per boundary.
pub fn line_align(pm: PartitionMap, n: usize) -> PartitionMap {
    let vpl = crate::VALUES_PER_LINE as VertexId;
    let mut bounds = pm.bounds().to_vec();
    let last = bounds.len() - 1;
    let mut prev: VertexId = 0;
    for b in &mut bounds[1..last] {
        let rounded = (*b + vpl / 2) / vpl * vpl;
        let clamped = rounded.clamp(prev, n as VertexId);
        *b = clamped;
        prev = clamped;
    }
    PartitionMap::from_bounds(bounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::VALUES_PER_LINE;

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3"), Some(vec![0, 1, 2, 3]));
        assert_eq!(parse_cpulist("0-1,4-5"), Some(vec![0, 1, 4, 5]));
        assert_eq!(parse_cpulist("7"), Some(vec![7]));
        assert_eq!(parse_cpulist("0-15,32-47").map(|v| v.len()), Some(32));
        assert_eq!(parse_cpulist(""), Some(vec![]), "memory-only nodes have empty cpulists");
        assert_eq!(parse_cpulist(" 2 , 4 "), Some(vec![2, 4]), "whitespace-tolerant");
        assert_eq!(parse_cpulist("a-b"), None);
        assert_eq!(parse_cpulist("5-2"), None, "descending range");
        assert_eq!(parse_cpulist("0-99999"), None, "beyond the cpu_set_t");
    }

    #[test]
    fn topology_from_synthetic_sysfs() {
        let root = std::env::temp_dir().join("daig-numa-tests").join("two-node");
        for (node, list) in [("node0", "0-3\n"), ("node1", "4-7\n")] {
            let d = root.join(node);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        // Distractor entries a real sysfs has.
        std::fs::create_dir_all(root.join("power")).unwrap();
        let topo = Topology::from_sysfs(&root).unwrap();
        assert_eq!(topo.nodes, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(topo.cpu_nodes().len(), 2);
    }

    #[test]
    fn missing_sysfs_is_none() {
        let root = std::env::temp_dir().join("daig-numa-tests").join("definitely-absent");
        assert_eq!(Topology::from_sysfs(&root), None);
    }

    #[test]
    fn node_split_is_contiguous_and_even() {
        // 8 workers over 2 nodes: 0..4 → node 0, 4..8 → node 1.
        let assigned: Vec<usize> = (0..8).map(|t| node_of_part(t, 8, 2)).collect();
        assert_eq!(assigned, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        // Fewer workers than nodes still lands in range.
        for t in 0..2 {
            assert!(node_of_part(t, 2, 4) < 4);
        }
        // One node: everything on it.
        assert!((0..5).all(|t| node_of_part(t, 5, 1) == 0));
    }

    #[test]
    fn detect_and_pin_never_panic() {
        // Whatever this host looks like, detection and pinning must be
        // infallible-as-in-no-panic; the return values are advisory.
        let _ = Topology::detect();
        let _ = pin_worker(0, 4);
    }

    #[test]
    fn line_align_rounds_interior_bounds() {
        let n = 1000usize;
        let pm = PartitionMap::from_bounds(vec![0, 237, 481, 733, n as VertexId]);
        let aligned = line_align(pm, n);
        let b = aligned.bounds();
        assert_eq!(b[0], 0);
        assert_eq!(*b.last().unwrap() as usize, n, "coverage preserved even when n is off-line");
        for &x in &b[1..b.len() - 1] {
            assert_eq!(x as usize % VALUES_PER_LINE, 0, "interior bound {x} not line-aligned");
        }
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "monotone");
        // 237 → 240, 481 → 480, 733 → 736 (nearest line multiples).
        assert_eq!(&b[1..4], &[240, 480, 736]);
    }

    #[test]
    fn line_align_is_idempotent_and_handles_tiny_graphs() {
        let pm = PartitionMap::from_bounds(vec![0, 240, 480, 1000]);
        let once = line_align(pm.clone(), 1000);
        assert_eq!(once, pm, "already-aligned bounds unchanged");
        // More parts than lines: bounds collapse monotonically, never cross.
        let tiny = PartitionMap::from_bounds(vec![0, 2, 4, 6, 9]);
        let a = line_align(tiny, 9);
        assert!(a.bounds().windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*a.bounds().last().unwrap(), 9);
        let covered: usize = (0..a.num_parts()).map(|t| a.len(t)).sum();
        assert_eq!(covered, 9);
    }
}
