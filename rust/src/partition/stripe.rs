//! Ablation: striped (round-robin) vertex assignment.
//!
//! The engine's delay buffers rely on each thread owning a *contiguous*
//! output range, so striping is modeled as a **relabeling**: vertex ids
//! are permuted so that consecutive original ids land in different
//! blocks (old id `v` → stripe of width `w` across `parts` blocks), and
//! the relabeled graph is then partitioned into equal contiguous ranges.
//! This preserves the graph's structure but destroys the ID locality the
//! paper's blocked layout exploits — running the engine on the striped
//! relabeling quantifies how much that locality is worth (DESIGN.md
//! ablation `stripe`).

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::partition::{equal_vertex, PartitionMap};

/// Compute the striping permutation: `perm[old] = new`.
///
/// Old vertex `v` is sent to block `(v / width) % parts` at the next free
/// slot, i.e. consecutive width-sized runs of old ids rotate through the
/// blocks.
pub fn permutation(n: usize, parts: usize, width: usize) -> Vec<VertexId> {
    assert!(parts >= 1 && width >= 1);
    let mut perm = vec![0 as VertexId; n];
    // Count how many ids each block receives.
    let mut counts = vec![0usize; parts];
    for v in 0..n {
        counts[(v / width) % parts] += 1;
    }
    // Prefix sums = each block's base offset in the new id space.
    let mut base = vec![0usize; parts];
    for t in 1..parts {
        base[t] = base[t - 1] + counts[t - 1];
    }
    let mut next = base;
    for v in 0..n {
        let b = (v / width) % parts;
        perm[v] = next[b] as VertexId;
        next[b] += 1;
    }
    perm
}

/// Apply the striping permutation to a graph.
pub fn relabel(g: &Csr, parts: usize, width: usize) -> (Csr, Vec<VertexId>) {
    let n = g.num_vertices();
    let perm = permutation(n, parts, width);
    let mut b = GraphBuilder::new(n);
    if g.is_weighted() {
        b = b.with_weights();
    }
    for (s, d, w) in g.edges() {
        b.push(perm[s as usize], perm[d as usize], w);
    }
    (b.build(), perm)
}

/// The matching contiguous partition of the relabeled id space.
pub fn partition(n: usize, parts: usize) -> PartitionMap {
    equal_vertex::partition_n(n, parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::graph::properties;

    #[test]
    fn permutation_is_bijective() {
        let p = permutation(100, 7, 3);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
    }

    #[test]
    fn relabel_preserves_edge_count_and_degrees() {
        let g = GapGraph::Web.generate(9, 4);
        let (r, perm) = relabel(&g, 8, 2);
        assert_eq!(g.num_edges(), r.num_edges());
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(g.in_degree(v), r.in_degree(perm[v as usize]));
            assert_eq!(g.out_degree(v), r.out_degree(perm[v as usize]));
        }
    }

    #[test]
    fn striping_destroys_web_locality() {
        let g = GapGraph::Web.generate(11, 8);
        let before = properties::diagonal_locality(&g, 16);
        let (r, _) = relabel(&g, 16, 16);
        let after = properties::diagonal_locality(&r, 16);
        assert!(after < before / 2.0, "before {before} after {after}");
    }

    #[test]
    fn width_equal_n_is_identity_block() {
        let p = permutation(10, 4, 10);
        assert_eq!(p, (0..10u32).collect::<Vec<_>>());
    }
}
