//! The paper's partitioner: contiguous blocks balanced by in-degree.
//!
//! "Vertices are allocated to individual threads in a way that balances
//! the aggregate number of in-neighbors per thread as much as possible"
//! (§III-A). Greedy sweep: walk vertices in ID order, cutting a new block
//! whenever the running in-degree sum reaches the ideal share. Work is
//! measured as `in_degree + 1` so that vertex-value writes count too and
//! zero-degree stretches don't collapse into one giant block.

use crate::graph::{Csr, GraphStore, VertexId};
use crate::partition::PartitionMap;

/// Partition `g` into `parts` contiguous in-degree-balanced blocks.
/// Generic over [`GraphStore`], so overlays partition the same way the
/// static CSR does (by *current* in-degrees, deltas included).
pub fn partition<G: GraphStore>(g: &G, parts: usize) -> PartitionMap {
    partition_range(g, 0..g.num_vertices() as VertexId, parts)
}

/// Partition the sub-range `range` of `g` into `parts` contiguous
/// in-degree-balanced blocks — the same greedy sweep as [`partition`]
/// restricted to a window. Sharded execution uses this to split one
/// shard's owned range across its worker threads
/// ([`crate::engine::EngineConfig`] `restrict`); `partition` is the
/// `range = 0..n` special case.
pub fn partition_range<G: GraphStore>(g: &G, range: std::ops::Range<VertexId>, parts: usize) -> PartitionMap {
    assert!(parts >= 1);
    assert!(range.start <= range.end, "partition range must be ascending");
    let total_work: u64 = range.clone().map(|v| g.in_degree(v) as u64 + 1).sum();
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(range.start);
    let mut acc = 0u64;
    let mut next_cut = 1u64;
    for v in range.clone() {
        acc += g.in_degree(v) as u64 + 1;
        // Cut when we pass the k-th ideal share; may emit several cuts at
        // one vertex only if parts > range length (guarded below).
        while bounds.len() < parts && acc * parts as u64 >= next_cut * total_work {
            bounds.push(v + 1);
            next_cut += 1;
        }
    }
    while bounds.len() < parts {
        bounds.push(range.end); // more parts than vertices: empty tail parts
    }
    bounds.push(range.end);
    PartitionMap::from_offset_bounds(bounds)
}

/// Maximum over parts of (work share / ideal share) − 1; 0 is perfect.
pub fn imbalance(g: &Csr, pm: &PartitionMap) -> f64 {
    let parts = pm.num_parts();
    let total: u64 = g.num_edges() as u64 + g.num_vertices() as u64;
    if total == 0 {
        return 0.0;
    }
    let ideal = total as f64 / parts as f64;
    (0..parts)
        .map(|t| {
            let r = pm.range(t);
            let work = g.range_in_edges(r.start, r.end) + (r.end - r.start) as u64;
            work as f64 / ideal - 1.0
        })
        .fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn covers_everything() {
        let g = GapGraph::Kron.generate(10, 8);
        for parts in [1, 2, 7, 32] {
            let pm = partition(&g, parts);
            assert_eq!(pm.num_parts(), parts);
            assert_eq!(pm.num_vertices(), g.num_vertices());
        }
    }

    #[test]
    fn balanced_on_skewed_graph() {
        let g = GapGraph::Kron.generate(12, 8);
        let pm = partition(&g, 16);
        // Skewed graphs can't be perfectly balanced by contiguous blocks,
        // but the greedy sweep should stay within a reasonable factor.
        assert!(imbalance(&g, &pm) < 1.0, "imbalance {}", imbalance(&g, &pm));
    }

    #[test]
    fn balanced_on_uniform_graph() {
        let g = GapGraph::Urand.generate(12, 8);
        let pm = partition(&g, 16);
        assert!(imbalance(&g, &pm) < 0.1, "imbalance {}", imbalance(&g, &pm));
    }

    #[test]
    fn more_parts_than_vertices() {
        let g = GraphBuilder::new(3).edges(&[(0, 1)]).build();
        let pm = partition(&g, 8);
        assert_eq!(pm.num_parts(), 8);
        assert_eq!(pm.num_vertices(), 3);
        let covered: usize = (0..8).map(|t| pm.len(t)).sum();
        assert_eq!(covered, 3);
    }

    #[test]
    fn single_part_is_whole_range() {
        let g = GapGraph::Web.generate(8, 4);
        let pm = partition(&g, 1);
        assert_eq!(pm.range(0), 0..g.num_vertices() as u32);
    }

    #[test]
    fn hub_vertex_isolated() {
        // One vertex with huge in-degree should end up nearly alone.
        let mut edges = Vec::new();
        for s in 1..101u32 {
            edges.push((s, 0u32));
        }
        let g = GraphBuilder::new(101).edges(&edges).build();
        let pm = partition(&g, 4);
        assert!(pm.len(0) < 50, "hub block should be small, got {}", pm.len(0));
    }
}
