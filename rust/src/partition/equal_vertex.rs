//! Ablation partitioner: contiguous blocks with equal vertex counts,
//! ignoring degree. On skewed graphs this produces badly imbalanced pull
//! work; comparing it against [`crate::partition::blocked`] quantifies
//! how much the paper's in-degree balancing matters.

use crate::graph::GraphStore;
use crate::partition::PartitionMap;

/// Split `0..n` into `parts` near-equal contiguous ranges.
pub fn partition<G: GraphStore>(g: &G, parts: usize) -> PartitionMap {
    partition_n(g.num_vertices(), parts)
}

/// As [`partition`] but from a bare vertex count.
pub fn partition_n(n: usize, parts: usize) -> PartitionMap {
    assert!(parts >= 1);
    let mut bounds = Vec::with_capacity(parts + 1);
    for t in 0..=parts {
        bounds.push(((n as u64 * t as u64) / parts as u64) as u32);
    }
    PartitionMap::from_bounds(bounds)
}

/// Split a sub-range into `parts` near-equal contiguous blocks (the
/// restricted-run twin of [`partition_n`], used when the engine sweeps
/// only one shard's owned range).
pub fn partition_range(range: std::ops::Range<u32>, parts: usize) -> PartitionMap {
    assert!(parts >= 1);
    assert!(range.start <= range.end, "partition range must be ascending");
    let len = (range.end - range.start) as u64;
    let mut bounds = Vec::with_capacity(parts + 1);
    for t in 0..=parts {
        bounds.push(range.start + ((len * t as u64) / parts as u64) as u32);
    }
    PartitionMap::from_offset_bounds(bounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::partition::blocked;

    #[test]
    fn sizes_differ_by_at_most_one() {
        let pm = partition_n(10, 3);
        let sizes: Vec<usize> = (0..3).map(|t| pm.len(t)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn worse_than_blocked_on_skew() {
        let g = GapGraph::Kron.generate(12, 8);
        let ev = partition(&g, 16);
        let bl = blocked::partition(&g, 16);
        assert!(
            blocked::imbalance(&g, &ev) > blocked::imbalance(&g, &bl),
            "equal-vertex should be worse on skewed graphs"
        );
    }

    #[test]
    fn zero_vertices() {
        let pm = partition_n(0, 4);
        assert_eq!(pm.num_vertices(), 0);
    }
}
