//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from rust. Python is never on this path — `make artifacts` ran
//! once at build time and produced `artifacts/*.hlo.txt` + a manifest.
//!
//! HLO **text** is the interchange format (see `python/compile/aot.py`
//! for why serialized protos don't round-trip into xla_extension 0.5.1).

pub mod artifact;
pub mod block_backend;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

pub use artifact::Manifest;

/// A compiled executable plus its manifest entry.
pub struct LoadedStep {
    exe: xla::PjRtLoadedExecutable,
    /// Block size N the step was lowered for.
    pub block: usize,
}

impl LoadedStep {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<&xla::Literal>(inputs).context("pjrt execute")?;
        let lit = out[0][0].to_literal_sync().context("to_literal_sync")?;
        // aot.py lowers with return_tuple=True: always a tuple.
        lit.to_tuple().context("output tuple")
    }
}

/// PJRT CPU client with a cache of compiled executables, keyed by entry
/// name from the manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedStep>>>,
}

impl Runtime {
    /// Load the manifest in `dir` and create the CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifact location (`artifacts/` relative to the CWD,
    /// overridable with `DAIG_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DAIG_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the executable for `name`.
    pub fn step(&self, name: &str) -> Result<std::sync::Arc<LoadedStep>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let entry = self.manifest.entry(name).with_context(|| format!("no artifact entry '{name}'"))?;
        let path = self.dir.join(&entry.file);
        let proto =
            xla::HloModuleProto::from_text_file(&path).with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let step = std::sync::Arc::new(LoadedStep { exe, block: entry.block });
        self.cache.lock().unwrap().insert(name.to_string(), step.clone());
        Ok(step)
    }

    /// Smallest lowered block size ≥ `n`, if any.
    pub fn block_for(&self, n: usize) -> Option<usize> {
        self.manifest.blocks().into_iter().filter(|&b| b >= n).min()
    }
}

/// Build an (r, c) f32 literal from row-major data.
pub fn literal_f32(data: &[f32], r: usize, c: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == r * c, "literal shape mismatch: {} != {r}x{c}", data.len());
    xla::Literal::vec1(data).reshape(&[r as i64, c as i64]).context("reshape literal")
}

/// Extract an f32 literal into a Vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to_vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(literal_to_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_checked() {
        assert!(literal_f32(&[1.0; 3], 2, 2).is_err());
    }

    // Runtime::load is exercised by rust/tests/pjrt_backend.rs (needs the
    // artifacts directory, which unit tests must not depend on).
}
