//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One lowered entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub file: String,
    /// Block size N the step was lowered at.
    pub block: usize,
    /// Input shapes (rows, cols) in call order.
    pub inputs: Vec<(usize, usize)>,
    /// Content hash of the HLO text (integrity check).
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Interchange format tag ("hlo-text").
    pub format: String,
    /// jax version that lowered the artifacts.
    pub jax_version: String,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Parse a manifest file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parse {path:?}"))
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(anyhow::Error::msg)?;
        let format = v.get("format").and_then(Json::as_str).context("format")?.to_string();
        anyhow::ensure!(format == "hlo-text", "unsupported artifact format '{format}'");
        let jax_version = v.get("jax").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let mut entries = Vec::new();
        for e in v.get("entries").and_then(Json::as_arr).context("entries")? {
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(|i| {
                    let s = i.get("shape").and_then(Json::as_arr).context("shape")?;
                    anyhow::ensure!(s.len() == 2, "non-2d input shape");
                    Ok((s[0].as_usize().context("dim")?, s[1].as_usize().context("dim")?))
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(Entry {
                name: e.get("name").and_then(Json::as_str).context("name")?.to_string(),
                file: e.get("file").and_then(Json::as_str).context("file")?.to_string(),
                block: e.get("block").and_then(Json::as_usize).context("block")?,
                inputs,
                sha256: e.get("sha256").and_then(Json::as_str).unwrap_or("").to_string(),
            });
        }
        Ok(Self { format, jax_version, entries })
    }

    /// Entry by name.
    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Distinct block sizes available, ascending.
    pub fn blocks(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.entries.iter().map(|e| e.block).collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    /// Verify every referenced file exists and matches its recorded hash
    /// length (cheap integrity check without a sha256 implementation).
    pub fn verify_files(&self, dir: &Path) -> Result<()> {
        for e in &self.entries {
            let p = dir.join(&e.file);
            anyhow::ensure!(p.exists(), "missing artifact file {p:?}");
            let text = std::fs::read_to_string(&p)?;
            anyhow::ensure!(text.starts_with("HloModule"), "{p:?} is not HLO text");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "jax": "0.8.2", "tile_m": 128,
      "entries": [
        {"name": "pagerank_step_128", "file": "pagerank_step_128.hlo.txt",
         "block": 128, "outputs": 2, "sha256": "ab",
         "inputs": [{"shape": [128, 128], "dtype": "float32"},
                    {"shape": [128, 1], "dtype": "float32"}]},
        {"name": "sssp_step_256", "file": "sssp_step_256.hlo.txt",
         "block": 256, "outputs": 2, "sha256": "cd",
         "inputs": [{"shape": [256, 256], "dtype": "float32"},
                    {"shape": [256, 1], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("pagerank_step_128").unwrap();
        assert_eq!(e.block, 128);
        assert_eq!(e.inputs, vec![(128, 128), (128, 1)]);
        assert_eq!(m.blocks(), vec![128, 256]);
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("{").is_err());
        assert!(Manifest::parse("{}").is_err());
    }
}
