//! Dense-block algorithm backend over the AOT artifacts.
//!
//! Densifies a (small) graph into the (N, N) block layout the L1 Pallas
//! kernels expect, then drives the per-round step executables from rust
//! until the paper's convergence criteria fire. This is the end-to-end
//! proof that the three-layer stack composes: Pallas kernel → JAX step →
//! HLO text → PJRT execution under the rust coordinator — with numerics
//! checked against the native engine in `rust/tests/pjrt_backend.rs`.
//!
//! Scope note: the *experiments* all run on the sparse engines (native &
//! simulator); the dense path is bounded by the largest lowered block
//! (512 vertices) and exists to exercise the AOT plumbing exactly as a
//! TPU deployment of the paper's update kernel would.

use anyhow::{Context, Result};

use crate::algorithms::pagerank::PrConfig;
use crate::algorithms::sssp::INF;
use crate::graph::{Csr, VertexId};

use super::{literal_f32, literal_to_vec, Runtime};

/// Result of a dense-block run.
#[derive(Debug, Clone)]
pub struct BlockRunResult {
    /// Per-vertex outputs (unpadded).
    pub values: Vec<f32>,
    /// Rounds executed.
    pub rounds: usize,
    /// True if converged before the round cap.
    pub converged: bool,
}

/// Dense PageRank via the `pagerank_step_N` artifact.
pub fn pagerank(rt: &Runtime, g: &Csr, cfg: &PrConfig, max_rounds: usize) -> Result<BlockRunResult> {
    let n = g.num_vertices();
    let np = rt.block_for(n).with_context(|| format!("graph too large for lowered blocks ({n} vertices)"))?;
    let step = rt.step(&format!("pagerank_step_{np}"))?;

    // Pull adjacency: m[i][j] = 1 iff edge j -> i. Padded region stays 0.
    let mut m = vec![0.0f32; np * np];
    for (s, d, _) in g.edges() {
        m[d as usize * np + s as usize] = 1.0;
    }
    let mut inv = vec![0.0f32; np];
    for v in 0..n {
        let d = g.out_degree(v as VertexId);
        inv[v] = if d == 0 { 0.0 } else { 1.0 / d as f32 };
    }
    let base = (1.0 - cfg.damping) / n as f32;
    // Real vertices start at 1/n; padded vertices start at their fixed
    // point (base) so they contribute no convergence delta after round 1.
    let mut scores = vec![base; np];
    scores[..n].fill(1.0 / n as f32);

    let m_lit = literal_f32(&m, np, np)?;
    let inv_lit = literal_f32(&inv, np, 1)?;
    let damping_lit = literal_f32(&[cfg.damping], 1, 1)?;
    let base_lit = literal_f32(&[base], 1, 1)?;

    let mut rounds = 0;
    let mut converged = false;
    while rounds < max_rounds {
        let scores_lit = literal_f32(&scores, np, 1)?;
        let out = step.execute(&[&m_lit, &scores_lit, &inv_lit, &damping_lit, &base_lit])?;
        anyhow::ensure!(out.len() == 2, "expected (scores, delta), got {} outputs", out.len());
        scores = literal_to_vec(&out[0])?;
        let delta = literal_to_vec(&out[1])?[0] as f64;
        rounds += 1;
        if delta < cfg.epsilon {
            converged = true;
            break;
        }
    }
    scores.truncate(n);
    // Decode like the sparse engine: redistribute dangling mass exactly
    // (see `algorithms::pagerank` module docs), so backends agree on
    // graphs with sinks too.
    crate::algorithms::pagerank::redistribute_dangling(&mut scores);
    Ok(BlockRunResult { values: scores, rounds, converged })
}

/// Dense Bellman-Ford via the `sssp_step_N` artifact. Distances ride in
/// f32 (exact for GAP-weight path lengths < 2^24); `u32::MAX` ⇔ +inf.
pub fn sssp(rt: &Runtime, g: &Csr, source: VertexId, max_rounds: usize) -> Result<BlockRunResult> {
    anyhow::ensure!(g.is_weighted(), "SSSP requires weights");
    let n = g.num_vertices();
    let np = rt.block_for(n).with_context(|| format!("graph too large for lowered blocks ({n} vertices)"))?;
    let step = rt.step(&format!("sssp_step_{np}"))?;

    // w[j][i] = weight of edge j -> i; +inf elsewhere (incl. padding).
    let mut w = vec![f32::INFINITY; np * np];
    for (s, d, wt) in g.edges() {
        let slot = &mut w[s as usize * np + d as usize];
        *slot = slot.min(wt as f32);
    }
    let mut dist = vec![f32::INFINITY; np];
    dist[source as usize] = 0.0;

    let w_lit = literal_f32(&w, np, np)?;
    let mut rounds = 0;
    let mut converged = false;
    while rounds < max_rounds {
        let dist_lit = literal_f32(&dist, np, 1)?;
        let out = step.execute(&[&w_lit, &dist_lit])?;
        anyhow::ensure!(out.len() == 2, "expected (dist, changed), got {} outputs", out.len());
        dist = literal_to_vec(&out[0])?;
        let changed = literal_to_vec(&out[1])?[0];
        rounds += 1;
        if changed == 0.0 {
            converged = true;
            break;
        }
    }
    dist.truncate(n);
    Ok(BlockRunResult { values: dist, rounds, converged })
}

/// Decode dense SSSP outputs back to the engine's u32 convention.
pub fn dist_to_u32(values: &[f32]) -> Vec<u32> {
    values.iter().map(|&d| if d.is_finite() { d as u32 } else { INF }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_decoding() {
        assert_eq!(dist_to_u32(&[0.0, 7.0, f32::INFINITY]), vec![0, 7, INF]);
    }

    // Full PJRT round-trips live in rust/tests/pjrt_backend.rs (they need
    // the artifacts directory built by `make artifacts`).
}
