//! Graph storage, generation, and analysis.
//!
//! Pull-style iterative algorithms read a vertex's **in-neighbors**, so
//! the canonical representation here is [`Csr`] over *incoming* edges
//! (i.e. CSC of the adjacency matrix). [`builder`] turns arbitrary edge
//! lists into that form; [`generators`]/[`gap`] produce the synthetic
//! GAP-analog suite used by every experiment; [`properties`] computes the
//! topology metrics (notably the diagonal-locality score of §IV-C) that
//! predict whether delaying updates helps.
//!
//! Storage itself sits behind the [`GraphStore`] trait: [`Csr`] is the
//! frozen static impl, [`VersionedGraph`] ([`overlay`]) layers versioned
//! insert/delete deltas over a CSR base for streaming mutation workloads
//! with incremental recomputation, and [`CompressedCsr`] ([`compressed`])
//! is the big-graph tier — delta/varint block-compressed rows, in RAM or
//! memory-mapped from a `.dagc` file written by `daig convert`.

pub mod builder;
pub mod compressed;
pub mod gap;
pub mod generators;
pub mod io;
pub mod overlay;
pub mod properties;
pub mod weights;

mod csr;
mod store;

pub use builder::GraphBuilder;
pub use compressed::CompressedCsr;
pub use csr::{Csr, VertexId};
pub use overlay::{EdgeMutation, GraphVersion, MutationReceipt, VersionedGraph};
pub use store::GraphStore;
