//! Community-clustered power-law generator — GAP "web" analog.
//!
//! The paper's §IV-C finding about Web is the one this generator must
//! preserve: web crawls order vertices by URL, so pages of one site get
//! contiguous IDs and link overwhelmingly within that contiguous block.
//! The resulting thread-access matrix is strongly diagonal (Fig. 5), and
//! that diagonal clustering is *why* delaying updates does not help —
//! threads mostly consume their own updates.
//!
//! Construction: vertex IDs are carved into contiguous communities with
//! power-law-ish sizes; each vertex emits power-law many links, ~92% to
//! targets inside its own community (skewed toward community hubs) and
//! the rest to hubs of other communities.

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::SplitMix64;

/// Fraction of links staying inside the source's community. Real web
/// crawls measure ~90–95% same-host links; the high end maximizes the
/// diagonal clustering that drives the paper's Fig. 5 finding.
const INTRA_COMMUNITY: f64 = 0.95;

/// Carve `n` vertices into contiguous communities with sizes spanning
/// roughly two orders of magnitude (like sites on the web).
fn community_bounds(n: usize, rng: &mut SplitMix64) -> Vec<(u32, u32)> {
    let mut bounds = Vec::new();
    let mut start = 0usize;
    // Heavy-tailed sizes, capped relative to n so that even at small test
    // scales every community sits well inside one 32-way partition block
    // (block ≈ n/32; cap = n/64 keeps ≥2 communities per block). Real web
    // crawls have the same property at GAP scale: sites ≪ n/32.
    let cap = (n as f64 / 64.0).max(16.0);
    while start < n {
        let u = rng.next_f64();
        let size = (16.0 * (1.0 - u).powf(-0.8)).min(cap) as usize;
        let end = (start + size.max(16)).min(n);
        bounds.push((start as u32, end as u32));
        start = end;
    }
    bounds
}

/// Zipf-ish pick inside `[lo, hi)`: low indices (community hubs) are
/// strongly preferred, mimicking sites whose front pages collect links.
fn pick_zipf(lo: u32, hi: u32, rng: &mut SplitMix64) -> VertexId {
    let span = (hi - lo) as f64;
    let u = rng.next_f64();
    // Quadratic skew toward lo: P(rank r) ~ denser near 0.
    lo + ((u * u) * span) as u32
}

/// Generate the web analog: directed, `~edge_factor * 2^scale` edges.
pub fn generate(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let mut rng = SplitMix64::new(seed);
    let bounds = community_bounds(n, &mut rng);

    // Map vertex -> community index for fast lookup.
    let mut community = vec![0u32; n];
    for (ci, &(lo, hi)) in bounds.iter().enumerate() {
        for v in lo..hi {
            community[v as usize] = ci as u32;
        }
    }

    let m = n * edge_factor;
    let mut es = Vec::with_capacity(m);
    for _ in 0..m {
        let src = rng.next_below(n as u64) as VertexId;
        let (lo, hi) = bounds[community[src as usize] as usize];
        let dst = if rng.chance(INTRA_COMMUNITY) {
            pick_zipf(lo, hi, &mut rng)
        } else {
            // Cross-site link: lands on some other community's hub region.
            let &(olo, ohi) = &bounds[rng.index(bounds.len())];
            pick_zipf(olo, ohi, &mut rng)
        };
        es.push((src, dst));
    }
    GraphBuilder::new(n).edges(&es).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directed_and_sized() {
        let g = generate(10, 8, 5);
        assert_eq!(g.num_vertices(), 1024);
        assert!(!g.is_symmetric());
        assert!(g.num_edges() > 1024 * 4);
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(8, 4, 3), generate(8, 4, 3));
    }

    #[test]
    fn high_locality() {
        // The defining property: most edges stay within a small ID window.
        let g = generate(12, 8, 7);
        let n = g.num_vertices() as u32;
        let window = n / 8; // one eighth of the ID space
        let local = g.edges().filter(|&(s, d, _)| s.abs_diff(d) < window).count();
        let frac = local as f64 / g.num_edges() as f64;
        assert!(frac > 0.75, "local fraction {frac}");
    }

    #[test]
    fn hubs_exist() {
        // Community front pages collect intra-site links.
        let g = generate(12, 8, 2);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        assert!((max_d as f64) > 5.0 * g.avg_degree(), "max {max_d} avg {}", g.avg_degree());
    }
}
