//! Recursive-MATrix (R-MAT / Kronecker) generator.
//!
//! Chakrabarti, Zhan & Faloutsos (SDM'04); the GAP "kron" graph is a
//! Graph500-style Kronecker graph, equivalent to R-MAT with
//! (a,b,c,d) = (0.57, 0.19, 0.19, 0.05). Each edge is placed by `scale`
//! recursive quadrant choices; we add the customary ±10% per-level noise
//! so the quadrant probabilities do not produce artifacts on the exact
//! power-of-two boundaries.

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::SplitMix64;

/// R-MAT quadrant probabilities.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Per-level multiplicative noise amplitude (0 disables).
    pub noise: f64,
}

impl RmatParams {
    /// Graph500/GAP "kron" parameters.
    pub fn kron() -> Self {
        Self { a: 0.57, b: 0.19, c: 0.19, noise: 0.1 }
    }
}

/// Draw one directed edge over `2^scale` vertices.
fn place_edge(scale: u32, p: &RmatParams, rng: &mut SplitMix64) -> (VertexId, VertexId) {
    let (mut src, mut dst) = (0u64, 0u64);
    for _ in 0..scale {
        src <<= 1;
        dst <<= 1;
        // Per-level noise keeps the distribution from being self-similar
        // in a degenerate way (standard Graph500 trick).
        let na = p.a * (1.0 + p.noise * (rng.next_f64() - 0.5));
        let nb = p.b * (1.0 + p.noise * (rng.next_f64() - 0.5));
        let nc = p.c * (1.0 + p.noise * (rng.next_f64() - 0.5));
        let r = rng.next_f64() * (na + nb + nc + (1.0 - p.a - p.b - p.c));
        if r < na {
            // top-left: neither bit set
        } else if r < na + nb {
            dst |= 1;
        } else if r < na + nb + nc {
            src |= 1;
        } else {
            src |= 1;
            dst |= 1;
        }
    }
    (src as VertexId, dst as VertexId)
}

/// Generate an R-MAT edge list with `n = 2^scale` vertices and
/// `edge_factor * n` directed edges (before dedup).
pub fn edges(scale: u32, edge_factor: usize, p: RmatParams, seed: u64) -> Vec<(VertexId, VertexId)> {
    assert!(scale <= 30, "scale too large");
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = SplitMix64::new(seed);
    (0..m).map(|_| place_edge(scale, &p, &mut rng)).collect()
}

/// GAP-kron analog: symmetric R-MAT graph with randomly permuted vertex
/// labels, as the Graph500 specification requires (without the
/// permutation, R-MAT's hub-at-low-ID correlation creates an artificial
/// sequential dependence chain that real Kronecker datasets do not have).
pub fn generate(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let raw = edges(scale, edge_factor, RmatParams::kron(), seed);
    let mut perm: Vec<VertexId> = (0..n as VertexId).collect();
    SplitMix64::new(seed ^ 0x6B50_9E44).shuffle(&mut perm);
    let es: Vec<(VertexId, VertexId)> = raw.iter().map(|&(s, d)| (perm[s as usize], perm[d as usize])).collect();
    GraphBuilder::new(n).edges(&es).symmetrize().build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        let g = generate(8, 8, 1);
        assert_eq!(g.num_vertices(), 256);
        // Dedup + symmetrize: edges between n*ef and 2*n*ef.
        assert!(g.num_edges() > 256 * 2, "too few edges: {}", g.num_edges());
        assert!(g.num_edges() <= 2 * 256 * 8);
        assert!(g.is_symmetric());
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(7, 4, 9), generate(7, 4, 9));
    }

    #[test]
    fn skewed_degrees() {
        // Scale-free: max degree far above mean.
        let g = generate(10, 8, 3);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        assert!(
            (max_d as f64) > 6.0 * g.avg_degree(),
            "expected skew: max {max_d}, avg {}",
            g.avg_degree()
        );
    }

    #[test]
    fn edge_endpoints_in_range() {
        for (s, d) in edges(6, 4, RmatParams::kron(), 5) {
            assert!(s < 64 && d < 64);
        }
    }
}
