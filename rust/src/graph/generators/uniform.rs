//! Uniform-random (Erdős–Rényi G(n,m)) generator — GAP "urand" analog.
//!
//! Every endpoint is drawn uniformly, so there is no degree skew and no
//! locality whatsoever: a vertex's in-neighbors are spread evenly over
//! the whole ID space, which makes urand the worst case for inter-thread
//! read sharing (every thread reads every other thread's partition).

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::SplitMix64;

/// `edge_factor * 2^scale` uniformly random directed edges.
pub fn edges(scale: u32, edge_factor: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    let n = 1u64 << scale;
    let m = (n as usize) * edge_factor;
    let mut rng = SplitMix64::new(seed);
    (0..m).map(|_| (rng.next_below(n) as VertexId, rng.next_below(n) as VertexId)).collect()
}

/// GAP-urand analog: symmetric uniform random graph.
pub fn generate(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let es = edges(scale, edge_factor, seed);
    GraphBuilder::new(1 << scale).edges(&es).symmetrize().build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_symmetry() {
        let g = generate(9, 8, 2);
        assert_eq!(g.num_vertices(), 512);
        assert!(g.is_symmetric());
        assert!(g.num_edges() > 512 * 8 / 2);
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(7, 4, 11), generate(7, 4, 11));
    }

    #[test]
    fn no_heavy_skew() {
        let g = generate(10, 8, 4);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        // Poisson-ish: max degree stays within a small factor of the mean.
        assert!((max_d as f64) < 4.0 * g.avg_degree(), "max {max_d} avg {}", g.avg_degree());
    }
}
