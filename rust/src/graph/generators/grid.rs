//! 2D lattice generator — GAP "road" analog.
//!
//! Road networks are near-planar: degree ≈ 2–4, enormous diameter, and
//! information travels slowly (the paper's §IV-D explains Road's poor
//! response to buffering by exactly this). A perturbed 2D grid reproduces
//! those properties: `side × side` vertices, 4-neighborhood, a fraction
//! of edges deleted (dead ends / rivers) and a few short-range diagonal
//! "shortcut" roads added.

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::SplitMix64;

/// Generate a perturbed grid with `side*side` vertices, in row-major ID
/// order (so contiguous ID blocks are horizontal strips — matching how
/// road-network IDs cluster geographically in the GAP dataset).
pub fn generate(side: usize, seed: u64) -> Csr {
    let n = side * side;
    let mut rng = SplitMix64::new(seed);
    let id = |r: usize, c: usize| (r * side + c) as VertexId;
    let mut es: Vec<(VertexId, VertexId)> = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            // Right and down neighbors; 8% of road segments are missing.
            if c + 1 < side && !rng.chance(0.08) {
                es.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < side && !rng.chance(0.08) {
                es.push((id(r, c), id(r + 1, c)));
            }
            // Rare short diagonal shortcut (~2%).
            if r + 1 < side && c + 1 < side && rng.chance(0.02) {
                es.push((id(r, c), id(r + 1, c + 1)));
            }
        }
    }
    GraphBuilder::new(n).edges(&es).symmetrize().build()
}

/// Road analog sized like the scale-based generators: picks `side` so that
/// `side^2 ≈ 2^scale`.
pub fn generate_scale(scale: u32, seed: u64) -> Csr {
    let side = (1usize << scale).isqrt().max(2);
    generate(side, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree() {
        let g = generate(32, 1);
        assert_eq!(g.num_vertices(), 1024);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        assert!(max_d <= 8, "grid degree bounded, got {max_d}");
        assert!(g.avg_degree() > 2.0 && g.avg_degree() < 5.0);
    }

    #[test]
    fn symmetric_and_deterministic() {
        let g = generate(16, 7);
        assert!(g.is_symmetric());
        assert_eq!(g, generate(16, 7));
    }

    #[test]
    fn mostly_connected() {
        // BFS from 0 should reach the vast majority of the grid despite
        // deleted segments.
        let g = generate(24, 3);
        let n = g.num_vertices();
        let mut seen = vec![false; n];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in g.in_neighbors(v) {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        assert!(count as f64 > 0.9 * n as f64, "connected fraction {}", count as f64 / n as f64);
    }

    #[test]
    fn scale_variant_size() {
        let g = generate_scale(10, 1);
        assert_eq!(g.num_vertices(), 32 * 32);
    }
}
