//! Skewed follower-graph generator — GAP "twitter" analog.
//!
//! Twitter is directed with extreme in-degree skew (celebrities) and no
//! particular ID locality: followers of a hub are spread across the whole
//! ID space, producing a diffuse thread-access matrix (paper Fig. 5 shows
//! Web clustered but Twitter behaving like Kron/Urand in the speedup
//! plots). We use R-MAT with more aggressive skew parameters plus a
//! deterministic ID permutation that destroys any residual block
//! structure the recursion introduces.

use crate::graph::generators::rmat::{self, RmatParams};
use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::SplitMix64;

/// Twitter-like R-MAT parameters (heavier `a` corner ⇒ stronger skew).
pub fn params() -> RmatParams {
    RmatParams { a: 0.65, b: 0.15, c: 0.15, noise: 0.1 }
}

/// Generate the twitter analog: directed, permuted IDs.
pub fn generate(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let raw = rmat::edges(scale, edge_factor, params(), seed);

    // Random relabeling: preserves the degree distribution but removes ID
    // locality, as in a real crawl where account IDs carry no structure.
    let mut perm: Vec<VertexId> = (0..n as VertexId).collect();
    SplitMix64::new(seed ^ 0x7717_7E44).shuffle(&mut perm);
    let es: Vec<(VertexId, VertexId)> = raw.iter().map(|&(s, d)| (perm[s as usize], perm[d as usize])).collect();

    GraphBuilder::new(n).edges(&es).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directed_and_deterministic() {
        let g = generate(9, 8, 4);
        assert!(!g.is_symmetric());
        assert_eq!(g, generate(9, 8, 4));
    }

    #[test]
    fn extreme_in_degree_skew() {
        let g = generate(11, 8, 6);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        assert!((max_d as f64) > 10.0 * g.avg_degree(), "max {max_d} avg {}", g.avg_degree());
    }

    #[test]
    fn no_id_locality() {
        // Unlike web: edges should NOT concentrate near the diagonal.
        let g = generate(11, 8, 9);
        let n = g.num_vertices() as u32;
        let window = n / 8;
        let local = g.edges().filter(|&(s, d, _)| s.abs_diff(d) < window).count();
        let frac = local as f64 / g.num_edges() as f64;
        assert!(frac < 0.4, "local fraction {frac} too high for twitter analog");
    }
}
