//! Synthetic graph generators for the GAP-analog suite.
//!
//! The paper evaluates on the five GAP benchmark graphs. Those are
//! multi-gigabyte downloads; per DESIGN.md §3 we substitute generators
//! that reproduce the *causal* topological properties §IV identifies:
//!
//! | GAP graph | generator | property preserved |
//! |---|---|---|
//! | Kron    | [`rmat`] (a=.57 b=.19 c=.19), symmetric | scale-free, long-range, diffuse access matrix |
//! | Urand   | [`uniform`], symmetric | no locality at all, uniform degree |
//! | Twitter | [`twitter`] (skewed RMAT + permutation), directed | heavy skew, diffuse |
//! | Web     | [`web`] (contiguous communities), directed | **diagonal-clustered** access matrix, high local reads |
//! | Road    | [`grid`] (2D lattice + perturbation), symmetric | huge diameter, degree ≈ 2–4, slow information flow |

pub mod grid;
pub mod rmat;
pub mod twitter;
pub mod uniform;
pub mod web;
