//! Topology metrics — Table II and the §IV-C locality precomputation.
//!
//! The paper concludes that a graph's amenability to delay-buffering "can
//! be precomputed" from its topology: graphs whose coarsened adjacency
//! mass sits on the main diagonal (Web) do not benefit. This module
//! computes that *diagonal locality score* plus the standard statistics
//! reported in Table II.

use crate::graph::{Csr, GraphStore, VertexId};
use crate::partition::{blocked, PartitionMap};
use crate::util::rng::SplitMix64;

/// Summary statistics for a graph (Table II plus locality diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub vertices: usize,
    pub edges: usize,
    pub symmetric: bool,
    pub weighted: bool,
    pub avg_degree: f64,
    pub max_in_degree: usize,
    /// Coefficient of variation of in-degree (skew measure).
    pub degree_cv: f64,
    /// Fraction of edges whose endpoints fall in the same partition when
    /// split into `parts` in-degree-balanced blocks — the mass on the
    /// diagonal of the paper's Fig. 5 access matrix.
    pub diagonal_locality: f64,
    /// BFS-estimated effective diameter (90th percentile distance from a
    /// sample of sources; usize::MAX-free: unreachable pairs ignored).
    pub effective_diameter: usize,
}

/// Number of blocks used for the locality score (the paper instruments a
/// 32-thread setup; we use the same granularity by default).
pub const LOCALITY_PARTS: usize = 32;

/// Compute all statistics. `O(m + sample·(n+m))` for the diameter sample.
pub fn stats(g: &Csr) -> GraphStats {
    let n = g.num_vertices();
    let m = g.num_edges();
    let avg = g.avg_degree();

    let mut max_in = 0usize;
    let mut var = 0.0f64;
    for v in 0..n as VertexId {
        let d = g.in_degree(v);
        max_in = max_in.max(d);
        let diff = d as f64 - avg;
        var += diff * diff;
    }
    let degree_cv = if n > 0 && avg > 0.0 { (var / n as f64).sqrt() / avg } else { 0.0 };

    GraphStats {
        vertices: n,
        edges: m,
        symmetric: g.is_symmetric(),
        weighted: g.is_weighted(),
        avg_degree: avg,
        max_in_degree: max_in,
        degree_cv,
        diagonal_locality: diagonal_locality(g, LOCALITY_PARTS),
        effective_diameter: effective_diameter(g, 8, 0xD1A3),
    }
}

/// Fraction of edges internal to their in-degree-balanced block — the
/// §IV-C predictor: high values (Web) mean threads consume their own
/// updates and delaying writes cannot relieve contention. Generic over
/// [`GraphStore`] (both executors seed adaptive-δ controllers from it),
/// iterating pull rows vertex by vertex — on a static CSR that visits
/// exactly the edges `Csr::edges` yields, in the same dst-major order.
pub fn diagonal_locality<G: GraphStore>(g: &G, parts: usize) -> f64 {
    if g.num_edges() == 0 {
        return 0.0;
    }
    let pm = blocked::partition(g, parts);
    let mut internal = 0usize;
    for d in 0..g.num_vertices() as VertexId {
        for s in g.in_neighbors(d) {
            if pm.owner(s) == pm.owner(d) {
                internal += 1;
            }
        }
    }
    internal as f64 / g.num_edges() as f64
}

/// The full coarsened access matrix: `counts[r][c]` = number of pull reads
/// thread `r` (owner of the destination) performs on data owned by thread
/// `c` (the source's partition). This is exactly what Fig. 5 plots.
pub fn access_matrix(g: &Csr, parts: usize) -> Vec<Vec<u64>> {
    let pm = blocked::partition(g, parts);
    access_matrix_with(g, &pm)
}

/// As [`access_matrix`] but over a caller-supplied partition map.
pub fn access_matrix_with(g: &Csr, pm: &PartitionMap) -> Vec<Vec<u64>> {
    let parts = pm.num_parts();
    let mut counts = vec![vec![0u64; parts]; parts];
    for (s, d, _) in g.edges() {
        counts[pm.owner(d) as usize][pm.owner(s) as usize] += 1;
    }
    counts
}

/// 90th-percentile BFS distance from `samples` random sources (ignoring
/// unreachable vertices). Cheap stand-in for effective diameter.
pub fn effective_diameter(g: &Csr, samples: usize, seed: u64) -> usize {
    let n = g.num_vertices();
    if n == 0 {
        return 0;
    }
    let mut rng = SplitMix64::new(seed);
    let mut best = 0usize;
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    for _ in 0..samples {
        let src = rng.index(n) as VertexId;
        dist.iter_mut().for_each(|d| *d = u32::MAX);
        queue.clear();
        dist[src as usize] = 0;
        queue.push_back(src);
        let mut reached = Vec::new();
        while let Some(v) = queue.pop_front() {
            reached.push(dist[v as usize]);
            // NOTE: pull lists are in-neighbors; on symmetric graphs this
            // equals out-neighbors. On directed graphs this measures the
            // reverse reachability, which is fine for an estimate.
            for &u in g.in_neighbors(v) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = dist[v as usize] + 1;
                    queue.push_back(u);
                }
            }
        }
        reached.sort_unstable();
        if !reached.is_empty() {
            best = best.max(reached[(reached.len() * 9) / 10] as usize);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;

    #[test]
    fn stats_tiny() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2), (2, 0), (0, 2)]).build();
        let s = stats(&g);
        assert_eq!(s.vertices, 3);
        assert_eq!(s.edges, 4);
        assert!(!s.symmetric);
        assert!((s.avg_degree - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_in_degree, 2);
    }

    #[test]
    fn web_more_diagonal_than_kron() {
        // The paper's central topology finding, at small scale.
        let web = GapGraph::Web.generate(11, 8);
        let kron = GapGraph::Kron.generate(11, 8);
        let lw = diagonal_locality(&web, 32);
        let lk = diagonal_locality(&kron, 32);
        assert!(lw > 2.0 * lk, "web {lw} vs kron {lk}");
        assert!(lw > 0.5, "web should be majority-local, got {lw}");
    }

    #[test]
    fn road_has_large_diameter() {
        let road = GapGraph::Road.generate(12, 0);
        let kron = GapGraph::Kron.generate(12, 8);
        let dr = effective_diameter(&road, 4, 1);
        let dk = effective_diameter(&kron, 4, 1);
        assert!(dr > 4 * dk.max(1), "road {dr} vs kron {dk}");
    }

    #[test]
    fn access_matrix_conserves_edges() {
        let g = GapGraph::Twitter.generate(10, 8);
        let am = access_matrix(&g, 8);
        let total: u64 = am.iter().flatten().sum();
        assert_eq!(total, g.num_edges() as u64);
    }

    #[test]
    fn empty_graph_stats() {
        let g = GraphBuilder::new(0).edges(&[]).build();
        let s = stats(&g);
        assert_eq!(s.vertices, 0);
        assert_eq!(s.effective_diameter, 0);
    }
}
