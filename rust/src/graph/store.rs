//! The storage abstraction behind every engine read path.
//!
//! Both executors, the schedulers, the partitioners, and the algorithm
//! layer read graphs exclusively through [`GraphStore`], so any storage
//! backend that can answer neighbor queries plugs into the whole stack:
//! the frozen [`Csr`](crate::graph::Csr) is the static impl, and
//! [`VersionedGraph`](crate::graph::VersionedGraph) layers mutable
//! insert/delete overlays on top of a CSR base (future backends — the
//! ROADMAP's compressed and mmap stores — slot in the same way).
//!
//! Design constraints:
//!
//! * **Zero overhead on the static path.** Every consumer is generic
//!   (`fn run<G: GraphStore>`), never `dyn`: calls monomorphize, and the
//!   `Csr` impl delegates straight to the inherent slice accessors, so a
//!   static-CSR run compiles to exactly the pre-trait code. `Csr` keeps
//!   its inherent slice-returning methods — concrete call sites resolve
//!   to those (inherent wins), only generic code sees the iterators.
//! * **Iterator-shaped neighbor access.** Overlaid storage cannot hand
//!   out one contiguous slice per row (a row is base-minus-tombstones
//!   plus inserts), so the trait's neighbor methods return iterators.
//!   [`GraphStore::in_neighbor_hint`] exposes a best-effort contiguous
//!   slice *only* for software prefetch, where a stale or partial row is
//!   harmless (hints have no architectural effect).
//! * **`Sync`.** Both executors share the store across worker threads.

use crate::graph::{Csr, VertexId};

/// Read-only graph access: everything the engines, schedulers, and
/// algorithms need, and nothing tied to one storage layout.
///
/// Implementations must present a consistent snapshot for the duration
/// of a run: vertex/edge counts, degrees, and neighbor lists may not
/// change while any engine holds the reference.
pub trait GraphStore: Sync {
    /// Number of vertices.
    fn num_vertices(&self) -> usize;

    /// Number of (directed) edges currently stored.
    fn num_edges(&self) -> usize;

    /// Whether edges carry weights.
    fn is_weighted(&self) -> bool;

    /// Whether the graph has undirected semantics (every edge paired
    /// with its reverse).
    fn is_symmetric(&self) -> bool;

    /// In-degree of `v`.
    fn in_degree(&self, v: VertexId) -> usize;

    /// Out-degree of `v`.
    fn out_degree(&self, v: VertexId) -> u32;

    /// All out-degrees, indexed by vertex (PageRank divides each
    /// neighbor's score by the *writer's* fan-out, so every backend
    /// materializes this array).
    fn out_degrees(&self) -> &[u32];

    /// In-neighbors of `v`. Order is backend-defined; `Csr` yields its
    /// sorted row, overlays yield surviving base entries then inserts.
    fn in_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_;

    /// In-neighbors of `v` zipped with edge weights. Panics if the
    /// graph is unweighted (same contract as
    /// [`Csr::in_neighbors_weighted`]).
    fn in_neighbors_weighted(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u32)> + '_;

    /// Out-neighbors of `v`. Call [`Self::ensure_out_edges`] before any
    /// timed or multi-threaded region that uses this.
    fn out_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_;

    /// Best-effort contiguous slice of in-neighbor ids, for software
    /// prefetch look-ahead only. May be shorter or longer than the true
    /// neighbor iterator and may include ids of deleted edges — a
    /// prefetch is a pure hint, so none of that affects results
    /// ([`crate::engine::kernels::prefetch_ahead`] bounds-checks its
    /// look-ahead).
    fn in_neighbor_hint(&self, v: VertexId) -> &[VertexId];

    /// Force any lazily built out-edge view to exist (no-op for
    /// backends that keep it materialized).
    fn ensure_out_edges(&self);

    /// Mean in-degree.
    fn avg_degree(&self) -> f64 {
        let n = self.num_vertices();
        if n == 0 {
            0.0
        } else {
            self.num_edges() as f64 / n as f64
        }
    }
}

/// The static backend: delegates every method to the inherent `Csr`
/// accessors, so generic consumers monomorphize to exactly the code
/// concrete `&Csr` call sites compile to.
impl GraphStore for Csr {
    #[inline]
    fn num_vertices(&self) -> usize {
        Csr::num_vertices(self)
    }

    #[inline]
    fn num_edges(&self) -> usize {
        Csr::num_edges(self)
    }

    #[inline]
    fn is_weighted(&self) -> bool {
        Csr::is_weighted(self)
    }

    #[inline]
    fn is_symmetric(&self) -> bool {
        Csr::is_symmetric(self)
    }

    #[inline]
    fn in_degree(&self, v: VertexId) -> usize {
        Csr::in_degree(self, v)
    }

    #[inline]
    fn out_degree(&self, v: VertexId) -> u32 {
        Csr::out_degree(self, v)
    }

    #[inline]
    fn out_degrees(&self) -> &[u32] {
        Csr::out_degrees(self)
    }

    #[inline]
    fn in_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        Csr::in_neighbors(self, v).iter().copied()
    }

    #[inline]
    fn in_neighbors_weighted(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u32)> + '_ {
        Csr::in_neighbors_weighted(self, v)
    }

    #[inline]
    fn out_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        Csr::out_neighbors(self, v).iter().copied()
    }

    #[inline]
    fn in_neighbor_hint(&self, v: VertexId) -> &[VertexId] {
        Csr::in_neighbors(self, v)
    }

    #[inline]
    fn ensure_out_edges(&self) {
        Csr::ensure_out_edges(self)
    }

    #[inline]
    fn avg_degree(&self) -> f64 {
        Csr::avg_degree(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// A generic consumer: total weight of all in-edges of all vertices,
    /// reading exclusively through the trait.
    fn total_weight<G: GraphStore>(g: &G) -> u64 {
        (0..g.num_vertices() as VertexId)
            .map(|v| g.in_neighbors_weighted(v).map(|(_, w)| w as u64).sum::<u64>())
            .sum()
    }

    #[test]
    fn csr_trait_view_matches_inherent() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 7), (2, 1, 3), (1, 3, 9), (3, 0, 2)]).build();
        assert_eq!(GraphStore::num_vertices(&g), g.num_vertices());
        assert_eq!(GraphStore::num_edges(&g), g.num_edges());
        assert!(GraphStore::is_weighted(&g));
        for v in 0..4u32 {
            let inherent: Vec<VertexId> = g.in_neighbors(v).to_vec();
            let through_trait: Vec<VertexId> = GraphStore::in_neighbors(&g, v).collect();
            assert_eq!(inherent, through_trait, "v{v}");
            let out_inherent: Vec<VertexId> = g.out_neighbors(v).to_vec();
            let out_trait: Vec<VertexId> = GraphStore::out_neighbors(&g, v).collect();
            assert_eq!(out_inherent, out_trait, "v{v}");
            assert_eq!(GraphStore::in_neighbor_hint(&g, v), g.in_neighbors(v), "v{v}");
            assert_eq!(GraphStore::in_degree(&g, v), g.in_degree(v), "v{v}");
            assert_eq!(GraphStore::out_degree(&g, v), g.out_degree(v), "v{v}");
        }
        assert_eq!(total_weight(&g), 7 + 3 + 9 + 2);
    }

    #[test]
    fn generic_consumers_accept_csr() {
        let g = GraphBuilder::new(3).weighted_edges(&[(0, 1, 1), (1, 2, 1)]).build();
        assert_eq!(total_weight(&g), 2);
        assert!(GraphStore::avg_degree(&g) > 0.0);
    }
}
