//! Block-compressed CSR: delta/varint neighbor lists in cache-line
//! blocks, one byte image shared by the in-RAM and mmap-backed paths.
//!
//! Neighbor rows are encoded per vertex: the first in-neighbor id is
//! absolute, every following id is the gap to its predecessor (rows are
//! strictly ascending after builder dedup, so gaps are ≥ 1 and small on
//! locality-friendly graphs — LEB128 varints make the common gap one
//! byte instead of four). The stream is carved into 64-byte blocks with
//! one hard rule, applied identically by encoder and decoder:
//!
//! > **Pad rule.** A varint never starts within the last
//! > `MAX_VARINT_BYTES - 1` bytes of a block. If fewer than
//! > [`MAX_VARINT_BYTES`] bytes remain, both sides skip to the next
//! > block boundary (the encoder writes zero bytes, the decoder steps
//! > over them).
//!
//! A u32 varint is at most 5 bytes, so under the pad rule **no varint
//! ever straddles a block boundary**: decoding one block's worth of
//! neighbors touches exactly one cache line of graph data. Weighted
//! graphs interleave a weight varint after each id varint under the same
//! rule.
//!
//! Per-vertex metadata lives beside the stream: `starts` (byte offset of
//! each row, rows contiguous), `in_degrees` (varint streams do not
//! encode their own element count), `out_degrees` (PageRank divides by
//! the writer's fan-out), and `block_firsts` — the first absolute
//! neighbor id whose varint starts in each block. `block_firsts` is what
//! [`GraphStore::in_neighbor_hint`] returns a window of: the engine's
//! `--prefetch` look-ahead walks block starts, warming the value lines a
//! sweep is about to gather from, without decoding ahead.
//!
//! The whole thing — header, metadata sections, block data — is a single
//! little-endian byte image ([format diagram](CompressedCsr#on-disk-format)
//! in DESIGN.md §12). [`CompressedCsr::from_csr`] builds the image in
//! RAM; `daig convert` writes it to disk; [`CompressedCsr::open_mmap`]
//! maps it read-only via the vendored `memmap2`, validating the header
//! against the file length io.rs-style *before* touching anything else,
//! so graphs larger than RAM stream from disk through the page cache.

use std::fs::File;
use std::io::{BufWriter, Read as _, Write as _};
use std::path::Path;
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};
use memmap2::Mmap;

use crate::graph::{Csr, GraphStore, VertexId};
use crate::CACHE_LINE_BYTES;

/// Magic bytes of the compressed block format.
const MAGIC: &[u8; 4] = b"DAGC";
/// Compressed format version.
const VERSION: u32 = 1;
/// Maximum encoded size of a u32 LEB128 varint.
pub const MAX_VARINT_BYTES: usize = 5;
/// Fixed bytes before the `starts` section: magic + version + flags +
/// reserved + n + m + data_len + nblocks.
const HEADER_BYTES: usize = 4 + 4 + 4 + 4 + 8 + 8 + 8 + 8;

// -------------------------------------------------------------- codec --

/// Append `x` as a LEB128 varint (1–5 bytes).
#[inline]
fn write_varint(out: &mut Vec<u8>, mut x: u32) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Whether a varint may start at byte offset `pos` (pad rule: not within
/// the last `MAX_VARINT_BYTES - 1` bytes of a 64-byte block).
#[inline]
fn needs_pad(pos: usize) -> bool {
    CACHE_LINE_BYTES - (pos % CACHE_LINE_BYTES) < MAX_VARINT_BYTES
}

/// Skip to the next block boundary if the pad rule forbids starting a
/// varint at `*pos` — the decoder half of the rule.
#[inline]
fn skip_pad(pos: &mut usize) {
    if needs_pad(*pos) {
        *pos = (*pos | (CACHE_LINE_BYTES - 1)) + 1;
    }
}

/// Delta-map a row element: the first id is stored absolute, later ids
/// as the gap to their (strictly smaller) predecessor.
#[inline]
fn delta_of(v: VertexId, i: usize, prev: VertexId, id: VertexId) -> u32 {
    if i == 0 {
        id
    } else {
        assert!(id > prev, "row {v} is not strictly ascending at position {i}");
        id - prev
    }
}

/// Decode one varint at `*pos` (applying the pad rule first). The loop
/// is bounded at 5 bytes and the 5th byte contributes only its low 4
/// bits, so hostile streams cannot shift out of range.
#[inline]
fn read_varint(data: &[u8], pos: &mut usize) -> u32 {
    skip_pad(pos);
    let mut x = 0u32;
    let mut shift = 0u32;
    loop {
        let b = data[*pos];
        *pos += 1;
        if shift == 28 {
            // 5th byte: top nibble only; a set continuation bit here is
            // impossible in encoder output and ignored defensively.
            x |= ((b & 0x0f) as u32) << 28;
            return x;
        }
        x |= ((b & 0x7f) as u32) << shift;
        if b & 0x80 == 0 {
            return x;
        }
        shift += 7;
    }
}

/// Streaming encoder for the block data section, tracking per-block
/// first-id metadata as it goes.
struct BlockEncoder {
    data: Vec<u8>,
    /// First absolute id whose varint starts in each completed-or-open
    /// block (extended lazily; blocks with no id start carry the last
    /// id written before them).
    block_firsts: Vec<VertexId>,
    last_id: VertexId,
}

impl BlockEncoder {
    fn new() -> Self {
        Self { data: Vec::new(), block_firsts: Vec::new(), last_id: 0 }
    }

    #[inline]
    fn pad(&mut self) {
        if needs_pad(self.data.len()) {
            let target = (self.data.len() | (CACHE_LINE_BYTES - 1)) + 1;
            self.data.resize(target, 0);
        }
    }

    /// Encode a neighbor id (already delta-mapped to `enc`); `id` is the
    /// absolute value, recorded for the hint table.
    #[inline]
    fn put_id(&mut self, id: VertexId, enc: u32) {
        self.pad();
        let block = self.data.len() / CACHE_LINE_BYTES;
        while self.block_firsts.len() < block {
            // Blocks opened by weights or padding alone: carry the
            // previous id (hints are best-effort neighbors-of-the-area).
            let carry = self.last_id;
            self.block_firsts.push(carry);
        }
        if self.block_firsts.len() == block {
            self.block_firsts.push(id);
        }
        self.last_id = id;
        write_varint(&mut self.data, enc);
    }

    /// Encode a weight (absolute, never delta'd).
    #[inline]
    fn put_weight(&mut self, w: u32) {
        self.pad();
        write_varint(&mut self.data, w);
    }

    /// Pad the stream to a whole number of blocks and square up the
    /// hint table.
    fn finish(mut self) -> (Vec<u8>, Vec<VertexId>) {
        let blocks = self.data.len().div_ceil(CACHE_LINE_BYTES);
        self.data.resize(blocks * CACHE_LINE_BYTES, 0);
        while self.block_firsts.len() < blocks {
            let carry = self.last_id;
            self.block_firsts.push(carry);
        }
        debug_assert_eq!(self.block_firsts.len(), blocks);
        (self.data, self.block_firsts)
    }
}

// ------------------------------------------------------------ backing --

/// Where the byte image lives. Both variants guarantee ≥ 8-byte base
/// alignment (Vec<u64> by type, mmap by page granularity), which the
/// section casts below require.
enum Backing {
    Owned(Vec<u64>, usize),
    Mapped(Mmap),
}

impl Backing {
    #[inline]
    fn bytes(&self) -> &[u8] {
        match self {
            // SAFETY: the Vec owns `len` initialized bytes viewed as u64s.
            Backing::Owned(buf, len) => unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len) },
            Backing::Mapped(m) => m,
        }
    }
}

/// View `count` `T`s at byte offset `off` of `bytes`. Panics (cleanly,
/// after header validation has already bounded everything) on
/// out-of-range or misaligned sections.
#[inline]
fn section<T>(bytes: &[u8], off: usize, count: usize) -> &[T] {
    let size = std::mem::size_of::<T>();
    assert!(off.checked_add(count * size).is_some_and(|end| end <= bytes.len()), "section out of range");
    let p = bytes[off..].as_ptr();
    assert_eq!(p as usize % std::mem::align_of::<T>(), 0, "section misaligned");
    // SAFETY: bounds and alignment checked above; T is u32/u64 (any bit
    // pattern valid); the backing is immutable for the store's lifetime.
    unsafe { std::slice::from_raw_parts(p as *const T, count) }
}

/// Byte offsets of each section within the image (derived from the
/// header once at open/build time).
#[derive(Debug, Clone, Copy)]
struct Sections {
    starts: usize,
    in_deg: usize,
    out_deg: usize,
    block_firsts: usize,
    nblocks: usize,
    data: usize,
    data_len: usize,
}

impl Sections {
    /// Compute the layout for given counts. Also the single source of
    /// truth for the expected image length.
    fn layout(n: usize, nblocks: usize, data_len: usize) -> (Sections, usize) {
        let starts = HEADER_BYTES;
        let in_deg = starts + (n + 1) * 8;
        let out_deg = in_deg + n * 4;
        let block_firsts = out_deg + n * 4;
        let data = (block_firsts + nblocks * 4).next_multiple_of(CACHE_LINE_BYTES);
        let total = data + data_len;
        (Sections { starts, in_deg, out_deg, block_firsts, nblocks, data, data_len }, total)
    }
}

/// Lazily built transpose (push orientation), same shape as `Csr`'s.
#[derive(Debug)]
struct OutEdges {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
}

// ----------------------------------------------------------- the store --

/// The second [`GraphStore`] backend: block-compressed CSR, in RAM or
/// mmap-backed, decoded on the fly inside the pull sweep.
///
/// ## On-disk format
///
/// One little-endian image, identical in RAM and on disk (`.dagc`):
///
/// ```text
/// offset  size           field
/// 0       4              magic "DAGC"
/// 4       4              version (1)
/// 8       4              flags: bit0 weighted, bit1 symmetric
/// 12      4              reserved (0)
/// 16      8              n (vertices)
/// 24      8              m (edges)
/// 32      8              data_len (block data bytes, multiple of 64)
/// 40      8              nblocks (= data_len / 64)
/// 48      8(n+1)         starts: row byte offsets into data
/// ·       4n             in_degrees
/// ·       4n             out_degrees
/// ·       4·nblocks      block_firsts (prefetch hint table)
/// ·       pad to 64      —
/// ·       data_len       delta/varint block data
/// ```
///
/// Sections are naturally aligned (the data section to a cache line),
/// so a page-aligned mmap of the file *is* the working representation —
/// opening a graph allocates O(1) and faults pages in as the sweep
/// touches them.
pub struct CompressedCsr {
    backing: Backing,
    sections: Sections,
    n: usize,
    m: usize,
    weighted: bool,
    symmetric: bool,
    /// Transpose view, decoded on first use (directed graphs only).
    out_view: OnceLock<OutEdges>,
}

impl std::fmt::Debug for CompressedCsr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompressedCsr")
            .field("n", &self.n)
            .field("m", &self.m)
            .field("weighted", &self.weighted)
            .field("symmetric", &self.symmetric)
            .field("blocks", &self.sections.nblocks)
            .field("image_bytes", &self.image().len())
            .field("mmap", &matches!(self.backing, Backing::Mapped(_)))
            .finish()
    }
}

impl PartialEq for CompressedCsr {
    fn eq(&self, other: &Self) -> bool {
        self.image() == other.image()
    }
}

impl CompressedCsr {
    // ------------------------------------------------------ accessors --

    /// The raw byte image (what `write` puts on disk).
    #[inline]
    pub fn image(&self) -> &[u8] {
        self.backing.bytes()
    }

    /// Whether this store reads from a memory-mapped file.
    pub fn is_mmap(&self) -> bool {
        matches!(self.backing, Backing::Mapped(_))
    }

    #[inline]
    fn starts(&self) -> &[u64] {
        section(self.image(), self.sections.starts, self.n + 1)
    }

    #[inline]
    fn in_degrees(&self) -> &[u32] {
        section(self.image(), self.sections.in_deg, self.n)
    }

    /// All out-degrees (indexed by vertex).
    #[inline]
    pub fn out_degrees(&self) -> &[u32] {
        section(self.image(), self.sections.out_deg, self.n)
    }

    #[inline]
    fn block_firsts(&self) -> &[VertexId] {
        section(self.image(), self.sections.block_firsts, self.sections.nblocks)
    }

    #[inline]
    fn data(&self) -> &[u8] {
        &self.image()[self.sections.data..self.sections.data + self.sections.data_len]
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of (directed) edges stored.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// Whether edges carry weights.
    #[inline]
    pub fn is_weighted(&self) -> bool {
        self.weighted
    }

    /// Whether the graph was symmetrized at build time.
    #[inline]
    pub fn is_symmetric(&self) -> bool {
        self.symmetric
    }

    /// In-degree of `v` (from the explicit table — a varint row does not
    /// encode its own element count).
    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        self.in_degrees()[v as usize] as usize
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> u32 {
        self.out_degrees()[v as usize]
    }

    /// Compressed data bytes per edge (the compression headline; the
    /// uncompressed CSR spends 4, plus 4 more when weighted).
    pub fn bytes_per_edge(&self) -> f64 {
        if self.m == 0 {
            0.0
        } else {
            self.sections.data_len as f64 / self.m as f64
        }
    }

    // ------------------------------------------------------- iterators --

    /// Decoding iterator over the in-neighbors of `v`.
    #[inline]
    pub fn in_neighbors(&self, v: VertexId) -> InIter<'_> {
        let s = self.starts();
        InIter {
            data: self.data(),
            pos: s[v as usize] as usize,
            remaining: self.in_degrees()[v as usize],
            prev: 0,
            first: true,
            skip_weights: self.weighted,
        }
    }

    /// Decoding iterator over `(in-neighbor, weight)` pairs. Panics if
    /// the graph is unweighted (same contract as
    /// [`Csr::in_neighbors_weighted`]).
    #[inline]
    pub fn in_neighbors_weighted(&self, v: VertexId) -> InWeightedIter<'_> {
        assert!(self.weighted, "graph is unweighted");
        let s = self.starts();
        InWeightedIter {
            data: self.data(),
            pos: s[v as usize] as usize,
            remaining: self.in_degrees()[v as usize],
            prev: 0,
            first: true,
        }
    }

    /// Out-neighbors of `v`: the in-row on symmetric graphs, the decoded
    /// transpose otherwise (call [`Self::ensure_out_edges`] up front to
    /// keep the build out of timed or multi-threaded regions).
    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> OutIter<'_> {
        if self.symmetric {
            return OutIter::Sym(self.in_neighbors(v));
        }
        let oe = self.out_view.get_or_init(|| self.build_out_edges());
        let lo = oe.offsets[v as usize] as usize;
        let hi = oe.offsets[v as usize + 1] as usize;
        OutIter::Directed(oe.targets[lo..hi].iter().copied())
    }

    /// Force the transpose view to exist (no-op on symmetric graphs).
    pub fn ensure_out_edges(&self) {
        if !self.symmetric {
            let _ = self.out_view.get_or_init(|| self.build_out_edges());
        }
    }

    /// One-shot counting-sort transpose over a full decode pass.
    fn build_out_edges(&self) -> OutEdges {
        let n = self.n;
        let degs = self.out_degrees();
        let mut offsets = vec![0u64; n + 1];
        for (u, &d) in degs.iter().enumerate() {
            offsets[u + 1] = offsets[u] + d as u64;
        }
        let mut next: Vec<u64> = offsets[..n].to_vec();
        let mut targets = vec![0 as VertexId; self.m];
        for v in 0..n as VertexId {
            for u in self.in_neighbors(v) {
                targets[next[u as usize] as usize] = v;
                next[u as usize] += 1;
            }
        }
        OutEdges { offsets, targets }
    }

    /// The block-start hint window for row `v`: the first absolute
    /// neighbor id of every 64-byte block the row touches. Best-effort
    /// by design — shorter than the row (one entry per block, not per
    /// neighbor) and possibly stale at block seams — which is exactly
    /// what the prefetch contract allows.
    #[inline]
    pub fn in_neighbor_hint(&self, v: VertexId) -> &[VertexId] {
        let s = self.starts();
        let lo = s[v as usize] as usize;
        let hi = s[v as usize + 1] as usize;
        if lo == hi {
            return &[];
        }
        &self.block_firsts()[lo / CACHE_LINE_BYTES..hi.div_ceil(CACHE_LINE_BYTES)]
    }

    /// Mean in-degree.
    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m as f64 / self.n as f64
        }
    }

    // ---------------------------------------------------- construction --

    /// Compress a CSR into the block format (in RAM).
    pub fn from_csr(g: &Csr) -> Self {
        let n = g.num_vertices();
        let m = g.num_edges();
        let weighted = g.is_weighted();
        let mut enc = BlockEncoder::new();
        let mut starts = Vec::with_capacity(n + 1);
        for v in 0..n as VertexId {
            starts.push(enc.data.len() as u64);
            let mut prev = 0u32;
            if weighted {
                for (i, (id, w)) in g.in_neighbors_weighted(v).enumerate() {
                    enc.put_id(id, delta_of(v, i, prev, id));
                    enc.put_weight(w);
                    prev = id;
                }
            } else {
                for (i, &id) in g.in_neighbors(v).iter().enumerate() {
                    enc.put_id(id, delta_of(v, i, prev, id));
                    prev = id;
                }
            }
        }
        starts.push(enc.data.len() as u64);
        let (data, block_firsts) = enc.finish();

        let in_degrees: Vec<u32> = (0..n as VertexId).map(|v| g.in_degree(v) as u32).collect();
        Self::assemble(
            n,
            m,
            weighted,
            g.is_symmetric(),
            &starts,
            &in_degrees,
            g.out_degrees(),
            &block_firsts,
            &data,
        )
    }

    /// Build the canonical byte image from its parts.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        n: usize,
        m: usize,
        weighted: bool,
        symmetric: bool,
        starts: &[u64],
        in_degrees: &[u32],
        out_degrees: &[u32],
        block_firsts: &[VertexId],
        data: &[u8],
    ) -> Self {
        debug_assert_eq!(starts.len(), n + 1);
        debug_assert_eq!(data.len() % CACHE_LINE_BYTES, 0);
        let nblocks = data.len() / CACHE_LINE_BYTES;
        debug_assert_eq!(block_firsts.len(), nblocks);
        let (sections, total) = Sections::layout(n, nblocks, data.len());

        let mut buf = vec![0u64; total.div_ceil(8)];
        // SAFETY: plain byte view of the owned, zeroed u64 buffer.
        let bytes = unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, total) };
        bytes[0..4].copy_from_slice(MAGIC);
        bytes[4..8].copy_from_slice(&VERSION.to_le_bytes());
        let flags = (weighted as u32) | ((symmetric as u32) << 1);
        bytes[8..12].copy_from_slice(&flags.to_le_bytes());
        bytes[16..24].copy_from_slice(&(n as u64).to_le_bytes());
        bytes[24..32].copy_from_slice(&(m as u64).to_le_bytes());
        bytes[32..40].copy_from_slice(&(data.len() as u64).to_le_bytes());
        bytes[40..48].copy_from_slice(&(nblocks as u64).to_le_bytes());
        for (i, &x) in starts.iter().enumerate() {
            bytes[sections.starts + i * 8..sections.starts + i * 8 + 8].copy_from_slice(&x.to_le_bytes());
        }
        for (i, &x) in in_degrees.iter().enumerate() {
            bytes[sections.in_deg + i * 4..sections.in_deg + i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        for (i, &x) in out_degrees.iter().enumerate() {
            bytes[sections.out_deg + i * 4..sections.out_deg + i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        for (i, &x) in block_firsts.iter().enumerate() {
            bytes[sections.block_firsts + i * 4..sections.block_firsts + i * 4 + 4]
                .copy_from_slice(&x.to_le_bytes());
        }
        bytes[sections.data..sections.data + data.len()].copy_from_slice(data);

        Self {
            backing: Backing::Owned(buf, total),
            sections,
            n,
            m,
            weighted,
            symmetric,
            out_view: OnceLock::new(),
        }
    }

    // -------------------------------------------------------------- io --

    /// Write the image to `path` (the `.dagc` file `daig convert`
    /// produces).
    pub fn write(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
        w.write_all(self.image())?;
        Ok(())
    }

    /// Open a `.dagc` file read-only through an mmap. Header counts are
    /// validated against the file length *before* the map is touched
    /// (io.rs style: truncated or hostile files return `Err`, never a
    /// huge allocation or a wild section cast), then the metadata
    /// sections get the same structural checks `read_binary` applies.
    pub fn open_mmap(path: &Path) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut header = [0u8; HEADER_BYTES];
        if file_len < HEADER_BYTES as u64 {
            bail!("{path:?}: not a .dagc file ({file_len} bytes is shorter than the header)");
        }
        file.read_exact(&mut header).with_context(|| format!("read {path:?}"))?;
        // SAFETY: read-only open; the file is treated as immutable for
        // the lifetime of the store (standard mmap-loader contract).
        let map = unsafe { Mmap::map(&file) }.with_context(|| format!("mmap {path:?}"))?;
        Self::from_image(Backing::Mapped(map), &header, file_len, path)
    }

    /// Read a `.dagc` file fully into RAM (same validation as
    /// [`Self::open_mmap`]; for hosts where mapping is undesirable).
    pub fn open_in_ram(path: &Path) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut header = [0u8; HEADER_BYTES];
        if file_len < HEADER_BYTES as u64 {
            bail!("{path:?}: not a .dagc file ({file_len} bytes is shorter than the header)");
        }
        file.read_exact(&mut header).with_context(|| format!("read {path:?}"))?;
        // Header-before-allocation: only reserve the buffer once the
        // declared counts reproduce the stat'd length.
        Self::validate_header(&header, file_len, path)?;
        let mut buf = vec![0u64; (file_len as usize).div_ceil(8)];
        // SAFETY: byte view of the owned buffer for read_exact.
        let bytes = unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, file_len as usize) };
        bytes[..HEADER_BYTES].copy_from_slice(&header);
        file.read_exact(&mut bytes[HEADER_BYTES..]).with_context(|| format!("read {path:?}"))?;
        Self::from_image(Backing::Owned(buf, file_len as usize), &header, file_len, path)
    }

    /// Parse + validate the fixed header; returns (n, m, weighted,
    /// symmetric, data_len, nblocks) and checks the implied total length
    /// against `file_len`.
    fn validate_header(
        header: &[u8; HEADER_BYTES],
        file_len: u64,
        path: &Path,
    ) -> Result<(usize, usize, bool, bool, usize, usize)> {
        let u32_at = |o: usize| u32::from_le_bytes(header[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(header[o..o + 8].try_into().unwrap());
        if &header[0..4] != MAGIC {
            bail!("{path:?}: not a .dagc file");
        }
        let version = u32_at(4);
        if version != VERSION {
            bail!("{path:?}: unsupported version {version}");
        }
        let flags = u32_at(8);
        if flags & !3 != 0 {
            bail!("{path:?}: corrupt header: unknown flag bits {flags:#x}");
        }
        let n64 = u64_at(16);
        let m64 = u64_at(24);
        let data_len = u64_at(32);
        let nblocks = u64_at(40);
        if n64 > u32::MAX as u64 {
            bail!("{path:?}: corrupt header: {n64} vertices exceeds the u32 id space");
        }
        if data_len % CACHE_LINE_BYTES as u64 != 0 {
            bail!("{path:?}: corrupt header: data length {data_len} is not a whole number of 64-byte blocks");
        }
        if nblocks != data_len / CACHE_LINE_BYTES as u64 {
            bail!("{path:?}: corrupt header: {nblocks} blocks does not match data length {data_len}");
        }
        // Every edge costs at least one data byte, so m is bounded by
        // the data section — rejects absurd counts before any O(n) work.
        if m64 > data_len {
            bail!("{path:?}: corrupt header: {m64} edges cannot fit in {data_len} data bytes");
        }
        let (_, expected) = Sections::layout(n64 as usize, nblocks as usize, data_len as usize);
        if expected as u64 != file_len {
            bail!(
                "{path:?}: corrupt header: n={n64}, m={m64}, {nblocks} blocks implies a {expected}-byte file, found {file_len} bytes"
            );
        }
        Ok((n64 as usize, m64 as usize, flags & 1 != 0, flags & 2 != 0, data_len as usize, nblocks as usize))
    }

    /// Finish opening from a validated backing image.
    fn from_image(backing: Backing, header: &[u8; HEADER_BYTES], file_len: u64, path: &Path) -> Result<Self> {
        let (n, m, weighted, symmetric, data_len, nblocks) = Self::validate_header(header, file_len, path)?;
        let (sections, _) = Sections::layout(n, nblocks, data_len);
        let g = Self { backing, sections, n, m, weighted, symmetric, out_view: OnceLock::new() };
        // Structural metadata checks (O(n), same spirit as read_binary's
        // monotone-offsets / degree-sum validation).
        let starts = g.starts();
        if starts[0] != 0 || starts.windows(2).any(|w| w[0] > w[1]) {
            bail!("{path:?}: corrupt row starts (not a monotone prefix)");
        }
        if *starts.last().unwrap() as usize > data_len {
            bail!("{path:?}: corrupt row starts (end {} beyond data length {data_len})", starts.last().unwrap());
        }
        if g.in_degrees().iter().map(|&d| d as u64).sum::<u64>() != m as u64 {
            bail!("{path:?}: corrupt in-degrees (sum ≠ edge count {m})");
        }
        if g.out_degrees().iter().map(|&d| d as u64).sum::<u64>() != m as u64 {
            bail!("{path:?}: corrupt out-degrees (sum ≠ edge count {m})");
        }
        Ok(g)
    }

    /// Full O(m) decode validation: every row decodes within its byte
    /// span to strictly ascending in-range ids. Metadata-only validation
    /// happens at open; this pass is for `daig convert --check` and
    /// tests, where the cost of faulting the whole file in is intended.
    pub fn verify_decode(&self) -> Result<()> {
        let starts = self.starts();
        for v in 0..self.n as VertexId {
            let mut prev: Option<VertexId> = None;
            for u in self.in_neighbors(v) {
                if (u as usize) >= self.n {
                    bail!("row {v}: decoded neighbor {u} out of range for n={}", self.n);
                }
                if let Some(p) = prev {
                    if u <= p {
                        bail!("row {v}: decoded neighbors not strictly ascending ({p} then {u})");
                    }
                }
                prev = Some(u);
            }
            let _ = starts;
        }
        Ok(())
    }

    /// Decompress back into a plain [`Csr`] (tests and tooling; the
    /// engine never needs this).
    pub fn to_csr(&self) -> Csr {
        let mut b = crate::graph::GraphBuilder::new(self.n).keep_self_loops();
        if self.weighted {
            b = b.with_weights();
            for v in 0..self.n as VertexId {
                for (u, w) in self.in_neighbors_weighted(v) {
                    b.push(u, v, w);
                }
            }
        } else {
            for v in 0..self.n as VertexId {
                for u in self.in_neighbors(v) {
                    b.push(u, v, 1);
                }
            }
        }
        // The builder recomputes out-degrees from the edges; symmetric
        // graphs round-trip because the paired reverse edges are all
        // present in the rows already.
        let mut g = b.build();
        if self.symmetric {
            g = Csr::from_parts(
                g.offsets().to_vec(),
                g.sources().to_vec(),
                g.weights().map(|w| w.to_vec()),
                g.out_degrees().to_vec(),
                true,
            );
        }
        g
    }
}

// ---------------------------------------------------------- iterators --

/// Decoding iterator over one row's neighbor ids (skipping interleaved
/// weights on weighted graphs).
pub struct InIter<'a> {
    data: &'a [u8],
    pos: usize,
    remaining: u32,
    prev: VertexId,
    first: bool,
    skip_weights: bool,
}

impl Iterator for InIter<'_> {
    type Item = VertexId;

    #[inline]
    fn next(&mut self) -> Option<VertexId> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let x = read_varint(self.data, &mut self.pos);
        let id = if self.first {
            self.first = false;
            x
        } else {
            self.prev.wrapping_add(x)
        };
        self.prev = id;
        if self.skip_weights {
            let _ = read_varint(self.data, &mut self.pos);
        }
        Some(id)
    }

    #[inline]
    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining as usize, Some(self.remaining as usize))
    }
}

impl ExactSizeIterator for InIter<'_> {}

/// Decoding iterator over one row's `(neighbor, weight)` pairs.
pub struct InWeightedIter<'a> {
    data: &'a [u8],
    pos: usize,
    remaining: u32,
    prev: VertexId,
    first: bool,
}

impl Iterator for InWeightedIter<'_> {
    type Item = (VertexId, u32);

    #[inline]
    fn next(&mut self) -> Option<(VertexId, u32)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let x = read_varint(self.data, &mut self.pos);
        let id = if self.first {
            self.first = false;
            x
        } else {
            self.prev.wrapping_add(x)
        };
        self.prev = id;
        let w = read_varint(self.data, &mut self.pos);
        Some((id, w))
    }

    #[inline]
    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining as usize, Some(self.remaining as usize))
    }
}

impl ExactSizeIterator for InWeightedIter<'_> {}

/// Out-neighbor iterator: the in-row on symmetric graphs, a transpose
/// slice otherwise.
pub enum OutIter<'a> {
    Sym(InIter<'a>),
    Directed(std::iter::Copied<std::slice::Iter<'a, VertexId>>),
}

impl Iterator for OutIter<'_> {
    type Item = VertexId;

    #[inline]
    fn next(&mut self) -> Option<VertexId> {
        match self {
            OutIter::Sym(it) => it.next(),
            OutIter::Directed(it) => it.next(),
        }
    }
}

// -------------------------------------------------------- GraphStore --

/// The compressed backend behind the same trait every engine path
/// consumes: generic call sites monomorphize the varint decode straight
/// into the pull sweep — no dispatch, no row materialization.
impl GraphStore for CompressedCsr {
    #[inline]
    fn num_vertices(&self) -> usize {
        CompressedCsr::num_vertices(self)
    }

    #[inline]
    fn num_edges(&self) -> usize {
        CompressedCsr::num_edges(self)
    }

    #[inline]
    fn is_weighted(&self) -> bool {
        CompressedCsr::is_weighted(self)
    }

    #[inline]
    fn is_symmetric(&self) -> bool {
        CompressedCsr::is_symmetric(self)
    }

    #[inline]
    fn in_degree(&self, v: VertexId) -> usize {
        CompressedCsr::in_degree(self, v)
    }

    #[inline]
    fn out_degree(&self, v: VertexId) -> u32 {
        CompressedCsr::out_degree(self, v)
    }

    #[inline]
    fn out_degrees(&self) -> &[u32] {
        CompressedCsr::out_degrees(self)
    }

    #[inline]
    fn in_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        CompressedCsr::in_neighbors(self, v)
    }

    #[inline]
    fn in_neighbors_weighted(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u32)> + '_ {
        CompressedCsr::in_neighbors_weighted(self, v)
    }

    #[inline]
    fn out_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        CompressedCsr::out_neighbors(self, v)
    }

    #[inline]
    fn in_neighbor_hint(&self, v: VertexId) -> &[VertexId] {
        CompressedCsr::in_neighbor_hint(self, v)
    }

    #[inline]
    fn ensure_out_edges(&self) {
        CompressedCsr::ensure_out_edges(self)
    }

    #[inline]
    fn avg_degree(&self) -> f64 {
        CompressedCsr::avg_degree(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;
    use crate::graph::GraphBuilder;
    use crate::prop::{forall, Gen};

    /// Encode one synthetic sorted row (optionally weighted) through the
    /// real encoder, returning (data, block_firsts, degree).
    fn encode_row(ids: &[u32], weights: Option<&[u32]>) -> (Vec<u8>, Vec<u32>) {
        let mut enc = BlockEncoder::new();
        let mut prev = 0u32;
        for (i, &id) in ids.iter().enumerate() {
            let delta = if i == 0 { id } else { id - prev };
            enc.put_id(id, delta);
            if let Some(ws) = weights {
                enc.put_weight(ws[i]);
            }
            prev = id;
        }
        enc.finish()
    }

    fn decode_row(data: &[u8], degree: u32, weighted: bool) -> Vec<u32> {
        InIter { data, pos: 0, remaining: degree, prev: 0, first: true, skip_weights: weighted }.collect()
    }

    fn sorted_unique(mut xs: Vec<u32>) -> Vec<u32> {
        xs.sort_unstable();
        xs.dedup();
        xs
    }

    #[test]
    fn varint_roundtrip_edges() {
        for x in [0u32, 1, 127, 128, 16_383, 16_384, (1 << 28) - 1, 1 << 28, u32::MAX - 1, u32::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, x);
            assert!(buf.len() <= MAX_VARINT_BYTES);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos), x, "{x}");
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn pad_rule_is_symmetric() {
        // Offsets 60..63 of any block forbid a varint start.
        for pos in 0..256usize {
            let forbidden = pos % 64 >= 64 - (MAX_VARINT_BYTES - 1);
            assert_eq!(needs_pad(pos), forbidden, "pos={pos}");
        }
    }

    #[test]
    fn codec_roundtrip_property() {
        forall(128, |g: &mut Gen| {
            let weighted = g.chance(0.5);
            let hi = 1u32 << g.usize(4..31);
            let ids = sorted_unique(g.vec_u32(0..hi, 0, 300));
            let ws: Vec<u32> = (0..ids.len()).map(|_| g.u32(1..1 << 20)).collect();
            let (data, _) = encode_row(&ids, weighted.then_some(ws.as_slice()));
            let got = decode_row(&data, ids.len() as u32, weighted);
            if got != ids {
                return false;
            }
            if weighted {
                let it = InWeightedIter { data: &data, pos: 0, remaining: ids.len() as u32, prev: 0, first: true };
                let pairs: Vec<(u32, u32)> = it.collect();
                return pairs == ids.iter().copied().zip(ws.iter().copied()).collect::<Vec<_>>();
            }
            true
        });
    }

    #[test]
    fn degree_zero_row_is_empty_and_free() {
        let (data, firsts) = encode_row(&[], None);
        assert!(data.is_empty());
        assert!(firsts.is_empty());
        assert_eq!(decode_row(&data, 0, false), Vec::<u32>::new());
    }

    #[test]
    fn max_gap_u32_deltas_roundtrip() {
        // First id absolute at the bottom of the range, then a gap that
        // spans (almost) the whole u32 space — the 5-byte varint tail.
        for row in [vec![0, u32::MAX - 1], vec![1, u32::MAX - 1], vec![0, 1, u32::MAX - 1]] {
            let (data, _) = encode_row(&row, None);
            assert_eq!(decode_row(&data, row.len() as u32, false), row, "{row:?}");
        }
        // Weighted variant with maximal weights.
        let row = vec![0, u32::MAX - 1];
        let ws = vec![u32::MAX, u32::MAX];
        let (data, _) = encode_row(&row, Some(&ws));
        let pairs: Vec<(u32, u32)> =
            InWeightedIter { data: &data, pos: 0, remaining: 2, prev: 0, first: true }.collect();
        assert_eq!(pairs, vec![(0, u32::MAX), (u32::MAX - 1, u32::MAX)]);
    }

    #[test]
    fn no_varint_straddles_a_block_boundary() {
        // Wide ids force 5-byte varints, maximizing pad events; the
        // property is that re-decoding stays in lockstep anyway, and
        // that every varint start obeys the pad rule.
        forall(64, |g: &mut Gen| {
            let base = 1u32 << 28; // every delta ≥ 2^28 ⇒ 5-byte varints
            let n = g.usize(1..100);
            let mut ids = Vec::with_capacity(n);
            let mut cur = g.u32(0..base);
            for _ in 0..n {
                ids.push(cur);
                let room = (u32::MAX - 2).saturating_sub(cur);
                if room <= base {
                    break;
                }
                cur += base + g.u32(0..(room - base).min(1 << 20) + 1);
            }
            let (data, _) = encode_row(&ids, None);
            // Walk the stream the decoder's way, asserting each varint
            // start position is legal.
            let mut pos = 0usize;
            for _ in 0..ids.len() {
                skip_pad(&mut pos);
                assert!(!needs_pad(pos));
                let start_block = pos / CACHE_LINE_BYTES;
                let _ = read_varint(&data, &mut pos);
                assert_eq!((pos - 1) / CACHE_LINE_BYTES, start_block, "varint straddled a block");
            }
            decode_row(&data, ids.len() as u32, false) == ids
        });
    }

    #[test]
    fn block_firsts_cover_every_block() {
        let g = GapGraph::Kron.generate(10, 8);
        let c = CompressedCsr::from_csr(&g);
        assert_eq!(c.block_firsts().len(), c.sections.nblocks);
        // Hint windows are consistent: each row's window holds ids from
        // the graph's id space (best-effort, but never garbage).
        for v in 0..g.num_vertices() as VertexId {
            for &h in c.in_neighbor_hint(v) {
                assert!((h as usize) < c.num_vertices(), "hint {h} out of range");
            }
        }
    }

    #[test]
    fn compressed_matches_csr_rows_gap_suite() {
        for gg in crate::graph::gap::ALL {
            for weighted in [false, true] {
                let g = if weighted { gg.generate_weighted(9, 4) } else { gg.generate(9, 4) };
                let c = CompressedCsr::from_csr(&g);
                assert_eq!(c.num_vertices(), g.num_vertices());
                assert_eq!(c.num_edges(), g.num_edges());
                assert_eq!(c.is_weighted(), g.is_weighted());
                assert_eq!(c.is_symmetric(), g.is_symmetric());
                assert_eq!(c.out_degrees(), g.out_degrees());
                for v in 0..g.num_vertices() as VertexId {
                    let want: Vec<VertexId> = g.in_neighbors(v).to_vec();
                    let got: Vec<VertexId> = c.in_neighbors(v).collect();
                    assert_eq!(got, want, "{} v{v}", gg.name());
                    assert_eq!(c.in_degree(v), g.in_degree(v));
                    if weighted {
                        let want: Vec<(VertexId, u32)> = g.in_neighbors_weighted(v).collect();
                        let got: Vec<(VertexId, u32)> = c.in_neighbors_weighted(v).collect();
                        assert_eq!(got, want, "{} v{v} weighted", gg.name());
                    }
                    let want_out: Vec<VertexId> = g.out_neighbors(v).to_vec();
                    let got_out: Vec<VertexId> = c.out_neighbors(v).collect();
                    assert_eq!(got_out, want_out, "{} v{v} out", gg.name());
                }
                c.verify_decode().unwrap();
            }
        }
    }

    #[test]
    fn random_graph_roundtrips_through_builder() {
        forall(24, |g: &mut Gen| {
            let n = g.usize(1..200);
            let m = g.usize(0..400);
            let edges = g.edges(n, m);
            let base = GraphBuilder::new(n).edges(&edges).build();
            let c = CompressedCsr::from_csr(&base);
            (0..n as VertexId).all(|v| c.in_neighbors(v).collect::<Vec<_>>() == base.in_neighbors(v))
        });
    }

    #[test]
    fn compression_actually_compresses() {
        // Kron rows are locality-friendly; delta+varint must beat the
        // flat 4 bytes/edge by a wide margin.
        let g = GapGraph::Kron.generate(12, 8);
        let c = CompressedCsr::from_csr(&g);
        assert!(c.bytes_per_edge() < 3.0, "bytes/edge = {}", c.bytes_per_edge());
        // And the whole image undercuts the uncompressed arrays.
        let csr_bytes = g.offsets().len() * 8 + g.sources().len() * 4 + g.out_degrees().len() * 4;
        assert!(c.image().len() < csr_bytes, "{} vs {}", c.image().len(), csr_bytes);
    }

    #[test]
    fn write_open_roundtrip_mmap_and_ram() {
        let dir = std::env::temp_dir().join("daig-compressed-tests");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, weighted) in [("rt.dagc", false), ("rtw.dagc", true)] {
            let g = if weighted {
                GapGraph::Web.generate_weighted(9, 4)
            } else {
                GapGraph::Web.generate(9, 4)
            };
            let c = CompressedCsr::from_csr(&g);
            let p = dir.join(name);
            c.write(&p).unwrap();
            let mm = CompressedCsr::open_mmap(&p).unwrap();
            assert!(mm.is_mmap());
            assert_eq!(mm, c, "mmap image differs");
            let ram = CompressedCsr::open_in_ram(&p).unwrap();
            assert!(!ram.is_mmap());
            assert_eq!(ram, c, "in-RAM image differs");
            for v in [0u32, 1, (g.num_vertices() / 2) as u32, (g.num_vertices() - 1) as u32] {
                assert_eq!(mm.in_neighbors(v).collect::<Vec<_>>(), g.in_neighbors(v));
            }
            let rt = mm.to_csr();
            assert_eq!(rt.offsets(), g.offsets(), "decompressed offsets differ");
            assert_eq!(rt.sources(), g.sources(), "decompressed sources differ");
            assert_eq!(rt.weights(), g.weights(), "decompressed weights differ");
            assert_eq!(rt.out_degrees(), g.out_degrees(), "decompressed out-degrees differ");
            assert_eq!(rt.is_symmetric(), g.is_symmetric());
        }
    }

    #[test]
    fn open_rejects_corrupt_files() {
        let dir = std::env::temp_dir().join("daig-compressed-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("garbage.dagc");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(CompressedCsr::open_mmap(&p).is_err());
        std::fs::write(&p, b"NOPEnopeNOPEnopeNOPEnopeNOPEnopeNOPEnopeNOPEnope").unwrap();
        assert!(CompressedCsr::open_mmap(&p).unwrap_err().to_string().contains("not a .dagc"));

        // Truncation: valid image cut short must fail the length check.
        let g = GapGraph::Kron.generate(8, 4);
        let c = CompressedCsr::from_csr(&g);
        let full = c.image().to_vec();
        let p = dir.join("trunc.dagc");
        std::fs::write(&p, &full[..full.len() - 17]).unwrap();
        let err = CompressedCsr::open_mmap(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt header"), "{err}");

        // Bit-flipped degree table: sum check must catch it.
        let mut bad = full.clone();
        let (s, _) = Sections::layout(g.num_vertices(), c.sections.nblocks, c.sections.data_len);
        bad[s.in_deg] ^= 0x01;
        let p = dir.join("deg.dagc");
        std::fs::write(&p, &bad).unwrap();
        let err = CompressedCsr::open_mmap(&p).unwrap_err().to_string();
        assert!(err.contains("in-degrees"), "{err}");
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).edges(&[]).build();
        let c = CompressedCsr::from_csr(&g);
        assert_eq!(c.num_vertices(), 0);
        assert_eq!(c.num_edges(), 0);
        assert_eq!(c.avg_degree(), 0.0);
    }

    #[test]
    fn isolated_vertices_have_empty_rows_and_hints() {
        let g = GraphBuilder::new(5).edges(&[(0, 4)]).build();
        let c = CompressedCsr::from_csr(&g);
        for v in 1..4u32 {
            assert_eq!(c.in_degree(v), 0);
            assert_eq!(c.in_neighbors(v).count(), 0);
            assert_eq!(c.in_neighbor_hint(v), &[] as &[u32]);
        }
        assert_eq!(c.in_neighbors(4).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn trait_view_matches_inherent() {
        let g = GraphBuilder::new(4).weighted_edges(&[(0, 1, 7), (2, 1, 3), (1, 3, 9), (3, 0, 2)]).build();
        let c = CompressedCsr::from_csr(&g);
        assert_eq!(GraphStore::num_edges(&c), 4);
        assert!(GraphStore::is_weighted(&c));
        for v in 0..4u32 {
            let through_trait: Vec<VertexId> = GraphStore::in_neighbors(&c, v).collect();
            assert_eq!(through_trait, g.in_neighbors(v), "v{v}");
            assert_eq!(GraphStore::in_degree(&c, v), g.in_degree(v));
        }
    }
}
