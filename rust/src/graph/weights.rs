//! Edge-weight assignment for SSSP workloads.
//!
//! The GAP benchmark assigns each edge a uniformly random integer weight
//! in `[1, 255]`; the paper's Bellman-Ford runs "use the given weights
//! for each of the GAP graphs". We reproduce that policy deterministically
//! from a seed so weighted graphs are reproducible.
//!
//! Weights are assigned per *undirected pair*: edge (u,v) and its reverse
//! (v,u) get the same weight on symmetric graphs, as GAP does.

use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::SplitMix64;

/// GAP weight range.
pub const MIN_WEIGHT: u32 = 1;
/// GAP weight range.
pub const MAX_WEIGHT: u32 = 255;

/// Hash-derived weight for the unordered pair `{u,v}` — both directions
/// of an undirected edge get the same value without any coordination.
fn pair_weight(u: u32, v: u32, seed: u64) -> u32 {
    let (a, b) = if u <= v { (u, v) } else { (v, u) };
    let mut h = SplitMix64::new(seed ^ ((a as u64) << 32 | b as u64));
    h.range_u32(MIN_WEIGHT, MAX_WEIGHT)
}

/// Produce a weighted copy of `g` with GAP-style uniform weights.
pub fn assign_uniform(g: &Csr, seed: u64) -> Csr {
    let mut b = GraphBuilder::new(g.num_vertices()).with_weights();
    if g.is_symmetric() {
        b = b.symmetrize();
        // Emit each undirected edge once; symmetrize restores the pair
        // with equal weights.
        for (s, d, _) in g.edges() {
            if s <= d {
                b.push(s, d, pair_weight(s, d, seed));
            }
        }
    } else {
        for (s, d, _) in g.edges() {
            b.push(s, d, pair_weight(s, d, seed));
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{rmat, web};

    #[test]
    fn weights_in_gap_range() {
        let g = assign_uniform(&rmat::generate(8, 4, 1), 42);
        assert!(g.is_weighted());
        for (_, _, w) in g.edges() {
            assert!((MIN_WEIGHT..=MAX_WEIGHT).contains(&w));
        }
    }

    #[test]
    fn symmetric_pairs_share_weight() {
        let g = assign_uniform(&rmat::generate(8, 4, 2), 7);
        // For every edge (s,d,w), the reverse must exist with weight w.
        for (s, d, w) in g.edges() {
            let rev: Vec<_> = g.in_neighbors_weighted(s).filter(|&(u, _)| u == d).collect();
            assert_eq!(rev, vec![(d, w)], "asymmetric weight for ({s},{d})");
        }
    }

    #[test]
    fn preserves_structure() {
        let base = web::generate(8, 4, 3);
        let g = assign_uniform(&base, 9);
        assert_eq!(g.num_vertices(), base.num_vertices());
        assert_eq!(g.num_edges(), base.num_edges());
        let mut a: Vec<_> = base.edges().map(|(s, d, _)| (s, d)).collect();
        let mut b: Vec<_> = g.edges().map(|(s, d, _)| (s, d)).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn deterministic_in_seed() {
        let base = rmat::generate(7, 4, 5);
        assert_eq!(assign_uniform(&base, 1), assign_uniform(&base, 1));
        assert_ne!(assign_uniform(&base, 1), assign_uniform(&base, 2));
    }
}
