//! Compressed sparse row storage over incoming edges.
//!
//! For pull-style algorithms each output vertex `v` scans its in-neighbor
//! list once per round; [`Csr`] therefore stores, for each vertex, the
//! sorted list of sources of its incoming edges (plus parallel edge
//! weights when present). Out-degrees are kept alongside because PageRank
//! divides each neighbor's score by *its* out-degree.

use std::sync::OnceLock;

/// Vertex identifier. 32 bits everywhere, matching the paper's element
/// sizing (δ is measured in 32-bit elements).
pub type VertexId = u32;

/// Lazily built transpose (push orientation): `offsets[u]..offsets[u+1]`
/// indexes `targets`, listing the vertices `u` has an edge *to*. Needed
/// by frontier scheduling (a changed vertex activates its out-neighbors).
#[derive(Debug, Clone)]
struct OutEdges {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
}

/// Immutable graph in pull orientation (row `v` = in-neighbors of `v`).
#[derive(Debug)]
pub struct Csr {
    /// `offsets[v]..offsets[v+1]` indexes `sources` (and `weights`).
    offsets: Vec<u64>,
    /// Concatenated in-neighbor lists, each sorted ascending.
    sources: Vec<VertexId>,
    /// Optional per-edge weights, parallel to `sources`.
    weights: Option<Vec<u32>>,
    /// Out-degree of every vertex (pull algorithms need the *writer's*
    /// fan-out, which CSC rows do not encode).
    out_degrees: Vec<u32>,
    /// True if built via symmetrization (undirected semantics).
    symmetric: bool,
    /// Transpose view, built on first use. Symmetric graphs never build
    /// it (out-neighbors == in-neighbors).
    out_view: OnceLock<OutEdges>,
}

impl Clone for Csr {
    fn clone(&self) -> Self {
        // The transpose cache is derived data; rebuild lazily in clones.
        Self {
            offsets: self.offsets.clone(),
            sources: self.sources.clone(),
            weights: self.weights.clone(),
            out_degrees: self.out_degrees.clone(),
            symmetric: self.symmetric,
            out_view: OnceLock::new(),
        }
    }
}

impl PartialEq for Csr {
    fn eq(&self, other: &Self) -> bool {
        self.offsets == other.offsets
            && self.sources == other.sources
            && self.weights == other.weights
            && self.out_degrees == other.out_degrees
            && self.symmetric == other.symmetric
    }
}

impl Csr {
    pub(crate) fn from_parts(
        offsets: Vec<u64>,
        sources: Vec<VertexId>,
        weights: Option<Vec<u32>>,
        out_degrees: Vec<u32>,
        symmetric: bool,
    ) -> Self {
        debug_assert_eq!(offsets.len(), out_degrees.len() + 1);
        debug_assert_eq!(*offsets.last().unwrap_or(&0) as usize, sources.len());
        if let Some(w) = &weights {
            debug_assert_eq!(w.len(), sources.len());
        }
        Self { offsets, sources, weights, out_degrees, symmetric, out_view: OnceLock::new() }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.out_degrees.len()
    }

    /// Number of (directed) edges stored.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.sources.len()
    }

    /// Whether the graph carries edge weights.
    #[inline]
    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// Whether the graph was symmetrized at build time.
    #[inline]
    pub fn is_symmetric(&self) -> bool {
        self.symmetric
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> u32 {
        self.out_degrees[v as usize]
    }

    /// All out-degrees (indexed by vertex).
    #[inline]
    pub fn out_degrees(&self) -> &[u32] {
        &self.out_degrees
    }

    /// Sorted in-neighbors of `v`.
    #[inline]
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.sources[lo..hi]
    }

    /// Out-neighbors of `v` (targets of `v`'s outgoing edges), sorted
    /// ascending. Symmetric graphs answer from the pull lists directly;
    /// directed graphs build (and cache) the transpose on first use —
    /// call [`Self::ensure_out_edges`] up front to keep the build out of
    /// timed or multi-threaded regions.
    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        if self.symmetric {
            return self.in_neighbors(v);
        }
        let oe = self.out_view.get_or_init(|| self.build_out_edges());
        let lo = oe.offsets[v as usize] as usize;
        let hi = oe.offsets[v as usize + 1] as usize;
        &oe.targets[lo..hi]
    }

    /// Force the transpose view to exist (no-op on symmetric graphs).
    pub fn ensure_out_edges(&self) {
        if !self.symmetric {
            let _ = self.out_view.get_or_init(|| self.build_out_edges());
        }
    }

    /// Counting-sort transpose of the pull lists: O(n + m).
    fn build_out_edges(&self) -> OutEdges {
        let n = self.num_vertices();
        let mut offsets = vec![0u64; n + 1];
        for (u, &d) in self.out_degrees.iter().enumerate() {
            offsets[u + 1] = offsets[u] + d as u64;
        }
        let mut next: Vec<u64> = offsets[..n].to_vec();
        let mut targets = vec![0 as VertexId; self.sources.len()];
        // Visiting destinations in ascending order leaves each target
        // list sorted ascending, matching the pull rows' convention.
        for v in 0..n as VertexId {
            for &u in self.in_neighbors(v) {
                targets[next[u as usize] as usize] = v;
                next[u as usize] += 1;
            }
        }
        OutEdges { offsets, targets }
    }

    /// In-neighbors of `v` zipped with edge weights. Panics if unweighted.
    #[inline]
    pub fn in_neighbors_weighted(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u32)> + '_ {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        let w = self.weights.as_ref().expect("graph is unweighted");
        self.sources[lo..hi].iter().copied().zip(w[lo..hi].iter().copied())
    }

    /// Raw offsets array (len = n+1).
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw concatenated sources array.
    #[inline]
    pub fn sources(&self) -> &[VertexId] {
        &self.sources
    }

    /// Raw weights array if present.
    #[inline]
    pub fn weights(&self) -> Option<&[u32]> {
        self.weights.as_deref()
    }

    /// Iterate all edges as `(src, dst, weight)` (weight 1 if unweighted).
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId, u32)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |v| {
            let lo = self.offsets[v as usize] as usize;
            let hi = self.offsets[v as usize + 1] as usize;
            (lo..hi).map(move |i| {
                let w = self.weights.as_ref().map(|w| w[i]).unwrap_or(1);
                (self.sources[i], v, w)
            })
        })
    }

    /// Total in-degree over a contiguous vertex range — the partitioners'
    /// balance objective.
    pub fn range_in_edges(&self, lo: VertexId, hi: VertexId) -> u64 {
        self.offsets[hi as usize] - self.offsets[lo as usize]
    }

    /// Mean in-degree.
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_vertices() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    #[test]
    fn tiny_graph_pull_lists() {
        // 0->1, 0->2, 1->2, 2->0
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2), (1, 2), (2, 0)]).build();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.in_neighbors(0), &[2]);
        assert_eq!(g.in_neighbors(1), &[0]);
        assert_eq!(g.in_neighbors(2), &[0, 1]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(1), 1);
        assert_eq!(g.out_degree(2), 1);
        assert_eq!(g.in_degree(2), 2);
    }

    #[test]
    fn edges_iterator_roundtrip() {
        let input = [(0u32, 1u32), (2, 1), (1, 0)];
        let g = GraphBuilder::new(3).edges(&input).build();
        let mut got: Vec<(u32, u32)> = g.edges().map(|(s, d, _)| (s, d)).collect();
        got.sort_unstable();
        let mut want = input.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn range_in_edges_matches_sum() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (0, 2), (3, 2), (2, 3), (1, 3)]).build();
        let total: u64 = (0..4).map(|v| g.in_degree(v) as u64).sum();
        assert_eq!(g.range_in_edges(0, 4), total);
        assert_eq!(g.range_in_edges(1, 3), (g.in_degree(1) + g.in_degree(2)) as u64);
    }

    #[test]
    fn weighted_access() {
        let g = GraphBuilder::new(2).weighted_edges(&[(0, 1, 7), (1, 0, 9)]).build();
        assert!(g.is_weighted());
        let nb: Vec<_> = g.in_neighbors_weighted(1).collect();
        assert_eq!(nb, vec![(0, 7)]);
    }

    #[test]
    #[should_panic(expected = "unweighted")]
    fn weighted_access_on_unweighted_panics() {
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let _ = g.in_neighbors_weighted(1).count();
    }

    #[test]
    fn out_neighbors_directed() {
        // 0->1, 0->2, 1->2, 2->0
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2), (1, 2), (2, 0)]).build();
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_neighbors(1), &[2]);
        assert_eq!(g.out_neighbors(2), &[0]);
        for v in 0..3u32 {
            assert_eq!(g.out_neighbors(v).len(), g.out_degree(v) as usize, "v{v}");
        }
    }

    #[test]
    fn out_neighbors_symmetric_alias_pull_rows() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).symmetrize().build();
        for v in 0..4u32 {
            assert_eq!(g.out_neighbors(v), g.in_neighbors(v), "v{v}");
        }
    }

    #[test]
    fn out_neighbors_transpose_consistent() {
        let g = GraphBuilder::new(6).edges(&[(0, 3), (5, 1), (2, 4), (2, 0), (4, 2), (3, 3)]).build();
        // Every pull edge (u in row v) appears as v in u's push row.
        for v in 0..6u32 {
            for &u in g.in_neighbors(v) {
                assert!(g.out_neighbors(u).contains(&v), "{u}->{v} missing from transpose");
            }
        }
        let out_total: usize = (0..6u32).map(|v| g.out_neighbors(v).len()).sum();
        assert_eq!(out_total, g.num_edges());
    }

    #[test]
    fn clone_and_eq_ignore_transpose_cache() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let _ = g.out_neighbors(0); // populate the cache
        let h = g.clone();
        assert_eq!(g, h);
        assert_eq!(h.out_neighbors(1), &[2]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).edges(&[]).build();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.avg_degree(), 0.0);
    }

    #[test]
    fn isolated_vertices() {
        let g = GraphBuilder::new(5).edges(&[(0, 4)]).build();
        for v in 1..4 {
            assert_eq!(g.in_degree(v), 0);
            assert_eq!(g.in_neighbors(v), &[] as &[u32]);
        }
        assert_eq!(g.in_neighbors(4), &[0]);
    }
}
