//! Graph serialization: plain edge-list text, a compact binary format,
//! and Matrix Market import — so users can run the engine on their own
//! graphs (including the real GAP downloads) rather than only the
//! synthetic suite.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::{Csr, GraphBuilder};

/// Magic bytes of the binary `.daig` format.
const MAGIC: &[u8; 4] = b"DAIG";
/// Binary format version.
const VERSION: u32 = 1;

// ---------------------------------------------------------------- text --

/// Write as whitespace-separated edge list (`src dst [weight]` per line).
pub fn write_edge_list(g: &Csr, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "# daig edge list: {} vertices, {} edges", g.num_vertices(), g.num_edges())?;
    for (s, d, wt) in g.edges() {
        if g.is_weighted() {
            writeln!(w, "{s} {d} {wt}")?;
        } else {
            writeln!(w, "{s} {d}")?;
        }
    }
    Ok(())
}

/// Read a whitespace-separated edge list. Lines starting with `#` or `%`
/// are comments. Vertex count is `max id + 1` unless `n` is given; an
/// explicit `n` smaller than some vertex id is a clean line-numbered
/// `Err` here, and the fallible `try_build` backstop catches anything
/// that slips through.
pub fn read_edge_list(path: &Path, n: Option<usize>, symmetrize: bool) -> Result<Csr> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut triples: Vec<(u32, u32, u32)> = Vec::new();
    let mut weighted = false;
    let mut max_id = 0u32;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let s: u32 = it.next().context("missing src")?.parse().with_context(|| format!("line {}", lineno + 1))?;
        let d: u32 = it.next().with_context(|| format!("line {}: missing dst", lineno + 1))?.parse()?;
        let w: u32 = match it.next() {
            Some(ws) => {
                weighted = true;
                ws.parse()?
            }
            None => 1,
        };
        if let Some(nv) = n {
            if s as usize >= nv || d as usize >= nv {
                bail!(
                    "{path:?}: line {}: vertex id {} out of range for n={nv}",
                    lineno + 1,
                    s.max(d)
                );
            }
        }
        max_id = max_id.max(s).max(d);
        triples.push((s, d, w));
    }
    let n = n.unwrap_or(if triples.is_empty() { 0 } else { max_id as usize + 1 });
    let mut b = GraphBuilder::new(n);
    if weighted {
        b = b.with_weights();
    }
    if symmetrize {
        b = b.symmetrize();
    }
    for (s, d, w) in triples {
        b.push(s, d, w);
    }
    b.try_build().with_context(|| format!("{path:?}"))
}

// -------------------------------------------------------------- binary --

fn put_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn put_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn get_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write the compact binary `.daig` format (offsets + sources (+weights)).
pub fn write_binary(g: &Csr, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    put_u32(&mut w, VERSION)?;
    let flags = (g.is_weighted() as u32) | ((g.is_symmetric() as u32) << 1);
    put_u32(&mut w, flags)?;
    put_u64(&mut w, g.num_vertices() as u64)?;
    put_u64(&mut w, g.num_edges() as u64)?;
    for &o in g.offsets() {
        put_u64(&mut w, o)?;
    }
    for &s in g.sources() {
        put_u32(&mut w, s)?;
    }
    for &d in g.out_degrees() {
        put_u32(&mut w, d)?;
    }
    if let Some(ws) = g.weights() {
        for &x in ws {
            put_u32(&mut w, x)?;
        }
    }
    Ok(())
}

/// Bytes before the offsets array: magic + version + flags + n + m.
const HEADER_BYTES: u64 = 4 + 4 + 4 + 8 + 8;

/// Read the binary `.daig` format.
///
/// The header's `n`/`m` counts are validated against the actual file
/// length *before* sizing any allocation: a truncated or garbage file
/// returns `Err` instead of aborting the process on a huge `Vec`
/// reservation.
pub fn read_binary(path: &Path) -> Result<Csr> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic == b"DAGC" {
        bail!(
            "{path:?}: this is a block-compressed .dagc file — load it with --store compressed / --mmap \
             (CompressedCsr::open_mmap), not the .daig reader"
        );
    }
    if &magic != MAGIC {
        bail!("{path:?}: not a .daig file");
    }
    let version = get_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let flags = get_u32(&mut r)?;
    if flags & !3 != 0 {
        bail!("{path:?}: corrupt header: unknown flag bits {flags:#x}");
    }
    let weighted = flags & 1 != 0;
    let symmetric = flags & 2 != 0;
    let n64 = get_u64(&mut r)?;
    let m64 = get_u64(&mut r)?;
    if n64 > u32::MAX as u64 {
        bail!("{path:?}: corrupt header: {n64} vertices exceeds the u32 id space");
    }
    if m64 > file_len / 4 {
        bail!("{path:?}: corrupt header: {m64} edges cannot fit in a {file_len}-byte file");
    }
    let expected = HEADER_BYTES + (n64 + 1) * 8 + n64 * 4 + m64 * 4 * if weighted { 2 } else { 1 };
    if expected != file_len {
        bail!("{path:?}: corrupt header: n={n64}, m={m64} implies a {expected}-byte file, found {file_len} bytes");
    }
    let (n, m) = (n64 as usize, m64 as usize);
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(get_u64(&mut r)?);
    }
    if offsets[0] != 0 || offsets.windows(2).any(|w| w[0] > w[1]) {
        bail!("{path:?}: corrupt offsets (not a monotone prefix sum)");
    }
    if *offsets.last().unwrap() as usize != m {
        bail!("{path:?}: corrupt offsets (end {} ≠ edge count {m})", offsets.last().unwrap());
    }
    let mut sources = Vec::with_capacity(m);
    for _ in 0..m {
        let s = get_u32(&mut r)?;
        if s as u64 >= n64 {
            bail!("{path:?}: corrupt source vertex {s} (n={n})");
        }
        sources.push(s);
    }
    let mut out_degrees = Vec::with_capacity(n);
    for _ in 0..n {
        out_degrees.push(get_u32(&mut r)?);
    }
    if out_degrees.iter().map(|&d| d as u64).sum::<u64>() != m64 {
        bail!("{path:?}: corrupt out-degrees (sum ≠ edge count {m})");
    }
    let weights = if weighted {
        let mut ws = Vec::with_capacity(m);
        for _ in 0..m {
            ws.push(get_u32(&mut r)?);
        }
        Some(ws)
    } else {
        None
    };
    Ok(Csr::from_parts(offsets, sources, weights, out_degrees, symmetric))
}

// ------------------------------------------------------- matrix market --

/// Read a MatrixMarket `coordinate` file as a graph (1-based indices;
/// `pattern` fields unweighted, otherwise weights are rounded to u32).
///
/// The banner and its qualifiers are matched case-insensitively (the
/// format spec says `%%MatrixMarket` is not case-sensitive and files
/// with `Symmetric`/`PATTERN` exist in the wild). Malformed content —
/// 0-based or out-of-range indices, unparsable weight fields — is
/// rejected with the offending line number instead of silently coerced
/// or left to blow up in the graph builder.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut lines = r.lines();
    let header = lines.next().context("empty file")??;
    let banner = header.to_ascii_lowercase();
    if !banner.starts_with("%%matrixmarket") {
        bail!("{path:?}: line 1: missing %%MatrixMarket banner");
    }
    let symmetric = banner.contains("symmetric");
    let pattern = banner.contains("pattern");
    let mut dims: Option<(u64, u64)> = None;
    let mut b: Option<GraphBuilder> = None;
    for (k, line) in lines.enumerate() {
        let lineno = k + 2; // 1-based, after the banner line
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let Some((rows, cols)) = dims else {
            let rows: u64 = field(path, lineno, it.next(), "row count")?;
            let cols: u64 = field(path, lineno, it.next(), "column count")?;
            if rows.max(cols) > u32::MAX as u64 {
                bail!("{path:?}: line {lineno}: {rows}x{cols} exceeds the u32 vertex id space");
            }
            dims = Some((rows, cols));
            let mut builder = GraphBuilder::new(rows.max(cols) as usize);
            if !pattern {
                builder = builder.with_weights();
            }
            if symmetric {
                builder = builder.symmetrize();
            }
            b = Some(builder);
            continue;
        };
        let i: u64 = field(path, lineno, it.next(), "row index")?;
        let j: u64 = field(path, lineno, it.next(), "column index")?;
        if i == 0 || j == 0 {
            bail!("{path:?}: line {lineno}: MatrixMarket indices are 1-based, got ({i}, {j})");
        }
        if i > rows || j > cols {
            bail!("{path:?}: line {lineno}: entry ({i}, {j}) out of range for a {rows}x{cols} matrix");
        }
        let w = if pattern {
            1
        } else {
            match it.next() {
                None => 1,
                Some(ws) => {
                    let x: f64 = ws
                        .parse()
                        .map_err(|_| anyhow::anyhow!("{path:?}: line {lineno}: bad weight field '{ws}'"))?;
                    if !x.is_finite() {
                        bail!("{path:?}: line {lineno}: non-finite weight '{ws}'");
                    }
                    (x.abs().round() as u32).max(1)
                }
            }
        };
        b.as_mut().unwrap().push((i - 1) as u32, (j - 1) as u32, w);
    }
    b.with_context(|| format!("{path:?}: no size line"))?.try_build().with_context(|| format!("{path:?}"))
}

/// Parse one whitespace-separated field with file/line context.
fn field<T: std::str::FromStr>(path: &Path, lineno: usize, tok: Option<&str>, what: &str) -> Result<T> {
    let tok = tok.with_context(|| format!("{path:?}: line {lineno}: missing {what}"))?;
    tok.parse().map_err(|_| anyhow::anyhow!("{path:?}: line {lineno}: bad {what} '{tok}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("daig-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = GapGraph::Twitter.generate(8, 4);
        let p = tmp("t.el");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, Some(g.num_vertices()), false).unwrap();
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.sources(), g2.sources());
    }

    #[test]
    fn weighted_edge_list_roundtrip() {
        let g = GapGraph::Twitter.generate_weighted(7, 4);
        let p = tmp("tw.el");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, Some(g.num_vertices()), false).unwrap();
        assert_eq!(g.weights(), g2.weights());
    }

    #[test]
    fn binary_roundtrip_exact() {
        for gg in [GapGraph::Kron, GapGraph::Web] {
            let g = gg.generate_weighted(8, 4);
            let p = tmp(&format!("{}.daig", gg.name()));
            write_binary(&g, &p).unwrap();
            let g2 = read_binary(&p).unwrap();
            assert_eq!(g, g2, "{}", gg.name());
        }
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.daig");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn binary_reader_redirects_compressed_files() {
        // A .dagc image handed to the .daig reader names the right tool
        // instead of reporting generic corruption.
        let g = GapGraph::Kron.generate(7, 4);
        let c = crate::graph::CompressedCsr::from_csr(&g);
        let p = tmp("misfiled.daig");
        c.write(&p).unwrap();
        let err = read_binary(&p).unwrap_err().to_string();
        assert!(err.contains("--store compressed"), "{err}");
    }

    #[test]
    fn matrix_market_basic() {
        let p = tmp("m.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 3\n1 2 5.0\n2 3 1.5\n3 1 2.0\n",
        )
        .unwrap();
        let g = read_matrix_market(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert!(g.is_weighted());
        assert_eq!(g.in_neighbors(1), &[0]);
    }

    #[test]
    fn matrix_market_symmetric_pattern() {
        let p = tmp("sp.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 1\n1 2\n").unwrap();
        let g = read_matrix_market(&p).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert!(!g.is_weighted());
    }

    #[test]
    fn edge_list_comments_and_blank_lines() {
        let p = tmp("c.el");
        std::fs::write(&p, "# hi\n\n0 1\n% also comment\n1 2 9\n").unwrap();
        let g = read_edge_list(&p, None, false).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
        assert!(g.is_weighted());
    }
}
