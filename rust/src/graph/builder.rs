//! Edge-list → [`Csr`] construction.
//!
//! Handles the messiness real edge lists have: duplicate edges, self
//! loops, unsorted input. Duplicates are removed (keeping the first
//! weight), self loops are dropped (neither PageRank-pull nor
//! Bellman-Ford benefits from them and the GAP reference builder also
//! removes them), and optional symmetrization inserts the reverse of
//! every edge.
//!
//! Validation is `Result`-based ([`GraphBuilder::try_build`]) with the
//! same indexed error style as `graph/io.rs`, so a corrupt in-memory
//! edge list surfaces as an error a serving process can handle —
//! [`GraphBuilder::build`] is the panicking convenience wrapper for
//! trusted (generated/test) inputs.

use anyhow::{bail, Result};

use super::csr::{Csr, VertexId};

/// Builder accumulating `(src, dst, weight)` triples.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    triples: Vec<(VertexId, VertexId, u32)>,
    weighted: bool,
    symmetrize: bool,
    keep_self_loops: bool,
    dedup_min_weight: bool,
    reject_self_loops: bool,
}

impl GraphBuilder {
    /// Builder for a graph over vertices `0..n`. Oversized `n` is
    /// reported by [`Self::try_build`] (or panics in [`Self::build`]),
    /// so staging edges can never abort a long-lived process.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            triples: Vec::new(),
            weighted: false,
            symmetrize: false,
            keep_self_loops: false,
            dedup_min_weight: false,
            reject_self_loops: false,
        }
    }

    /// Add unweighted directed edges.
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        self.triples.extend(es.iter().map(|&(s, d)| (s, d, 1)));
        self
    }

    /// Add weighted directed edges; marks the graph weighted.
    pub fn weighted_edges(mut self, es: &[(VertexId, VertexId, u32)]) -> Self {
        self.weighted = true;
        self.triples.extend_from_slice(es);
        self
    }

    /// Push a single edge.
    pub fn push(&mut self, s: VertexId, d: VertexId, w: u32) {
        self.triples.push((s, d, w));
    }

    /// Mark the builder weighted even if edges were added via [`Self::edges`].
    pub fn with_weights(mut self) -> Self {
        self.weighted = true;
        self
    }

    /// Insert the reverse of every edge (undirected semantics). The GAP
    /// road/urand/kron graphs are symmetric; twitter/web are not.
    pub fn symmetrize(mut self) -> Self {
        self.symmetrize = true;
        self
    }

    /// Keep self loops instead of dropping them (off by default).
    pub fn keep_self_loops(mut self) -> Self {
        self.keep_self_loops = true;
        self
    }

    /// Resolve parallel edges by keeping the **minimum** weight instead
    /// of the first staged one. The right policy for shortest-path
    /// inputs, where a duplicate edge means "there are several roads;
    /// take the cheapest".
    pub fn dedup_parallel_edges(mut self) -> Self {
        self.dedup_min_weight = true;
        self
    }

    /// Turn self loops into indexed [`Self::try_build`] errors instead
    /// of silently dropping them — the same policy
    /// [`VersionedGraph::apply_batch`](super::VersionedGraph::apply_batch)
    /// applies to mutation batches, for pipelines that treat a self
    /// loop as corrupt input rather than noise.
    pub fn reject_self_loops(mut self) -> Self {
        self.reject_self_loops = true;
        self
    }

    /// Current number of staged triples (before dedup).
    pub fn staged_edges(&self) -> usize {
        self.triples.len()
    }

    /// Finalize into CSR (pull orientation), panicking on invalid input
    /// — the convenience wrapper over [`Self::try_build`] for trusted
    /// (generated/test) edge lists.
    pub fn build(self) -> Csr {
        match self.try_build() {
            Ok(g) => g,
            Err(e) => panic!("{e}"),
        }
    }

    /// Finalize into CSR (pull orientation). Invalid input — an edge
    /// endpoint outside `0..n`, or an `n` beyond the u32 id space — is a
    /// clean `Err` in the `graph/io.rs` style (`edge <index>: …`), so
    /// corrupt in-memory edge lists can't abort a serving process.
    pub fn try_build(self) -> Result<Csr> {
        let Self { n, mut triples, weighted, symmetrize, keep_self_loops, dedup_min_weight, reject_self_loops } =
            self;

        if n > u32::MAX as usize {
            bail!("vertex count {n} exceeds the u32 id space");
        }
        for (i, &(s, d, _)) in triples.iter().enumerate() {
            if (s as usize) >= n || (d as usize) >= n {
                bail!("edge {i}: ({s},{d}) out of range for n={n}");
            }
            if reject_self_loops && s == d {
                bail!("edge {i}: self loop ({s},{d}) rejected");
            }
        }
        if !keep_self_loops {
            triples.retain(|&(s, d, _)| s != d);
        }
        if symmetrize {
            let rev: Vec<_> = triples.iter().map(|&(s, d, w)| (d, s, w)).collect();
            triples.extend(rev);
        }

        // Sort by (dst, src) so each pull row comes out sorted, then dedup
        // on the (src, dst) pair keeping the first weight — or, with
        // [`Self::dedup_parallel_edges`], sort weight-last so the dedup
        // keeps the minimum weight of each parallel-edge bundle.
        if dedup_min_weight {
            triples.sort_unstable_by_key(|&(s, d, w)| (d, s, w));
        } else {
            triples.sort_unstable_by_key(|&(s, d, _)| (d, s));
        }
        triples.dedup_by_key(|&mut (s, d, _)| (s, d));

        // Edge *counts* are u64 (offsets), but per-vertex degrees and the
        // compressed store's per-row element counts are u32 — a graph
        // with more than u32::MAX edges would silently truncate them.
        if triples.len() > u32::MAX as usize {
            bail!("edge count {} exceeds the u32 edge index space", triples.len());
        }

        let mut offsets = vec![0u64; n + 1];
        for &(_, d, _) in &triples {
            offsets[d as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }

        let sources: Vec<VertexId> = triples.iter().map(|&(s, _, _)| s).collect();
        let weights = if weighted { Some(triples.iter().map(|&(_, _, w)| w).collect()) } else { None };

        let mut out_degrees = vec![0u32; n];
        for &(s, _, _) in &triples {
            out_degrees[s as usize] += 1;
        }

        Ok(Csr::from_parts(offsets, sources, weights, out_degrees, symmetrize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_self_loop_removal() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 1), (1, 1), (2, 1)]).build();
        assert_eq!(g.num_edges(), 2); // (0,1) deduped, (1,1) dropped
        assert_eq!(g.in_neighbors(1), &[0, 2]);
    }

    #[test]
    fn keep_self_loops_option() {
        let g = GraphBuilder::new(2).edges(&[(1, 1)]).keep_self_loops().build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.in_neighbors(1), &[1]);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).symmetrize().build();
        assert_eq!(g.num_edges(), 4);
        assert!(g.is_symmetric());
        assert_eq!(g.in_neighbors(1), &[0, 2]);
        assert_eq!(g.out_degree(1), 2);
    }

    #[test]
    fn symmetrize_dedups_bidirectional_input() {
        // (0,1) and (1,0) both present: symmetrizing must not double-count.
        let g = GraphBuilder::new(2).edges(&[(0, 1), (1, 0)]).symmetrize().build();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn rows_sorted() {
        let g = GraphBuilder::new(5).edges(&[(4, 0), (1, 0), (3, 0), (2, 0)]).build();
        assert_eq!(g.in_neighbors(0), &[1, 2, 3, 4]);
    }

    #[test]
    fn dedup_keeps_first_weight() {
        let g = GraphBuilder::new(2).weighted_edges(&[(0, 1, 5), (0, 1, 9)]).build();
        let nb: Vec<_> = g.in_neighbors_weighted(1).collect();
        assert_eq!(nb, vec![(0, 5)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        GraphBuilder::new(2).edges(&[(0, 5)]).build();
    }

    #[test]
    fn try_build_reports_indexed_errors() {
        // The edge index and endpoints are named, io.rs-style, so a
        // serving process can log which staged edge was corrupt.
        let err = GraphBuilder::new(3).edges(&[(0, 1), (7, 2), (2, 0)]).try_build().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("edge 1") && msg.contains("(7,2)") && msg.contains("n=3"), "{msg}");
        // Valid input still builds through the fallible path.
        let g = GraphBuilder::new(3).edges(&[(0, 1), (2, 1)]).try_build().unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn try_build_rejects_oversized_n() {
        let err = GraphBuilder::new(u32::MAX as usize + 1).try_build().unwrap_err();
        assert!(err.to_string().contains("u32 id space"), "{err}");
    }

    #[test]
    fn dedup_parallel_edges_keeps_min_weight() {
        let g = GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, 9), (0, 1, 3), (0, 1, 5)])
            .dedup_parallel_edges()
            .build();
        let nb: Vec<_> = g.in_neighbors_weighted(1).collect();
        assert_eq!(nb, vec![(0, 3)]);
        // Default policy is unchanged: first staged weight wins.
        let g = GraphBuilder::new(2).weighted_edges(&[(0, 1, 9), (0, 1, 3)]).build();
        let nb: Vec<_> = g.in_neighbors_weighted(1).collect();
        assert_eq!(nb, vec![(0, 9)]);
    }

    #[test]
    fn reject_self_loops_reports_indexed_error() {
        // Same policy and error shape as VersionedGraph::apply_batch:
        // the offending index and endpoints are named.
        let err = GraphBuilder::new(3)
            .edges(&[(0, 1), (2, 2), (1, 0)])
            .reject_self_loops()
            .try_build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("edge 1") && msg.contains("self loop") && msg.contains("(2,2)"), "{msg}");
        // Without the flag the loop is silently dropped as before.
        let g = GraphBuilder::new(3).edges(&[(0, 1), (2, 2), (1, 0)]).build();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn out_degrees_after_dedup() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 1), (0, 2)]).build();
        assert_eq!(g.out_degree(0), 2);
    }
}
