//! Edge-list → [`Csr`] construction.
//!
//! Handles the messiness real edge lists have: duplicate edges, self
//! loops, unsorted input. Duplicates are removed (keeping the first
//! weight), self loops are dropped (neither PageRank-pull nor
//! Bellman-Ford benefits from them and the GAP reference builder also
//! removes them), and optional symmetrization inserts the reverse of
//! every edge.

use super::csr::{Csr, VertexId};

/// Builder accumulating `(src, dst, weight)` triples.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    triples: Vec<(VertexId, VertexId, u32)>,
    weighted: bool,
    symmetrize: bool,
    keep_self_loops: bool,
}

impl GraphBuilder {
    /// Builder for a graph over vertices `0..n`.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        Self { n, triples: Vec::new(), weighted: false, symmetrize: false, keep_self_loops: false }
    }

    /// Add unweighted directed edges.
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        self.triples.extend(es.iter().map(|&(s, d)| (s, d, 1)));
        self
    }

    /// Add weighted directed edges; marks the graph weighted.
    pub fn weighted_edges(mut self, es: &[(VertexId, VertexId, u32)]) -> Self {
        self.weighted = true;
        self.triples.extend_from_slice(es);
        self
    }

    /// Push a single edge.
    pub fn push(&mut self, s: VertexId, d: VertexId, w: u32) {
        self.triples.push((s, d, w));
    }

    /// Mark the builder weighted even if edges were added via [`Self::edges`].
    pub fn with_weights(mut self) -> Self {
        self.weighted = true;
        self
    }

    /// Insert the reverse of every edge (undirected semantics). The GAP
    /// road/urand/kron graphs are symmetric; twitter/web are not.
    pub fn symmetrize(mut self) -> Self {
        self.symmetrize = true;
        self
    }

    /// Keep self loops instead of dropping them (off by default).
    pub fn keep_self_loops(mut self) -> Self {
        self.keep_self_loops = true;
        self
    }

    /// Current number of staged triples (before dedup).
    pub fn staged_edges(&self) -> usize {
        self.triples.len()
    }

    /// Finalize into CSR (pull orientation).
    pub fn build(self) -> Csr {
        let Self { n, mut triples, weighted, symmetrize, keep_self_loops } = self;

        for &(s, d, _) in &triples {
            assert!((s as usize) < n && (d as usize) < n, "edge ({s},{d}) out of range for n={n}");
        }
        if !keep_self_loops {
            triples.retain(|&(s, d, _)| s != d);
        }
        if symmetrize {
            let rev: Vec<_> = triples.iter().map(|&(s, d, w)| (d, s, w)).collect();
            triples.extend(rev);
        }

        // Sort by (dst, src) so each pull row comes out sorted, then dedup
        // on the (src, dst) pair keeping the first weight.
        triples.sort_unstable_by_key(|&(s, d, _)| (d, s));
        triples.dedup_by_key(|&mut (s, d, _)| (s, d));

        let mut offsets = vec![0u64; n + 1];
        for &(_, d, _) in &triples {
            offsets[d as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }

        let sources: Vec<VertexId> = triples.iter().map(|&(s, _, _)| s).collect();
        let weights = if weighted { Some(triples.iter().map(|&(_, _, w)| w).collect()) } else { None };

        let mut out_degrees = vec![0u32; n];
        for &(s, _, _) in &triples {
            out_degrees[s as usize] += 1;
        }

        Csr::from_parts(offsets, sources, weights, out_degrees, symmetrize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_self_loop_removal() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 1), (1, 1), (2, 1)]).build();
        assert_eq!(g.num_edges(), 2); // (0,1) deduped, (1,1) dropped
        assert_eq!(g.in_neighbors(1), &[0, 2]);
    }

    #[test]
    fn keep_self_loops_option() {
        let g = GraphBuilder::new(2).edges(&[(1, 1)]).keep_self_loops().build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.in_neighbors(1), &[1]);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).symmetrize().build();
        assert_eq!(g.num_edges(), 4);
        assert!(g.is_symmetric());
        assert_eq!(g.in_neighbors(1), &[0, 2]);
        assert_eq!(g.out_degree(1), 2);
    }

    #[test]
    fn symmetrize_dedups_bidirectional_input() {
        // (0,1) and (1,0) both present: symmetrizing must not double-count.
        let g = GraphBuilder::new(2).edges(&[(0, 1), (1, 0)]).symmetrize().build();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn rows_sorted() {
        let g = GraphBuilder::new(5).edges(&[(4, 0), (1, 0), (3, 0), (2, 0)]).build();
        assert_eq!(g.in_neighbors(0), &[1, 2, 3, 4]);
    }

    #[test]
    fn dedup_keeps_first_weight() {
        let g = GraphBuilder::new(2).weighted_edges(&[(0, 1, 5), (0, 1, 9)]).build();
        let nb: Vec<_> = g.in_neighbors_weighted(1).collect();
        assert_eq!(nb, vec![(0, 5)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        GraphBuilder::new(2).edges(&[(0, 5)]).build();
    }

    #[test]
    fn out_degrees_after_dedup() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 1), (0, 2)]).build();
        assert_eq!(g.out_degree(0), 2);
    }
}
