//! Mutable edge overlays over a frozen CSR base.
//!
//! [`VersionedGraph`] is the second [`GraphStore`] backend: a
//! [`Csr`] base plus per-vertex insert and delete delta lists, with a
//! monotonically increasing version bumped by every applied batch. The
//! read path composes a row on the fly — surviving base entries
//! (tombstone-filtered) chained with the inserts — so a mutation batch
//! is O(batch) instead of an O(n + m) rebuild, and once the accumulated
//! churn passes a configurable fraction of the base edge count the
//! overlay is compacted back into a fresh CSR.
//!
//! Overlay layout (all per-vertex lists kept sorted for binary search):
//!
//! * `ins_in[d]`: inserted in-edges of `d` as `(src, weight)`, sorted
//!   by `src`. Mirrored by `ins_out[s]` (dst ids) for the push side.
//! * `del_in[d]`: tombstoned *base* in-edges of `d` (src ids).
//!   Mirrored by `del_out[s]`.
//!
//! Re-inserting a tombstoned base edge keeps the tombstone and records
//! the edge in the insert list — the tombstone shadows the stale base
//! weight, the insert carries the fresh one. An edge is present iff
//! `(in base && not tombstoned) || in inserts`.
//!
//! Batches are atomic: [`VersionedGraph::apply_batch`] validates every
//! mutation (against the state the preceding mutations of the same
//! batch would produce) before touching the overlay, so an `Err` leaves
//! the graph byte-identical.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::builder::GraphBuilder;
use super::csr::{Csr, VertexId};
use super::store::GraphStore;
use crate::util::rng::SplitMix64;

/// A single edge mutation. Batched into
/// [`VersionedGraph::apply_batch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeMutation {
    /// Add edge `src -> dst` with `weight` (must be `>= 1`; exactly `1`
    /// on unweighted graphs). Rejected if the edge already exists.
    Insert {
        /// Source vertex.
        src: VertexId,
        /// Destination vertex.
        dst: VertexId,
        /// Edge weight (`>= 1`).
        weight: u32,
    },
    /// Remove edge `src -> dst`. Rejected if the edge does not exist.
    Delete {
        /// Source vertex.
        src: VertexId,
        /// Destination vertex.
        dst: VertexId,
    },
}

/// Monotonically increasing content version of a [`VersionedGraph`]
/// (0 = pristine base; +1 per applied batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GraphVersion(pub u64);

/// What [`VersionedGraph::apply_batch`] did: the version it produced,
/// the edges it actually added/removed (with weights — deletes report
/// the weight the dying edge had), and whether the batch tripped a
/// compaction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MutationReceipt {
    /// Version after the batch.
    pub version: GraphVersion,
    /// Edges added, as `(src, dst, weight)`.
    pub inserted: Vec<(VertexId, VertexId, u32)>,
    /// Edges removed, as `(src, dst, weight)` with the weight they had.
    pub deleted: Vec<(VertexId, VertexId, u32)>,
    /// Whether the overlay was compacted back into a fresh CSR.
    pub compacted: bool,
}

impl MutationReceipt {
    /// Every vertex whose in-edge set changed (the dst of each
    /// mutation), sorted and deduplicated — the natural dirty seed for
    /// incremental recomputation.
    pub fn touched_dsts(&self) -> Vec<VertexId> {
        let mut out: Vec<VertexId> =
            self.inserted.iter().chain(self.deleted.iter()).map(|&(_, d, _)| d).collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Default compaction threshold: compact once accumulated churn
/// exceeds this fraction of the base edge count.
pub const DEFAULT_COMPACT_FRAC: f64 = 0.25;

/// [`Csr`] base + per-vertex insert/delete overlays + version counter.
///
/// Implements [`GraphStore`], so both executors and every algorithm run
/// on it unchanged. The base is never mutated in place; compaction
/// replaces it wholesale.
#[derive(Debug, Clone)]
pub struct VersionedGraph {
    base: Csr,
    /// Inserted in-edges per dst, sorted by src.
    ins_in: Vec<Vec<(VertexId, u32)>>,
    /// Tombstoned base in-edges per dst (src ids), sorted.
    del_in: Vec<Vec<VertexId>>,
    /// Inserted out-edges per src (dst ids), sorted.
    ins_out: Vec<Vec<VertexId>>,
    /// Tombstoned base out-edges per src (dst ids), sorted.
    del_out: Vec<Vec<VertexId>>,
    /// Materialized out-degrees, maintained incrementally.
    out_degrees: Vec<u32>,
    /// Current logical edge count.
    num_edges: usize,
    /// Content version; bumped once per applied batch.
    version: u64,
    /// Compact when `delta_edges > compact_frac * base.num_edges()`.
    compact_frac: f64,
    /// Accumulated churn (applied mutations) since the last compaction.
    delta_edges: usize,
}

impl VersionedGraph {
    /// Wrap a frozen CSR as version 0 with the
    /// [default](DEFAULT_COMPACT_FRAC) compaction threshold.
    pub fn new(base: Csr) -> Self {
        let n = base.num_vertices();
        let out_degrees = base.out_degrees().to_vec();
        let num_edges = base.num_edges();
        Self {
            base,
            ins_in: vec![Vec::new(); n],
            del_in: vec![Vec::new(); n],
            ins_out: vec![Vec::new(); n],
            del_out: vec![Vec::new(); n],
            out_degrees,
            num_edges,
            version: 0,
            compact_frac: DEFAULT_COMPACT_FRAC,
            delta_edges: 0,
        }
    }

    /// Override the compaction threshold (fraction of base edges the
    /// accumulated churn may reach before compaction; `f64::INFINITY`
    /// disables compaction).
    pub fn with_compaction_threshold(mut self, frac: f64) -> Self {
        assert!(frac >= 0.0, "compaction threshold must be non-negative");
        self.compact_frac = frac;
        self
    }

    /// Current content version.
    pub fn version(&self) -> GraphVersion {
        GraphVersion(self.version)
    }

    /// The current CSR base (post-compaction this is the rebuilt CSR).
    pub fn base(&self) -> &Csr {
        &self.base
    }

    /// Accumulated churn since the last compaction (mutations applied).
    pub fn delta_edges(&self) -> usize {
        self.delta_edges
    }

    /// Whether any overlay entries exist (false right after a
    /// compaction or on a pristine base).
    pub fn has_deltas(&self) -> bool {
        self.ins_in.iter().any(|v| !v.is_empty()) || self.del_in.iter().any(|v| !v.is_empty())
    }

    /// Whether edge `src -> dst` currently exists.
    pub fn edge_present(&self, src: VertexId, dst: VertexId) -> bool {
        if self.ins_in[dst as usize].binary_search_by_key(&src, |&(s, _)| s).is_ok() {
            return true;
        }
        self.base_has(src, dst) && self.del_in[dst as usize].binary_search(&src).is_err()
    }

    fn base_has(&self, src: VertexId, dst: VertexId) -> bool {
        self.base.in_neighbors(dst).binary_search(&src).is_ok()
    }

    /// Weight of base edge `src -> dst` (1 on unweighted graphs).
    /// Caller guarantees the base edge exists.
    fn base_weight(&self, src: VertexId, dst: VertexId) -> u32 {
        let row = self.base.in_neighbors(dst);
        let idx = row.binary_search(&src).expect("base edge must exist");
        match self.base.weights() {
            Some(ws) => ws[self.base.offsets()[dst as usize] as usize + idx],
            None => 1,
        }
    }

    /// Weight of the current edge `src -> dst` (insert entry wins over
    /// base). Caller guarantees the edge is present.
    fn current_weight(&self, src: VertexId, dst: VertexId) -> u32 {
        match self.ins_in[dst as usize].binary_search_by_key(&src, |&(s, _)| s) {
            Ok(i) => self.ins_in[dst as usize][i].1,
            Err(_) => self.base_weight(src, dst),
        }
    }

    /// Apply a batch of mutations atomically: every mutation is
    /// validated (in batch order, against the state its predecessors
    /// would produce) before any is applied, so an `Err` leaves the
    /// graph unchanged. Errors are indexed `mutation <i>: …`, matching
    /// the `graph/io.rs` / [`GraphBuilder::try_build`] style.
    ///
    /// Rejected per mutation: endpoints out of range, self loops,
    /// inserting a present edge (parallel-edge duplicate), deleting an
    /// absent edge, zero weights, and non-unit weights on unweighted
    /// graphs. On success the version is bumped once and, if the
    /// accumulated churn exceeds the compaction threshold, the overlay
    /// is folded back into a fresh CSR base.
    pub fn apply_batch(&mut self, batch: &[EdgeMutation]) -> Result<MutationReceipt> {
        let n = self.base.num_vertices();
        // Pass 1: validate against current state + batch-local pending
        // presence, touching nothing.
        let mut pending: HashMap<(VertexId, VertexId), bool> = HashMap::new();
        for (i, m) in batch.iter().enumerate() {
            let (src, dst) = match *m {
                EdgeMutation::Insert { src, dst, .. } | EdgeMutation::Delete { src, dst } => (src, dst),
            };
            if (src as usize) >= n || (dst as usize) >= n {
                bail!("mutation {i}: ({src},{dst}) out of range for n={n}");
            }
            if src == dst {
                bail!("mutation {i}: self loop ({src},{dst}) rejected");
            }
            let present =
                pending.get(&(src, dst)).copied().unwrap_or_else(|| self.edge_present(src, dst));
            match *m {
                EdgeMutation::Insert { weight, .. } => {
                    if weight == 0 {
                        bail!("mutation {i}: zero weight on ({src},{dst}); weights must be >= 1");
                    }
                    if !self.base.is_weighted() && weight != 1 {
                        bail!("mutation {i}: weight {weight} on ({src},{dst}) of an unweighted graph");
                    }
                    if present {
                        bail!("mutation {i}: duplicate edge ({src},{dst}) already present");
                    }
                    pending.insert((src, dst), true);
                }
                EdgeMutation::Delete { .. } => {
                    if !present {
                        bail!("mutation {i}: delete of absent edge ({src},{dst})");
                    }
                    pending.insert((src, dst), false);
                }
            }
        }

        // Pass 2: apply (infallible now).
        let mut inserted = Vec::new();
        let mut deleted = Vec::new();
        for m in batch {
            match *m {
                EdgeMutation::Insert { src, dst, weight } => {
                    self.insert_unchecked(src, dst, weight);
                    inserted.push((src, dst, weight));
                }
                EdgeMutation::Delete { src, dst } => {
                    let w = self.current_weight(src, dst);
                    self.delete_unchecked(src, dst);
                    deleted.push((src, dst, w));
                }
            }
        }
        self.version += 1;
        self.delta_edges += batch.len();

        let compacted = self.delta_edges as f64 > self.compact_frac * self.base.num_edges() as f64;
        if compacted {
            self.compact();
        }
        Ok(MutationReceipt { version: GraphVersion(self.version), inserted, deleted, compacted })
    }

    fn insert_unchecked(&mut self, src: VertexId, dst: VertexId, weight: u32) {
        let ins = &mut self.ins_in[dst as usize];
        let pos = ins.binary_search_by_key(&src, |&(s, _)| s).unwrap_err();
        ins.insert(pos, (src, weight));
        let out = &mut self.ins_out[src as usize];
        let pos = out.binary_search(&dst).unwrap_err();
        out.insert(pos, dst);
        self.out_degrees[src as usize] += 1;
        self.num_edges += 1;
    }

    fn delete_unchecked(&mut self, src: VertexId, dst: VertexId) {
        let ins = &mut self.ins_in[dst as usize];
        if let Ok(i) = ins.binary_search_by_key(&src, |&(s, _)| s) {
            // Deleting an overlay insert: drop the insert entry (any
            // base tombstone for the pair stays, keeping the base edge
            // shadowed).
            ins.remove(i);
            let out = &mut self.ins_out[src as usize];
            let j = out.binary_search(&dst).expect("in/out insert lists out of sync");
            out.remove(j);
        } else {
            // Deleting a live base edge: tombstone it on both sides.
            let del = &mut self.del_in[dst as usize];
            let pos = del.binary_search(&src).unwrap_err();
            del.insert(pos, src);
            let out = &mut self.del_out[src as usize];
            let pos = out.binary_search(&dst).unwrap_err();
            out.insert(pos, dst);
        }
        self.out_degrees[src as usize] -= 1;
        self.num_edges -= 1;
    }

    /// Fold the overlay back into a fresh CSR base. The logical graph
    /// (and its version) is unchanged; the overlay lists come out
    /// empty. Called automatically by [`Self::apply_batch`] past the
    /// compaction threshold; public so callers can force it (e.g.
    /// before a long read-only serving phase).
    pub fn compact(&mut self) {
        let n = self.base.num_vertices();
        let mut b = GraphBuilder::new(n);
        if self.base.is_weighted() {
            b = b.with_weights();
        }
        for v in 0..n as VertexId {
            let del = &self.del_in[v as usize];
            let row = self.base.in_neighbors(v);
            for (i, &u) in row.iter().enumerate() {
                if del.binary_search(&u).is_ok() {
                    continue;
                }
                let w = match self.base.weights() {
                    Some(ws) => ws[self.base.offsets()[v as usize] as usize + i],
                    None => 1,
                };
                b.push(u, v, w);
            }
            for &(u, w) in &self.ins_in[v as usize] {
                b.push(u, v, w);
            }
        }
        let fresh = b.try_build().expect("compaction rebuilt an invalid edge list");
        debug_assert_eq!(fresh.num_edges(), self.num_edges);
        debug_assert_eq!(fresh.out_degrees(), &self.out_degrees[..]);
        self.base = fresh;
        for v in 0..n {
            self.ins_in[v].clear();
            self.del_in[v].clear();
            self.ins_out[v].clear();
            self.del_out[v].clear();
        }
        self.delta_edges = 0;
    }

    /// Materialize the current logical graph as a standalone [`Csr`]
    /// (for oracle comparisons; the overlay is untouched).
    pub fn to_csr(&self) -> Csr {
        let mut snap = self.clone();
        snap.compact();
        snap.base
    }

    /// Generate a seeded random mutation batch touching about
    /// `frac * num_edges` edges: half deletes of existing edges, half
    /// inserts of currently absent (non-self-loop) pairs. Weighted
    /// graphs get insert weights in `1..=64`. Deterministic in `seed`.
    pub fn random_batch(&self, frac: f64, seed: u64) -> Vec<EdgeMutation> {
        let n = self.num_vertices();
        let m = self.num_edges;
        let k = ((m as f64 * frac).round() as usize).max(1);
        let n_del = k / 2;
        let n_ins = k - n_del;
        let mut rng = SplitMix64::new(seed);
        let mut out = Vec::with_capacity(k);

        // Deletes: sample distinct positions in the current edge list.
        let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(m);
        for v in 0..n as VertexId {
            for u in GraphStore::in_neighbors(self, v) {
                edges.push((u, v));
            }
        }
        rng.shuffle(&mut edges);
        let mut chosen: std::collections::HashSet<(VertexId, VertexId)> = Default::default();
        for &(s, d) in edges.iter().take(n_del.min(edges.len())) {
            chosen.insert((s, d));
            out.push(EdgeMutation::Delete { src: s, dst: d });
        }

        // Inserts: rejection-sample absent pairs (bounded attempts so a
        // near-complete graph cannot spin forever).
        let mut attempts = 0usize;
        let max_attempts = 64 * k + 64;
        let mut added = 0usize;
        while added < n_ins && attempts < max_attempts {
            attempts += 1;
            let s = rng.index(n) as VertexId;
            let d = rng.index(n) as VertexId;
            if s == d || chosen.contains(&(s, d)) || self.edge_present(s, d) {
                continue;
            }
            chosen.insert((s, d));
            let weight = if self.is_weighted() { rng.range_u32(1, 64) } else { 1 };
            out.push(EdgeMutation::Insert { src: s, dst: d, weight });
            added += 1;
        }
        out
    }

    fn iter_in(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        let del = &self.del_in[v as usize];
        self.base
            .in_neighbors(v)
            .iter()
            .copied()
            .filter(move |u| del.binary_search(u).is_err())
            .chain(self.ins_in[v as usize].iter().map(|&(u, _)| u))
    }
}

impl GraphStore for VersionedGraph {
    fn num_vertices(&self) -> usize {
        self.base.num_vertices()
    }

    fn num_edges(&self) -> usize {
        self.num_edges
    }

    fn is_weighted(&self) -> bool {
        self.base.is_weighted()
    }

    fn is_symmetric(&self) -> bool {
        // Conservative: mutations are directed, so symmetry only
        // survives while the overlay is empty.
        self.base.is_symmetric() && !self.has_deltas()
    }

    fn in_degree(&self, v: VertexId) -> usize {
        self.base.in_degree(v) - self.del_in[v as usize].len() + self.ins_in[v as usize].len()
    }

    fn out_degree(&self, v: VertexId) -> u32 {
        self.out_degrees[v as usize]
    }

    fn out_degrees(&self) -> &[u32] {
        &self.out_degrees
    }

    fn in_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.iter_in(v)
    }

    fn in_neighbors_weighted(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u32)> + '_ {
        let del = &self.del_in[v as usize];
        self.base
            .in_neighbors_weighted(v)
            .filter(move |(u, _)| del.binary_search(u).is_err())
            .chain(self.ins_in[v as usize].iter().copied())
    }

    fn out_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        let del = &self.del_out[v as usize];
        self.base
            .out_neighbors(v)
            .iter()
            .copied()
            .filter(move |d| del.binary_search(d).is_err())
            .chain(self.ins_out[v as usize].iter().copied())
    }

    fn in_neighbor_hint(&self, v: VertexId) -> &[VertexId] {
        // Prefetch hint only: the base row may include tombstoned ids
        // and misses overlay inserts — harmless for a pure hint.
        self.base.in_neighbors(v)
    }

    fn ensure_out_edges(&self) {
        self.base.ensure_out_edges();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn diamond() -> Csr {
        // 0 -> {1,2} -> 3, plus 0 -> 3 long edge.
        GraphBuilder::new(4)
            .weighted_edges(&[(0, 1, 2), (0, 2, 4), (1, 3, 2), (2, 3, 1), (0, 3, 9)])
            .build()
    }

    fn in_row(g: &VersionedGraph, v: VertexId) -> Vec<(VertexId, u32)> {
        let mut row: Vec<_> = g.in_neighbors_weighted(v).collect();
        row.sort_unstable();
        row
    }

    #[test]
    fn pristine_overlay_matches_base() {
        let base = diamond();
        let g = VersionedGraph::new(base.clone());
        assert_eq!(g.version(), GraphVersion(0));
        assert_eq!(GraphStore::num_edges(&g), base.num_edges());
        for v in 0..4u32 {
            let trait_row: Vec<VertexId> = GraphStore::in_neighbors(&g, v).collect();
            assert_eq!(trait_row, base.in_neighbors(v), "v{v}");
            let out_row: Vec<VertexId> = GraphStore::out_neighbors(&g, v).collect();
            assert_eq!(out_row, base.out_neighbors(v), "v{v}");
            assert_eq!(GraphStore::out_degree(&g, v), base.out_degree(v));
            assert_eq!(GraphStore::in_degree(&g, v), base.in_degree(v));
        }
        assert!(!g.has_deltas());
        assert!(g.to_csr() == base);
    }

    #[test]
    fn insert_and_delete_roundtrip() {
        let mut g = VersionedGraph::new(diamond());
        let r = g
            .apply_batch(&[
                EdgeMutation::Insert { src: 3, dst: 0, weight: 5 },
                EdgeMutation::Delete { src: 0, dst: 3 },
            ])
            .unwrap();
        assert_eq!(r.version, GraphVersion(1));
        assert_eq!(r.inserted, vec![(3, 0, 5)]);
        assert_eq!(r.deleted, vec![(0, 3, 9)]);
        assert_eq!(r.touched_dsts(), vec![0, 3]);
        assert_eq!(GraphStore::num_edges(&g), 5);
        assert_eq!(in_row(&g, 0), vec![(3, 5)]);
        assert_eq!(in_row(&g, 3), vec![(1, 2), (2, 1)]);
        assert_eq!(GraphStore::out_degree(&g, 0), 2);
        assert_eq!(GraphStore::out_degree(&g, 3), 1);
        let outs: Vec<VertexId> = GraphStore::out_neighbors(&g, 0).collect();
        assert_eq!(outs, vec![1, 2]);
        assert!(g.edge_present(3, 0) && !g.edge_present(0, 3));
    }

    #[test]
    fn reinsert_after_delete_takes_new_weight() {
        let mut g = VersionedGraph::new(diamond());
        g.apply_batch(&[EdgeMutation::Delete { src: 0, dst: 3 }]).unwrap();
        g.apply_batch(&[EdgeMutation::Insert { src: 0, dst: 3, weight: 1 }]).unwrap();
        assert_eq!(g.version(), GraphVersion(2));
        assert_eq!(in_row(&g, 3), vec![(0, 1), (1, 2), (2, 1)]);
        // Deleting the re-inserted edge removes it again (tombstone
        // still shadows the base entry).
        g.apply_batch(&[EdgeMutation::Delete { src: 0, dst: 3 }]).unwrap();
        assert!(!g.edge_present(0, 3));
        assert_eq!(in_row(&g, 3), vec![(1, 2), (2, 1)]);
    }

    #[test]
    fn batch_is_atomic_on_error() {
        let mut g = VersionedGraph::new(diamond());
        let before = g.to_csr();
        let err = g
            .apply_batch(&[
                EdgeMutation::Insert { src: 3, dst: 0, weight: 5 },
                EdgeMutation::Delete { src: 1, dst: 2 }, // absent
            ])
            .unwrap_err();
        assert!(err.to_string().contains("mutation 1") && err.to_string().contains("absent"), "{err}");
        assert_eq!(g.version(), GraphVersion(0));
        assert!(g.to_csr() == before);
    }

    #[test]
    fn validation_errors_are_indexed() {
        let mut g = VersionedGraph::new(diamond());
        let cases: Vec<(Vec<EdgeMutation>, &str)> = vec![
            (vec![EdgeMutation::Insert { src: 9, dst: 0, weight: 1 }], "mutation 0: (9,0) out of range"),
            (vec![EdgeMutation::Insert { src: 2, dst: 2, weight: 1 }], "self loop"),
            (vec![EdgeMutation::Insert { src: 0, dst: 1, weight: 3 }], "duplicate edge (0,1)"),
            (vec![EdgeMutation::Insert { src: 3, dst: 0, weight: 0 }], "zero weight"),
            (vec![EdgeMutation::Delete { src: 1, dst: 0 }], "absent edge (1,0)"),
            (
                vec![
                    EdgeMutation::Insert { src: 3, dst: 0, weight: 1 },
                    EdgeMutation::Insert { src: 3, dst: 0, weight: 2 },
                ],
                "mutation 1: duplicate edge (3,0)",
            ),
        ];
        for (batch, needle) in cases {
            let err = g.apply_batch(&batch).unwrap_err().to_string();
            assert!(err.contains(needle), "expected {needle:?} in {err:?}");
        }
        // Intra-batch delete-then-insert of the same pair is legal.
        g.apply_batch(&[
            EdgeMutation::Delete { src: 0, dst: 3 },
            EdgeMutation::Insert { src: 0, dst: 3, weight: 7 },
        ])
        .unwrap();
        assert_eq!(in_row(&g, 3), vec![(0, 7), (1, 2), (2, 1)]);
    }

    #[test]
    fn unweighted_base_rejects_nonunit_weight() {
        let base = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let mut g = VersionedGraph::new(base);
        let err = g
            .apply_batch(&[EdgeMutation::Insert { src: 2, dst: 0, weight: 3 }])
            .unwrap_err();
        assert!(err.to_string().contains("unweighted"), "{err}");
        g.apply_batch(&[EdgeMutation::Insert { src: 2, dst: 0, weight: 1 }]).unwrap();
        let row: Vec<VertexId> = GraphStore::in_neighbors(&g, 0).collect();
        assert_eq!(row, vec![2]);
    }

    #[test]
    fn compaction_preserves_logical_graph() {
        let mut g = VersionedGraph::new(diamond()).with_compaction_threshold(f64::INFINITY);
        g.apply_batch(&[
            EdgeMutation::Delete { src: 0, dst: 3 },
            EdgeMutation::Insert { src: 3, dst: 0, weight: 5 },
            EdgeMutation::Insert { src: 1, dst: 2, weight: 8 },
        ])
        .unwrap();
        let logical = g.to_csr();
        assert!(g.has_deltas());
        g.compact();
        assert!(!g.has_deltas());
        assert_eq!(g.delta_edges(), 0);
        assert!(g.base() == &logical);
        assert_eq!(g.version(), GraphVersion(1)); // compaction ≠ new content
        // Rows read identically post-compaction.
        assert_eq!(in_row(&g, 0), vec![(3, 5)]);
        assert_eq!(in_row(&g, 2), vec![(0, 4), (1, 8)]);
    }

    #[test]
    fn auto_compaction_past_threshold() {
        let mut g = VersionedGraph::new(diamond()).with_compaction_threshold(0.25);
        // 5 base edges * 0.25 = 1.25: a 2-mutation batch trips it.
        let r = g
            .apply_batch(&[
                EdgeMutation::Delete { src: 0, dst: 3 },
                EdgeMutation::Insert { src: 3, dst: 1, weight: 2 },
            ])
            .unwrap();
        assert!(r.compacted);
        assert!(!g.has_deltas());
        assert_eq!(g.base().num_edges(), 5);
        assert_eq!(in_row(&g, 1), vec![(0, 2), (3, 2)]);
    }

    #[test]
    fn random_batch_is_valid_and_deterministic() {
        let base = GraphBuilder::new(64)
            .weighted_edges(
                &(0..256u32)
                    .map(|i| ((i * 7 + 1) % 64, (i * 13 + 3) % 64, 1 + i % 9))
                    .filter(|&(s, d, _)| s != d)
                    .collect::<Vec<_>>(),
            )
            .build();
        let g = VersionedGraph::new(base);
        let b1 = g.random_batch(0.05, 42);
        let b2 = g.random_batch(0.05, 42);
        assert_eq!(b1, b2);
        assert!(!b1.is_empty());
        let mut g2 = g.clone();
        let r = g2.apply_batch(&b1).expect("random batch must validate");
        assert_eq!(r.inserted.len() + r.deleted.len(), b1.len());
        // Different seed, different batch.
        assert_ne!(g.random_batch(0.05, 43), b1);
    }

    #[test]
    fn overlay_degrees_stay_consistent() {
        let base = GraphBuilder::new(32)
            .weighted_edges(
                &(0..128u32)
                    .map(|i| ((i * 5 + 2) % 32, (i * 11 + 7) % 32, 1 + i % 5))
                    .filter(|&(s, d, _)| s != d)
                    .collect::<Vec<_>>(),
            )
            .build();
        let mut g = VersionedGraph::new(base).with_compaction_threshold(f64::INFINITY);
        for round in 0..4u64 {
            let batch = g.random_batch(0.1, 100 + round);
            g.apply_batch(&batch).unwrap();
        }
        let flat = g.to_csr();
        assert_eq!(GraphStore::num_edges(&g), flat.num_edges());
        for v in 0..32u32 {
            assert_eq!(GraphStore::in_degree(&g, v), flat.in_degree(v), "in v{v}");
            assert_eq!(GraphStore::out_degree(&g, v), flat.out_degree(v), "out v{v}");
            let mut row: Vec<VertexId> = GraphStore::in_neighbors(&g, v).collect();
            row.sort_unstable();
            assert_eq!(row, flat.in_neighbors(v), "row v{v}");
            let mut outs: Vec<VertexId> = GraphStore::out_neighbors(&g, v).collect();
            outs.sort_unstable();
            assert_eq!(outs, flat.out_neighbors(v), "outs v{v}");
        }
    }
}
