//! The GAP-analog benchmark suite used by every experiment.
//!
//! Binds the five generator families to the names the paper uses and
//! fixes per-graph seeds so "kron at scale 14" means the same graph in
//! every test, example, bench, and experiment run.

use crate::graph::generators::{grid, rmat, twitter, uniform, web};
use crate::graph::{weights, Csr};

/// The five GAP benchmark graphs (analog generators — see DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GapGraph {
    Kron,
    Urand,
    Twitter,
    Web,
    Road,
}

/// All five, in the paper's table order.
pub const ALL: [GapGraph; 5] = [GapGraph::Kron, GapGraph::Road, GapGraph::Twitter, GapGraph::Urand, GapGraph::Web];

impl GapGraph {
    /// Lower-case name as used in the paper's tables and our CLI.
    pub fn name(self) -> &'static str {
        match self {
            GapGraph::Kron => "kron",
            GapGraph::Urand => "urand",
            GapGraph::Twitter => "twitter",
            GapGraph::Web => "web",
            GapGraph::Road => "road",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "kron" => Some(GapGraph::Kron),
            "urand" => Some(GapGraph::Urand),
            "twitter" => Some(GapGraph::Twitter),
            "web" => Some(GapGraph::Web),
            "road" => Some(GapGraph::Road),
            _ => None,
        }
    }

    /// Fixed per-graph generation seed (distinct streams per family).
    fn seed(self) -> u64 {
        match self {
            GapGraph::Kron => 0x6AF1,
            GapGraph::Urand => 0x06A2,
            GapGraph::Twitter => 0x7311,
            GapGraph::Web => 0x3EB5,
            GapGraph::Road => 0x0AD7,
        }
    }

    /// Per-graph default edge factor (used when `edge_factor == 0`). The
    /// real GAP graphs differ in density too (kron/urand ef16, twitter
    /// ef24, web ef26); these values are calibrated so each analog sits
    /// in the same convergence regime as its GAP original at small scale
    /// (see EXPERIMENTS.md "regime matching").
    pub fn default_edge_factor(self) -> usize {
        match self {
            GapGraph::Kron => 12,
            GapGraph::Urand => 8,
            GapGraph::Twitter => 8,
            GapGraph::Web => 8,
            GapGraph::Road => 0, // lattice degree is structural
        }
    }

    /// Upper bound on the directed edges generation stages for this
    /// family at `(scale, edge_factor)` — before dedup, after
    /// symmetrization. Used to reject overflowing requests *before* any
    /// allocation happens.
    fn staged_edge_bound(self, scale: u32, edge_factor: usize) -> u128 {
        let n = 1u128 << scale.min(64);
        match self {
            // Symmetric families stage every edge twice.
            GapGraph::Kron | GapGraph::Urand => 2 * n * edge_factor as u128,
            GapGraph::Twitter | GapGraph::Web => n * edge_factor as u128,
            // Lattice: ≤ 2 forward neighbors per vertex, symmetrized.
            GapGraph::Road => 4 * n,
        }
    }

    /// Generate the unweighted graph at `2^scale` vertices (road rounds to
    /// the nearest square grid). `edge_factor == 0` selects the per-graph
    /// default.
    ///
    /// Panics (before allocating anything) if the requested size would
    /// push the edge count past the u32 edge index space — per-vertex
    /// degrees and the compressed store's row counts are 32-bit, so such
    /// a graph would otherwise truncate silently. `try_build` carries the
    /// same check as a `Result` backstop for hand-staged edge lists.
    pub fn generate(self, scale: u32, edge_factor: usize) -> Csr {
        let edge_factor = if edge_factor == 0 { self.default_edge_factor() } else { edge_factor };
        let staged = self.staged_edge_bound(scale, edge_factor);
        assert!(
            scale < 32 && staged <= u32::MAX as u128,
            "{} at scale {scale} with edge factor {edge_factor} would stage {staged} edges, \
             beyond the u32 edge index space",
            self.name(),
        );
        match self {
            GapGraph::Kron => rmat::generate(scale, edge_factor, self.seed()),
            GapGraph::Urand => uniform::generate(scale, edge_factor, self.seed()),
            GapGraph::Twitter => twitter::generate(scale, edge_factor, self.seed()),
            GapGraph::Web => web::generate(scale, edge_factor, self.seed()),
            // Road ignores edge_factor: lattice degree is structural.
            GapGraph::Road => grid::generate_scale(scale, self.seed()),
        }
    }

    /// Weighted variant (GAP uniform `[1,255]` weights) for SSSP.
    pub fn generate_weighted(self, scale: u32, edge_factor: usize) -> Csr {
        weights::assign_uniform(&self.generate(scale, edge_factor), self.seed() ^ 0xBF57)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for g in ALL {
            assert_eq!(GapGraph::from_name(g.name()), Some(g));
        }
        assert_eq!(GapGraph::from_name("nope"), None);
    }

    #[test]
    fn suite_generates_all() {
        for g in ALL {
            let c = g.generate(8, 4);
            assert!(c.num_vertices() >= 64, "{}", g.name());
            assert!(c.num_edges() > 0, "{}", g.name());
        }
    }

    #[test]
    fn expected_directedness() {
        assert!(GapGraph::Kron.generate(7, 4).is_symmetric());
        assert!(GapGraph::Urand.generate(7, 4).is_symmetric());
        assert!(GapGraph::Road.generate(8, 4).is_symmetric());
        assert!(!GapGraph::Twitter.generate(7, 4).is_symmetric());
        assert!(!GapGraph::Web.generate(7, 4).is_symmetric());
    }

    #[test]
    #[should_panic(expected = "beyond the u32 edge index space")]
    fn oversized_scale_rejected_before_allocation() {
        // 2·2^28·16 = 2^33 staged edges: must die on the arithmetic
        // check, not OOM in the generator.
        GapGraph::Kron.generate(28, 16);
    }

    #[test]
    #[should_panic(expected = "beyond the u32 edge index space")]
    fn oversized_directed_scale_rejected() {
        GapGraph::Web.generate(31, 4);
    }

    #[test]
    fn weighted_suite() {
        for g in ALL {
            let c = g.generate_weighted(7, 4);
            assert!(c.is_weighted(), "{}", g.name());
        }
    }
}
