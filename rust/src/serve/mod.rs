//! Always-on batched query serving over the lane engine (DESIGN.md
//! §11).
//!
//! The batch path (PR 5) answers a *fixed* set of queries per
//! invocation; this module keeps the engine resident and feeds it a
//! continuous stream: queries are admitted into a bounded FIFO queue
//! ([`BatchFormer`], backpressure on overflow), packed into k-lane
//! groups that run as single engine generations, answered through a
//! result cache keyed by `(algorithm, parameters, GraphVersion)`
//! ([`ResultCache`]), and measured by mergeable latency histograms
//! ([`LatencyHistogram`]) for p50/p99 SLO reporting. [`loadgen`]
//! drives a running server closed- or open-loop for the
//! `BENCH_serve.json` artifact and the `serve` experiment.
//!
//! Module map — submit flows left to right:
//!
//! * [`query`]: [`Query`] / [`QueryKey`] / [`ServedResult`] types.
//! * [`batcher`]: bounded admission + FIFO lane packing.
//! * [`server`]: the worker loop, cache discipline, shutdown.
//! * [`cache`]: version-keyed bounded answer cache.
//! * [`histogram`]: log-bucketed mergeable latency histograms.
//! * [`loadgen`]: closed-/open-loop drivers + [`LoadReport`].

pub mod batcher;
pub mod cache;
pub mod histogram;
pub mod loadgen;
pub mod query;
pub mod server;

pub use batcher::{BatchFormer, FormedBatch, QueueFull};
pub use cache::{CacheStats, ResultCache};
pub use histogram::LatencyHistogram;
pub use loadgen::{LoadMode, LoadReport, LoadSpec};
pub use query::{Query, QueryClass, QueryKey, QueryOutput, ServedResult};
pub use server::{QueryServer, QueryTicket, ServeConfig, ServeStats, SubmitError};
