//! The always-on query server: submit → batch → lane-group run → reply.
//!
//! [`QueryServer::start`] spawns one worker thread that loops forever:
//! wait until the [`BatchFormer`] can pack a lane group, run the group
//! as a single batched engine generation
//! ([`sssp::run_native_batch`] / [`pagerank::run_native_batch`]), decode
//! the per-lane answers, cache and reply, release the lanes, repeat.
//! Per-lane convergence drop-out means short queries inside a group
//! stop paying rounds the moment they settle; the lanes they occupied
//! return to the FIFO freelist when the group's generation ends and are
//! refilled by the next [`BatchFormer::form`].
//!
//! Concurrency layout — three shared pieces, strict lock order
//! **graph → cache → (histogram)**, with the former/state mutex never
//! held across either:
//!
//! * `graph: RwLock<VersionedGraph>` — queries run under a read lock
//!   (many batches could run concurrently in principle; today one
//!   worker), mutations under the write lock.
//! * `cache: Mutex<ResultCache>` — looked up at submit under the graph
//!   read lock; **inserted under the same read lock the batch ran
//!   under**. That ordering is what makes invalidation race-free: a
//!   concurrent [`QueryServer::apply_mutations`] needs the write lock
//!   to bump the version, so it cannot interleave between "computed at
//!   version v" and "cached at version v" and leave a stale entry
//!   behind. (Hits are version-correct by the key alone; this protects
//!   the *no stale entry survives* memory invariant.)
//! * `state: Mutex<ServerState>` + condvar — admission queue, lane
//!   occupancy, counters. Submitters signal the worker after admitting;
//!   [`QueryServer::shutdown`] sets the flag, wakes the worker, and
//!   joins it after the queue drains.
//!
//! Replies travel over per-query [`mpsc`] channels
//! ([`QueryTicket::wait`]), so a slow client blocks nobody.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatchFormer, QueueFull};
use super::cache::{CacheStats, ResultCache};
use super::histogram::LatencyHistogram;
use super::query::{Query, QueryOutput, ServedResult};
use crate::algorithms::pagerank::{self, PrConfig};
use crate::algorithms::sssp;
use crate::engine::EngineConfig;
use crate::graph::{Csr, EdgeMutation, GraphVersion, MutationReceipt, VersionedGraph, VertexId};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Lane-group width `k` the batch former packs toward (must be a
    /// legal lane count: 1, 2, 4, 8, or 16).
    pub lanes: usize,
    /// Admission-queue bound — beyond this, submits are rejected with
    /// [`SubmitError::Overloaded`] (the backpressure signal).
    pub queue_capacity: usize,
    /// Result-cache bound in answers (0 disables caching).
    pub cache_capacity: usize,
    /// Engine configuration for every served batch.
    pub engine: EngineConfig,
    /// PageRank hyper-parameters for PPR queries.
    pub pr: PrConfig,
}

impl ServeConfig {
    /// Defaults: `k` lanes, a 4·k admission queue, a 64-answer cache.
    pub fn new(lanes: usize, engine: EngineConfig) -> Self {
        Self { lanes, queue_capacity: 4 * lanes.max(1), cache_capacity: 64, engine, pr: PrConfig::default() }
    }
}

/// Why a submit was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Backpressure: the admission queue is full. The query comes back
    /// so a closed-loop client can retry and an open-loop one can count
    /// the drop.
    Overloaded(Query),
    /// The query fails validation against the current graph
    /// ([`Query::validate`]); the message names the problem.
    Invalid(String),
    /// The server is shutting down and admits nothing new.
    ShuttingDown(Query),
}

/// Handle for one admitted (or cache-answered) query.
#[derive(Debug)]
pub struct QueryTicket {
    rx: mpsc::Receiver<ServedResult>,
}

impl QueryTicket {
    /// Block until the answer arrives.
    pub fn wait(self) -> ServedResult {
        self.rx.recv().expect("the server answers every admitted query before dropping its sender")
    }
}

/// One admitted query waiting for (or occupying) a lane.
struct PendingQuery {
    query: Query,
    reply: mpsc::Sender<ServedResult>,
    submitted: Instant,
}

/// Everything behind the state mutex.
struct ServerState {
    former: BatchFormer<PendingQuery>,
    shutting_down: bool,
    /// Queries answered by an engine run.
    served_engine: u64,
    /// Queries answered from the result cache at submit.
    served_cached: u64,
    /// Submits rejected by backpressure.
    rejected: u64,
}

/// State shared between the front end and the worker thread.
struct Shared {
    graph: RwLock<VersionedGraph>,
    cache: Mutex<ResultCache>,
    state: Mutex<ServerState>,
    /// Signalled on admit and on shutdown.
    work_ready: Condvar,
    hist: Mutex<LatencyHistogram>,
    /// Set once the worker exits (normally at shutdown; also on
    /// panic, so submitters fail fast instead of queueing forever).
    worker_gone: AtomicBool,
}

/// Counter snapshot from [`QueryServer::stats`].
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Queries answered by engine runs.
    pub served_engine: u64,
    /// Queries answered from the result cache.
    pub served_cached: u64,
    /// Submits rejected by backpressure.
    pub rejected: u64,
    /// Current graph version.
    pub version: GraphVersion,
    /// Result-cache counters.
    pub cache: CacheStats,
    /// Submit-to-reply latency histogram (cache hits included).
    pub hist: LatencyHistogram,
}

/// The always-on serving front end over the lane engine (see module
/// docs).
pub struct QueryServer {
    shared: Arc<Shared>,
    worker: Option<JoinHandle<()>>,
}

impl QueryServer {
    /// Take ownership of `graph` and start serving with one worker
    /// thread. Panics if `cfg.lanes` is not a legal lane count.
    pub fn start(graph: VersionedGraph, cfg: ServeConfig) -> Self {
        let shared = Arc::new(Shared {
            graph: RwLock::new(graph),
            cache: Mutex::new(ResultCache::new(cfg.cache_capacity)),
            state: Mutex::new(ServerState {
                former: BatchFormer::new(cfg.lanes, cfg.queue_capacity),
                shutting_down: false,
                served_engine: 0,
                served_cached: 0,
                rejected: 0,
            }),
            work_ready: Condvar::new(),
            hist: Mutex::new(LatencyHistogram::new()),
            worker_gone: AtomicBool::new(false),
        });
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("daig-serve".into())
                .spawn(move || worker_loop(&shared, &cfg.engine, &cfg.pr))
                .expect("spawn serve worker")
        };
        Self { shared, worker: Some(worker) }
    }

    /// Submit a query. Returns a ticket immediately: pre-answered on a
    /// cache hit, otherwise fulfilled by the worker after the query's
    /// lane group runs. Errors are immediate (validation, backpressure,
    /// shutdown) — a submit never blocks on the engine.
    pub fn submit(&self, query: Query) -> Result<QueryTicket, SubmitError> {
        if self.shared.worker_gone.load(Ordering::Acquire) {
            return Err(SubmitError::ShuttingDown(query));
        }
        let submitted = Instant::now();
        // Cache lookup under the graph read lock: the version read and
        // the cache probe see the same graph (lock order graph → cache).
        {
            let g = self.shared.graph.read().unwrap();
            query.validate(&*g).map_err(SubmitError::Invalid)?;
            let key = query.key(g.version());
            let mut cache = self.shared.cache.lock().unwrap();
            if let Some(output) = cache.get(&key) {
                let version = key.version;
                drop(cache);
                drop(g);
                let latency_s = submitted.elapsed().as_secs_f64();
                self.shared.hist.lock().unwrap().record_secs(latency_s);
                self.shared.state.lock().unwrap().served_cached += 1;
                let (tx, rx) = mpsc::channel();
                tx.send(ServedResult { query, version, output, latency_s, cached: true })
                    .expect("receiver held locally");
                return Ok(QueryTicket { rx });
            }
        }
        let (tx, rx) = mpsc::channel();
        let mut st = self.shared.state.lock().unwrap();
        if st.shutting_down {
            return Err(SubmitError::ShuttingDown(query));
        }
        let class = query.class();
        let pending = PendingQuery { query, reply: tx, submitted };
        match st.former.admit(class, pending) {
            Ok(()) => {
                self.shared.work_ready.notify_one();
                Ok(QueryTicket { rx })
            }
            Err(QueueFull(p)) => {
                st.rejected += 1;
                Err(SubmitError::Overloaded(p.query))
            }
        }
    }

    /// Submit and block for the answer — the closed-loop client path.
    pub fn query(&self, query: Query) -> Result<ServedResult, SubmitError> {
        self.submit(query).map(QueryTicket::wait)
    }

    /// Apply a mutation batch under the graph write lock, then drop
    /// result-cache entries stranded at superseded versions. In-flight
    /// batches finish against the pre-mutation graph (they hold the
    /// read lock) and their answers carry the version they ran at.
    pub fn apply_mutations(&self, batch: &[EdgeMutation]) -> anyhow::Result<MutationReceipt> {
        let mut g = self.shared.graph.write().unwrap();
        let receipt = g.apply_batch(batch)?;
        // Still under the write lock: no batch can cache a stale entry
        // between the version bump and this sweep.
        self.shared.cache.lock().unwrap().invalidate_older_than(receipt.version);
        Ok(receipt)
    }

    /// Current graph version.
    pub fn version(&self) -> GraphVersion {
        self.shared.graph.read().unwrap().version()
    }

    /// Consistent `(version, CSR snapshot)` pair — what the
    /// serve-while-mutating differential suite replays oracles against.
    pub fn snapshot_csr(&self) -> (GraphVersion, Csr) {
        let g = self.shared.graph.read().unwrap();
        (g.version(), g.to_csr())
    }

    /// A deterministic mutation batch against the current graph
    /// (delegates to [`VersionedGraph::random_batch`]).
    pub fn random_batch(&self, frac: f64, seed: u64) -> Vec<EdgeMutation> {
        self.shared.graph.read().unwrap().random_batch(frac, seed)
    }

    /// Counter snapshot (histogram cloned, not drained).
    pub fn stats(&self) -> ServeStats {
        let version = self.shared.graph.read().unwrap().version();
        let cache = self.shared.cache.lock().unwrap().stats();
        let hist = self.shared.hist.lock().unwrap().clone();
        let st = self.shared.state.lock().unwrap();
        ServeStats {
            served_engine: st.served_engine,
            served_cached: st.served_cached,
            rejected: st.rejected,
            version,
            cache,
            hist,
        }
    }

    /// Stop admitting, drain every already-admitted query, join the
    /// worker, and return the final counters.
    pub fn shutdown(mut self) -> ServeStats {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutting_down = true;
            self.shared.work_ready.notify_all();
        }
        if let Some(w) = self.worker.take() {
            w.join().expect("serve worker panicked");
        }
        self.stats()
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            {
                let mut st = self.shared.state.lock().unwrap();
                st.shutting_down = true;
                self.shared.work_ready.notify_all();
            }
            // Drop during an unwind must not double-panic.
            let _ = w.join();
        }
    }
}

/// The worker: form → run → reply → release, until shutdown drains the
/// queue.
fn worker_loop(shared: &Shared, ecfg: &EngineConfig, pr: &PrConfig) {
    // Guard: mark the worker gone even if a batch run panics, so
    // submitters get `ShuttingDown` instead of tickets nobody answers.
    struct Gone<'a>(&'a AtomicBool);
    impl Drop for Gone<'_> {
        fn drop(&mut self) {
            self.0.store(true, Ordering::Release);
        }
    }
    let _gone = Gone(&shared.worker_gone);

    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(b) = st.former.form() {
                    break Some(b);
                }
                if st.shutting_down && st.former.is_idle() {
                    break None;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { return };

        // Run the group under the graph read lock; keep holding it
        // while caching so no mutation can interleave (module docs).
        let (version, outputs) = {
            let g = shared.graph.read().unwrap();
            let version = g.version();
            let outputs: Vec<Arc<QueryOutput>> = match batch.class {
                super::query::QueryClass::Sssp => {
                    let sources: Vec<VertexId> = batch
                        .items
                        .iter()
                        .map(|p| match &p.query {
                            Query::Sssp { source } => *source,
                            Query::Ppr { .. } => unreachable!("former never mixes classes"),
                        })
                        .collect();
                    let res = sssp::run_native_batch(&*g, &sources, ecfg);
                    res.dist.into_iter().map(|d| Arc::new(QueryOutput::Distances(d))).collect()
                }
                super::query::QueryClass::Ppr => {
                    let teleports: Vec<Vec<VertexId>> = batch
                        .items
                        .iter()
                        .map(|p| match &p.query {
                            Query::Ppr { teleports } => teleports.clone(),
                            Query::Sssp { .. } => unreachable!("former never mixes classes"),
                        })
                        .collect();
                    let res = pagerank::run_native_batch(&*g, &teleports, ecfg, pr);
                    res.values.into_iter().map(|v| Arc::new(QueryOutput::Scores(v))).collect()
                }
            };
            let mut cache = shared.cache.lock().unwrap();
            for (p, out) in batch.items.iter().zip(&outputs) {
                cache.insert(p.query.key(version), Arc::clone(out));
            }
            (version, outputs)
        };

        // Reply (receiver may have hung up — that only loses the
        // answer, not the lane) and record latency.
        {
            let mut hist = shared.hist.lock().unwrap();
            for (p, output) in batch.items.into_iter().zip(outputs) {
                let latency_s = p.submitted.elapsed().as_secs_f64();
                hist.record_secs(latency_s);
                let _ = p.reply.send(ServedResult {
                    query: p.query,
                    version,
                    output,
                    latency_s,
                    cached: false,
                });
            }
        }

        let served = batch.lanes.len() as u64;
        let mut st = shared.state.lock().unwrap();
        st.former.release(&batch.lanes);
        st.served_engine += served;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ExecutionMode;

    fn small_server(lanes: usize) -> QueryServer {
        let csr = crate::graph::generators::uniform::generate(6, 4, 7);
        let vg = VersionedGraph::new(crate::graph::weights::assign_uniform(&csr, 7));
        let ecfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        QueryServer::start(vg, ServeConfig::new(lanes, ecfg))
    }

    #[test]
    fn serves_sssp_and_ppr_end_to_end() {
        let server = small_server(4);
        let (v0, csr) = server.snapshot_csr();
        let d = server.query(Query::Sssp { source: 0 }).expect("admitted");
        assert_eq!(d.version, v0);
        assert!(!d.cached);
        assert_eq!(d.output.distances().unwrap(), &crate::algorithms::oracle::dijkstra(&csr, 0)[..]);
        let p = server.query(Query::Ppr { teleports: vec![1, 2] }).expect("admitted");
        assert_eq!(p.output.scores().unwrap().len(), csr.num_vertices());
        let stats = server.shutdown();
        assert_eq!(stats.served_engine, 2);
        assert_eq!(stats.hist.count(), 2);
    }

    #[test]
    fn repeat_query_is_served_from_cache_until_mutation() {
        let server = small_server(2);
        let first = server.query(Query::Sssp { source: 3 }).unwrap();
        assert!(!first.cached);
        let again = server.query(Query::Sssp { source: 3 }).unwrap();
        assert!(again.cached, "repeat at the same version hits the cache");
        assert_eq!(again.output, first.output);
        let batch = server.random_batch(0.05, 11);
        let receipt = server.apply_mutations(&batch).expect("batch applies");
        let after = server.query(Query::Sssp { source: 3 }).unwrap();
        assert!(!after.cached, "version bump forces recompute");
        assert_eq!(after.version, receipt.version);
        let stats = server.shutdown();
        assert_eq!(stats.served_cached, 1);
        assert_eq!(stats.cache.hits, 1);
    }

    #[test]
    fn invalid_queries_are_rejected_at_submit() {
        let server = small_server(1);
        match server.submit(Query::Sssp { source: 1 << 20 }) {
            Err(SubmitError::Invalid(msg)) => assert!(msg.contains("out of range"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        match server.submit(Query::Ppr { teleports: vec![] }) {
            Err(SubmitError::Invalid(msg)) => assert!(msg.contains("empty"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        let stats = server.shutdown();
        assert_eq!(stats.served_engine + stats.served_cached, 0);
    }

    #[test]
    fn shutdown_drains_admitted_queries() {
        let server = small_server(8);
        let tickets: Vec<QueryTicket> =
            (0..8).map(|s| server.submit(Query::Sssp { source: s }).expect("admitted")).collect();
        let stats = server.shutdown();
        assert_eq!(stats.served_engine, 8, "every admitted query is answered before exit");
        for t in tickets {
            let r = t.wait();
            assert!(r.output.distances().is_some());
        }
    }
}
