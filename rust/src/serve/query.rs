//! Query and result types for the serving front end.
//!
//! A [`Query`] names one of the two lane-batched algorithms plus its
//! parameters; a [`QueryKey`] adds the [`GraphVersion`] it was (or
//! would be) answered against, which makes it the result-cache key —
//! two textually identical queries separated by a mutation batch are
//! *different* keys, so a cache hit is always version-correct by
//! construction.

use std::sync::Arc;

use crate::graph::{GraphStore, GraphVersion, VertexId};

/// Which lane-batched algorithm a query runs. CC/BFS have no batched
/// variant, so the server does not admit them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryClass {
    /// Multi-source Bellman-Ford ([`crate::algorithms::sssp::MultiSssp`]).
    Sssp,
    /// Personalized PageRank
    /// ([`crate::algorithms::pagerank::MultiPageRank`]).
    Ppr,
}

impl QueryClass {
    /// Short label for reports.
    pub fn label(self) -> &'static str {
        match self {
            QueryClass::Sssp => "sssp",
            QueryClass::Ppr => "ppr",
        }
    }
}

/// One serving query: an SSSP source or a personalized-PageRank
/// teleport set.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Query {
    /// Distances from `source` (requires a weighted graph).
    Sssp {
        /// Source vertex.
        source: VertexId,
    },
    /// Personalized PageRank over a non-empty teleport set.
    Ppr {
        /// Teleport vertices (uniform restart distribution).
        teleports: Vec<VertexId>,
    },
}

impl Query {
    /// The algorithm class this query runs under.
    pub fn class(&self) -> QueryClass {
        match self {
            Query::Sssp { .. } => QueryClass::Sssp,
            Query::Ppr { .. } => QueryClass::Ppr,
        }
    }

    /// The query's parameter vector (source / teleport set) — what,
    /// together with the class and graph version, keys the result
    /// cache.
    pub fn params(&self) -> &[VertexId] {
        match self {
            Query::Sssp { source } => std::slice::from_ref(source),
            Query::Ppr { teleports } => teleports,
        }
    }

    /// Cache key for answering this query at graph `version`.
    pub fn key(&self, version: GraphVersion) -> QueryKey {
        QueryKey { class: self.class(), params: self.params().to_vec(), version }
    }

    /// Validate against a graph: endpoints in range, SSSP only on
    /// weighted graphs, PPR teleport sets non-empty. Errors name the
    /// offending input so a rejected submit is self-explanatory.
    pub fn validate<G: GraphStore>(&self, g: &G) -> Result<(), String> {
        let n = g.num_vertices() as VertexId;
        match self {
            Query::Sssp { source } => {
                if !g.is_weighted() {
                    return Err("sssp query on an unweighted graph".into());
                }
                if *source >= n {
                    return Err(format!("sssp source {source} out of range for n={n}"));
                }
            }
            Query::Ppr { teleports } => {
                if teleports.is_empty() {
                    return Err("ppr query with an empty teleport set".into());
                }
                if let Some(&v) = teleports.iter().find(|&&v| v >= n) {
                    return Err(format!("ppr teleport {v} out of range for n={n}"));
                }
            }
        }
        Ok(())
    }
}

/// Result-cache key: `(algorithm, source/teleport-set, GraphVersion)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct QueryKey {
    /// Algorithm class.
    pub class: QueryClass,
    /// Source (SSSP) or teleport set (PPR).
    pub params: Vec<VertexId>,
    /// Graph version the answer is valid for.
    pub version: GraphVersion,
}

/// A decoded per-query answer.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryOutput {
    /// SSSP distances per vertex ([`crate::algorithms::sssp::INF`] =
    /// unreachable).
    Distances(Vec<u32>),
    /// Personalized PageRank scores per vertex (mass-normalized like
    /// [`crate::algorithms::pagerank::MultiPrResult`]).
    Scores(Vec<f32>),
}

impl QueryOutput {
    /// SSSP distances, or `None` for a PPR answer.
    pub fn distances(&self) -> Option<&[u32]> {
        match self {
            QueryOutput::Distances(d) => Some(d),
            QueryOutput::Scores(_) => None,
        }
    }

    /// PPR scores, or `None` for an SSSP answer.
    pub fn scores(&self) -> Option<&[f32]> {
        match self {
            QueryOutput::Scores(s) => Some(s),
            QueryOutput::Distances(_) => None,
        }
    }
}

/// What the server hands back for one admitted query.
#[derive(Debug, Clone)]
pub struct ServedResult {
    /// The query this answers.
    pub query: Query,
    /// Graph version the answer was computed against — the contract
    /// the serve-while-mutating differential suite checks results by.
    pub version: GraphVersion,
    /// The answer (shared with the result cache).
    pub output: Arc<QueryOutput>,
    /// Submit-to-response latency, seconds, as measured by the server.
    pub latency_s: f64,
    /// Whether the answer came out of the result cache.
    pub cached: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn keys_distinguish_class_params_and_version() {
        let q = Query::Sssp { source: 3 };
        let p = Query::Ppr { teleports: vec![3] };
        let v0 = GraphVersion(0);
        let v1 = GraphVersion(1);
        assert_ne!(q.key(v0), p.key(v0), "same params, different class");
        assert_ne!(q.key(v0), q.key(v1), "same query, different version");
        assert_eq!(q.key(v0), Query::Sssp { source: 3 }.key(v0));
        assert_eq!(q.params(), &[3]);
        assert_eq!(q.class().label(), "sssp");
        assert_eq!(p.class().label(), "ppr");
    }

    #[test]
    fn validation_names_the_problem() {
        let unweighted = GraphBuilder::new(4).edges(&[(0, 1), (1, 2)]).build();
        let weighted = GraphBuilder::new(4).weighted_edges(&[(0, 1, 2), (1, 2, 3)]).build();
        assert!(Query::Sssp { source: 0 }.validate(&unweighted).unwrap_err().contains("unweighted"));
        assert!(Query::Sssp { source: 9 }.validate(&weighted).unwrap_err().contains("out of range"));
        assert!(Query::Sssp { source: 0 }.validate(&weighted).is_ok());
        assert!(Query::Ppr { teleports: vec![] }.validate(&unweighted).unwrap_err().contains("empty"));
        assert!(Query::Ppr { teleports: vec![0, 9] }.validate(&unweighted).unwrap_err().contains("out of range"));
        assert!(Query::Ppr { teleports: vec![0, 2] }.validate(&unweighted).is_ok());
    }

    #[test]
    fn outputs_decode_by_kind() {
        let d = QueryOutput::Distances(vec![0, 5]);
        let s = QueryOutput::Scores(vec![0.5, 0.5]);
        assert_eq!(d.distances(), Some(&[0u32, 5][..]));
        assert!(d.scores().is_none());
        assert_eq!(s.scores(), Some(&[0.5f32, 0.5][..]));
        assert!(s.distances().is_none());
    }
}
