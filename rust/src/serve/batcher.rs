//! The batch former: bounded admission + FIFO packing into lane groups.
//!
//! Arriving queries enter a bounded FIFO admission queue; when the
//! queue is full, [`BatchFormer::admit`] rejects with the item handed
//! back — that rejection *is* the backpressure signal, surfaced to
//! clients as [`crate::serve::SubmitError::Overloaded`] so a closed
//! loop retries and an open loop counts a drop instead of queueing
//! unboundedly.
//!
//! [`BatchFormer::form`] packs the next lane group: it takes the
//! oldest waiting query's [`QueryClass`] (a lane group runs one vertex
//! program, so SSSP and PPR queries can never share a group), collects
//! same-class queries in FIFO order, and sizes the group to the
//! **largest legal lane count** that the free lanes and the same-class
//! backlog support — lane counts must divide a cache line
//! ([`lanes::valid_lane_count`]), so 3 waiting queries form a group of
//! 2 and leave one queued rather than pad a dead lane. Lane indices
//! come from the engine's [`LaneSlots`] allocator, whose freelist is
//! FIFO: lanes freed by per-lane convergence drop-out are refilled in
//! the order they were freed.
//!
//! Invariants (property-tested in `rust/tests/prop_serve.rs`):
//!
//! * a lane is never assigned to two in-flight queries;
//! * freed lanes are refilled in FIFO order;
//! * every formed group's size is a legal lane count (divides a cache
//!   line);
//! * admission never exceeds the configured queue bound.

use std::collections::VecDeque;

use super::query::QueryClass;
use crate::engine::lanes::{self, LaneSlots};

/// Backpressure: the admission queue is full. Carries the rejected
/// item back to the caller so nothing is silently dropped.
#[derive(Debug)]
pub struct QueueFull<T>(pub T);

/// One formed lane group, ready to run as a single engine generation.
#[derive(Debug)]
pub struct FormedBatch<T> {
    /// Algorithm class every member shares.
    pub class: QueryClass,
    /// Lane index per member (from [`LaneSlots`]; release after the
    /// run via [`BatchFormer::release`]).
    pub lanes: Vec<usize>,
    /// The members, FIFO order.
    pub items: Vec<T>,
}

/// Bounded admission queue + lane packer (see module docs).
#[derive(Debug)]
pub struct BatchFormer<T> {
    /// Admission bound (pending queries, not in-flight lanes).
    capacity: usize,
    /// FIFO admission queue: `(sequence, class, payload)`.
    queue: VecDeque<(u64, QueryClass, T)>,
    /// Lane occupancy (FIFO freelist).
    slots: LaneSlots,
    /// Monotone admission sequence, doubling as the slot occupant id.
    next_seq: u64,
}

impl<T> BatchFormer<T> {
    /// Former over `k` lanes with an admission queue bounded at
    /// `capacity` queries. Panics unless `k` is a legal lane count and
    /// `capacity > 0`.
    pub fn new(k: usize, capacity: usize) -> Self {
        assert!(capacity > 0, "admission queue needs capacity >= 1");
        Self { capacity, queue: VecDeque::new(), slots: LaneSlots::new(k), next_seq: 0 }
    }

    /// Lane-group width this former packs toward.
    pub fn lanes(&self) -> usize {
        self.slots.lanes()
    }

    /// Admission queue bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queries waiting for a lane.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Lanes currently running queries (assigned, not yet released).
    pub fn in_flight(&self) -> usize {
        self.slots.occupied()
    }

    /// Whether there is nothing waiting *and* nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.occupied() == 0
    }

    /// Enqueue a query, or reject it (handing it back) when the queue
    /// is at capacity — the backpressure path.
    pub fn admit(&mut self, class: QueryClass, item: T) -> Result<(), QueueFull<T>> {
        if self.queue.len() >= self.capacity {
            return Err(QueueFull(item));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back((seq, class, item));
        Ok(())
    }

    /// Largest legal lane count `<= want`, bounded by the group width
    /// (`0` when `want == 0`).
    fn widest_group(&self, want: usize) -> usize {
        let mut best = 0;
        for g in lanes::LANE_COUNTS {
            if g <= want && g <= self.slots.lanes() && g > best {
                best = g;
            }
        }
        best
    }

    /// Pack the next lane group, or `None` when nothing can form (no
    /// pending queries, or no free lanes). Takes the oldest query's
    /// class, gathers same-class queries FIFO, and sizes the group to
    /// the largest legal lane count those queries and the free lanes
    /// allow. Queries of the *other* class stay queued in order for a
    /// later group.
    pub fn form(&mut self) -> Option<FormedBatch<T>> {
        let (_, class, _) = self.queue.front()?;
        let class = *class;
        let same: usize = self.queue.iter().filter(|(_, c, _)| *c == class).count();
        let group = self.widest_group(same.min(self.slots.free_lanes()));
        if group == 0 {
            return None;
        }
        let mut lanes_out = Vec::with_capacity(group);
        let mut items = Vec::with_capacity(group);
        let mut i = 0;
        while items.len() < group {
            if self.queue[i].1 == class {
                let (seq, _, item) = self.queue.remove(i).expect("index in bounds");
                let lane = self.slots.assign(seq).expect("free lanes were counted above");
                lanes_out.push(lane);
                items.push(item);
            } else {
                i += 1;
            }
        }
        Some(FormedBatch { class, lanes: lanes_out, items })
    }

    /// Release a finished group's lanes back to the FIFO freelist
    /// (call once per formed batch, after its engine run completes).
    pub fn release(&mut self, lanes: &[usize]) {
        for &l in lanes {
            self.slots.release(l);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_fifo_and_sizes_legally() {
        let mut f: BatchFormer<u32> = BatchFormer::new(8, 64);
        for i in 0..3 {
            f.admit(QueryClass::Sssp, i).unwrap();
        }
        // 3 pending -> group of 2 (largest legal <= 3), FIFO members.
        let b = f.form().unwrap();
        assert_eq!(b.class, QueryClass::Sssp);
        assert_eq!(b.items, vec![0, 1]);
        assert_eq!(b.lanes.len(), 2);
        assert!(lanes::valid_lane_count(b.lanes.len()));
        assert_eq!(f.pending(), 1);
        assert_eq!(f.in_flight(), 2);
        // The straggler forms a singleton group on the next call.
        let b2 = f.form().unwrap();
        assert_eq!(b2.items, vec![2]);
        assert!(f.form().is_none(), "nothing left to pack");
        f.release(&b.lanes);
        f.release(&b2.lanes);
        assert!(f.is_idle());
    }

    #[test]
    fn classes_never_share_a_group() {
        let mut f: BatchFormer<&str> = BatchFormer::new(4, 64);
        f.admit(QueryClass::Sssp, "s0").unwrap();
        f.admit(QueryClass::Ppr, "p0").unwrap();
        f.admit(QueryClass::Sssp, "s1").unwrap();
        f.admit(QueryClass::Ppr, "p1").unwrap();
        let b = f.form().unwrap();
        assert_eq!((b.class, b.items.clone()), (QueryClass::Sssp, vec!["s0", "s1"]));
        let b2 = f.form().unwrap();
        assert_eq!((b2.class, b2.items.clone()), (QueryClass::Ppr, vec!["p0", "p1"]));
    }

    #[test]
    fn admission_is_bounded() {
        let mut f: BatchFormer<u32> = BatchFormer::new(4, 2);
        f.admit(QueryClass::Sssp, 0).unwrap();
        f.admit(QueryClass::Sssp, 1).unwrap();
        let QueueFull(back) = f.admit(QueryClass::Sssp, 2).unwrap_err();
        assert_eq!(back, 2, "the rejected item comes back to the caller");
        // Forming drains the queue, re-opening admission.
        let b = f.form().unwrap();
        assert_eq!(b.items.len(), 2);
        f.admit(QueryClass::Sssp, 3).unwrap();
    }

    #[test]
    fn no_free_lanes_means_no_group() {
        let mut f: BatchFormer<u32> = BatchFormer::new(1, 8);
        f.admit(QueryClass::Sssp, 0).unwrap();
        f.admit(QueryClass::Sssp, 1).unwrap();
        let b = f.form().unwrap();
        assert_eq!(b.items, vec![0]);
        assert!(f.form().is_none(), "the single lane is in flight");
        f.release(&b.lanes);
        assert_eq!(f.form().unwrap().items, vec![1]);
    }
}
