//! Load generation against a running [`QueryServer`].
//!
//! Two standard driver shapes (the same pair the noria/FASTER serving
//! papers report with):
//!
//! * **Closed loop** ([`LoadMode::Closed`]): `clients` threads each
//!   submit one query, block on the answer, submit the next. Offered
//!   load self-limits to the service rate, so throughput *is* capacity
//!   — this is the mode the `serve` experiment's k-scaling assertion
//!   uses. Backpressure rejections are retried (after a yield), because
//!   a closed-loop client has nothing better to do.
//! * **Open loop** ([`LoadMode::Open`]): one dispatcher fires queries
//!   on an exponential-interarrival clock at `qps`, regardless of how
//!   the server keeps up. Backpressure rejections are *counted as
//!   drops*, not retried — queueing them would just rebuild the closed
//!   loop — which makes overload visible in the report instead of in
//!   unbounded latency.
//!
//! Every query's class/parameters are drawn deterministically from the
//! workload seed (per-client [`SplitMix64::fork`]s), so a load run is
//! reproducible modulo thread interleaving. An optional mutator applies
//! a [`VersionedGraph::random_batch`]-style delta every
//! `mutate_every` queries, exercising the serve-while-mutating path
//! under load.
//!
//! [`VersionedGraph::random_batch`]: crate::graph::VersionedGraph::random_batch

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::histogram::LatencyHistogram;
use super::query::Query;
use super::server::{QueryServer, SubmitError};
use crate::graph::VertexId;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// How the generator offers load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadMode {
    /// `clients` synchronous submit-wait loops (throughput = capacity).
    Closed {
        /// Concurrent client threads.
        clients: usize,
    },
    /// Exponential-interarrival dispatch at `qps`, drops on overload.
    Open {
        /// Target offered queries per second.
        qps: f64,
    },
}

/// Workload description for one load run.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Offered-load shape.
    pub mode: LoadMode,
    /// Total queries to issue (admitted + dropped).
    pub queries: usize,
    /// Fraction of queries that are PPR (rest are SSSP); PPR teleport
    /// sets are 1-4 vertices.
    pub ppr_frac: f64,
    /// Apply one random mutation batch per this many issued queries
    /// (`0` = never mutate).
    pub mutate_every: usize,
    /// Fraction of edges each mutation batch touches.
    pub mutate_frac: f64,
    /// Workload seed (query parameters, interarrivals, mutations).
    pub seed: u64,
}

impl LoadSpec {
    /// Closed-loop spec with no mutations.
    pub fn closed(clients: usize, queries: usize, seed: u64) -> Self {
        Self { mode: LoadMode::Closed { clients }, queries, ppr_frac: 0.25, mutate_every: 0, mutate_frac: 0.02, seed }
    }

    /// Open-loop spec with no mutations.
    pub fn open(qps: f64, queries: usize, seed: u64) -> Self {
        Self { mode: LoadMode::Open { qps }, queries, ppr_frac: 0.25, mutate_every: 0, mutate_frac: 0.02, seed }
    }

    /// Builder-style: mutate every `every` issued queries.
    pub fn with_mutations(mut self, every: usize, frac: f64) -> Self {
        self.mutate_every = every;
        self.mutate_frac = frac;
        self
    }
}

/// What a load run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Queries offered (admitted + dropped).
    pub issued: u64,
    /// Queries answered (engine or cache).
    pub served: u64,
    /// Open-loop drops / closed-loop retried rejections.
    pub rejected: u64,
    /// Of `served`, how many came from the result cache.
    pub cached: u64,
    /// Mutation batches applied by the driver.
    pub mutations: u64,
    /// Wall-clock duration of the run, seconds.
    pub elapsed_s: f64,
    /// Served queries per second.
    pub qps: f64,
    /// Client-observed latency (merged across client threads).
    pub hist: LatencyHistogram,
}

impl LoadReport {
    /// JSON object for BENCH artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("issued", Json::Num(self.issued as f64)),
            ("served", Json::Num(self.served as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("cached", Json::Num(self.cached as f64)),
            ("mutations", Json::Num(self.mutations as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("qps", Json::Num(self.qps)),
            ("latency", self.hist.to_json()),
        ])
    }
}

/// Draw the next query from the workload distribution: PPR with
/// probability `ppr_frac` (1–4 uniform teleports), SSSP otherwise,
/// parameters uniform over the vertex space. Public because the sharded
/// router (`daig route`) replays the same workload against a cluster.
pub fn next_query(rng: &mut SplitMix64, n: usize, ppr_frac: f64) -> Query {
    if rng.chance(ppr_frac) {
        let k = 1 + rng.index(4);
        let teleports: Vec<VertexId> = (0..k).map(|_| rng.index(n) as VertexId).collect();
        Query::Ppr { teleports }
    } else {
        Query::Sssp { source: rng.index(n) as VertexId }
    }
}

/// Run `spec` against `server`, blocking until every issued query is
/// answered or dropped. The server keeps running afterwards (callers
/// own shutdown).
pub fn run(server: &QueryServer, n_vertices: usize, spec: &LoadSpec) -> LoadReport {
    match spec.mode {
        LoadMode::Closed { clients } => run_closed(server, n_vertices, spec, clients.max(1)),
        LoadMode::Open { qps } => run_open(server, n_vertices, spec, qps),
    }
}

/// Shared driver state: the issue counter doubles as the mutation
/// trigger, so "one batch per `mutate_every` issued" holds across
/// client threads without a coordinator.
struct DriverCounters {
    issued: AtomicU64,
    rejected: AtomicU64,
    cached: AtomicU64,
    mutations: AtomicU64,
    failed: AtomicBool,
}

impl DriverCounters {
    fn new() -> Self {
        Self {
            issued: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            cached: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
            failed: AtomicBool::new(false),
        }
    }
}

/// Apply the driver-side mutation if `issued` crossed a trigger point.
fn maybe_mutate(
    server: &QueryServer,
    spec: &LoadSpec,
    counters: &DriverCounters,
    issued: u64,
    rng: &Mutex<SplitMix64>,
) {
    if spec.mutate_every == 0 || issued == 0 || issued % spec.mutate_every as u64 != 0 {
        return;
    }
    let batch = {
        let mut rng = rng.lock().unwrap();
        server.random_batch(spec.mutate_frac, rng.next_u64())
    };
    if server.apply_mutations(&batch).is_ok() {
        counters.mutations.fetch_add(1, Ordering::Relaxed);
    }
}

fn run_closed(server: &QueryServer, n: usize, spec: &LoadSpec, clients: usize) -> LoadReport {
    let counters = DriverCounters::new();
    let mutate_rng = Mutex::new(SplitMix64::new(spec.seed ^ 0xDE1A));
    let hist = Mutex::new(LatencyHistogram::new());
    let served = AtomicU64::new(0);
    let start = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let mut rng = SplitMix64::new(spec.seed).fork(c as u64);
            let (counters, hist, served, mutate_rng) = (&counters, &hist, &served, &mutate_rng);
            s.spawn(move || {
                let mut local = LatencyHistogram::new();
                loop {
                    let ticket = counters.issued.fetch_add(1, Ordering::Relaxed);
                    if ticket >= spec.queries as u64 || counters.failed.load(Ordering::Relaxed) {
                        break;
                    }
                    maybe_mutate(server, spec, counters, ticket, mutate_rng);
                    let mut query = next_query(&mut rng, n, spec.ppr_frac);
                    // A closed-loop client retries backpressure — it
                    // has nothing else to offer until this answer.
                    loop {
                        match server.query(query) {
                            Ok(res) => {
                                local.record_secs(res.latency_s);
                                served.fetch_add(1, Ordering::Relaxed);
                                if res.cached {
                                    counters.cached.fetch_add(1, Ordering::Relaxed);
                                }
                                break;
                            }
                            Err(SubmitError::Overloaded(q)) => {
                                counters.rejected.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                                query = q;
                            }
                            Err(_) => {
                                // Invalid / shutting down: a workload
                                // bug, not load — stop the run instead
                                // of spinning.
                                counters.failed.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                }
                hist.lock().unwrap().merge(&local);
            });
        }
    });
    let elapsed_s = start.elapsed().as_secs_f64();
    let served = served.load(Ordering::Relaxed);
    LoadReport {
        issued: counters.issued.load(Ordering::Relaxed).min(spec.queries as u64),
        served,
        rejected: counters.rejected.load(Ordering::Relaxed),
        cached: counters.cached.load(Ordering::Relaxed),
        mutations: counters.mutations.load(Ordering::Relaxed),
        elapsed_s,
        qps: if elapsed_s > 0.0 { served as f64 / elapsed_s } else { 0.0 },
        hist: hist.into_inner().unwrap(),
    }
}

fn run_open(server: &QueryServer, n: usize, spec: &LoadSpec, qps: f64) -> LoadReport {
    assert!(qps > 0.0, "open-loop load needs qps > 0");
    let counters = DriverCounters::new();
    let mutate_rng = Mutex::new(SplitMix64::new(spec.seed ^ 0xDE1A));
    let hist = Mutex::new(LatencyHistogram::new());
    let served = AtomicU64::new(0);
    let start = Instant::now();
    std::thread::scope(|s| {
        let mut rng = SplitMix64::new(spec.seed);
        let mut clock = Duration::ZERO;
        for i in 0..spec.queries {
            // Exponential interarrival: -ln(U)/λ (U nudged off 0).
            let u = rng.next_f64().max(1e-12);
            clock += Duration::from_secs_f64(-u.ln() / qps);
            if let Some(sleep) = clock.checked_sub(start.elapsed()) {
                std::thread::sleep(sleep);
            }
            counters.issued.fetch_add(1, Ordering::Relaxed);
            maybe_mutate(server, spec, &counters, i as u64, &mutate_rng);
            let query = next_query(&mut rng, n, spec.ppr_frac);
            match server.submit(query) {
                Ok(ticket) => {
                    let (counters, hist, served) = (&counters, &hist, &served);
                    s.spawn(move || {
                        let res = ticket.wait();
                        hist.lock().unwrap().record_secs(res.latency_s);
                        served.fetch_add(1, Ordering::Relaxed);
                        if res.cached {
                            counters.cached.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
                // Open loop: an overloaded submit is a drop, by design.
                Err(SubmitError::Overloaded(_)) => {
                    counters.rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => break,
            }
        }
    });
    let elapsed_s = start.elapsed().as_secs_f64();
    let served = served.load(Ordering::Relaxed);
    LoadReport {
        issued: counters.issued.load(Ordering::Relaxed),
        served,
        rejected: counters.rejected.load(Ordering::Relaxed),
        cached: counters.cached.load(Ordering::Relaxed),
        mutations: counters.mutations.load(Ordering::Relaxed),
        elapsed_s,
        qps: if elapsed_s > 0.0 { served as f64 / elapsed_s } else { 0.0 },
        hist: hist.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, ExecutionMode};
    use crate::graph::VersionedGraph;
    use crate::serve::server::ServeConfig;

    fn server(lanes: usize, queue: usize) -> (QueryServer, usize) {
        let csr = crate::graph::generators::uniform::generate(7, 4, 5);
        let weighted = crate::graph::weights::assign_uniform(&csr, 5);
        let n = weighted.num_vertices();
        let ecfg = EngineConfig::new(2, ExecutionMode::Asynchronous);
        let mut cfg = ServeConfig::new(lanes, ecfg);
        cfg.queue_capacity = queue;
        (QueryServer::start(VersionedGraph::new(weighted), cfg), n)
    }

    #[test]
    fn closed_loop_serves_every_query() {
        let (server, n) = server(4, 16);
        let report = run(&server, n, &LoadSpec::closed(4, 24, 9));
        assert_eq!(report.issued, 24);
        assert_eq!(report.served, 24, "closed loop retries until served");
        assert_eq!(report.hist.count(), 24);
        assert!(report.qps > 0.0);
        server.shutdown();
    }

    #[test]
    fn closed_loop_with_mutations_applies_batches() {
        let (server, n) = server(2, 16);
        let spec = LoadSpec::closed(2, 16, 3).with_mutations(4, 0.02);
        let report = run(&server, n, &spec);
        assert_eq!(report.served, 16);
        assert!(report.mutations >= 2, "mutator fired: {}", report.mutations);
        let stats = server.shutdown();
        assert!(stats.version.0 >= report.mutations, "each batch bumped the version");
    }

    #[test]
    fn open_loop_counts_drops_instead_of_retrying() {
        // 1-lane server with a tiny queue under a fast open loop: some
        // submits must drop, and issued = served + rejected.
        let (server, n) = server(1, 1);
        let report = run(&server, n, &LoadSpec::open(2000.0, 40, 11));
        assert_eq!(report.issued, 40);
        assert_eq!(report.served + report.rejected, 40);
        assert_eq!(report.hist.count(), report.served);
        server.shutdown();
    }

    #[test]
    fn report_json_is_well_formed() {
        let (server, n) = server(2, 8);
        let report = run(&server, n, &LoadSpec::closed(2, 8, 1));
        let s = report.to_json().to_string();
        assert!(s.contains("\"served\":8"), "{s}");
        assert!(s.contains("\"latency\":{"), "{s}");
        server.shutdown();
    }
}
