//! Version-keyed result cache.
//!
//! Keys are [`QueryKey`]s — `(algorithm, source/teleport-set,
//! GraphVersion)` — so a lookup at the *current* graph version can
//! never return an answer computed against a mutated-away graph:
//! correctness is in the key, not in invalidation timing. Invalidation
//! ([`ResultCache::invalidate_older_than`]) is still run after every
//! [`crate::graph::VersionedGraph::apply_batch`], but for memory, not
//! correctness — entries at superseded versions can never hit again,
//! so they are garbage the moment the version bumps (including the
//! compaction case: a batch that compacts the overlay back into a
//! fresh CSR purges every pre-compaction entry like any other bump).
//!
//! Capacity is bounded with FIFO eviction (oldest insert first): a
//! serving cache's job is absorbing *repeat* traffic between
//! mutations, and between invalidation sweeps FIFO ≈ LRU at a fraction
//! of the bookkeeping.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use super::query::{QueryKey, QueryOutput};
use crate::graph::GraphVersion;

/// Hit/miss/eviction counters (monotone since server start).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to the engine.
    pub misses: u64,
    /// Entries dropped by capacity eviction.
    pub evictions: u64,
    /// Entries dropped by version invalidation.
    pub invalidated: u64,
}

/// Bounded, version-keyed answer cache (see module docs).
#[derive(Debug)]
pub struct ResultCache {
    capacity: usize,
    map: HashMap<QueryKey, Arc<QueryOutput>>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<QueryKey>,
    stats: CacheStats,
}

impl ResultCache {
    /// Cache holding at most `capacity` answers (`0` disables caching:
    /// every lookup misses, every insert is dropped).
    pub fn new(capacity: usize) -> Self {
        Self { capacity, map: HashMap::new(), order: VecDeque::new(), stats: CacheStats::default() }
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Look up an answer, counting the hit or miss.
    pub fn get(&mut self, key: &QueryKey) -> Option<Arc<QueryOutput>> {
        match self.map.get(key) {
            Some(v) => {
                self.stats.hits += 1;
                Some(Arc::clone(v))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert an answer, evicting the oldest entries past capacity.
    /// Re-inserting a present key refreshes the value without growing
    /// the cache.
    pub fn insert(&mut self, key: QueryKey, value: Arc<QueryOutput>) {
        if self.capacity == 0 {
            return;
        }
        if self.map.insert(key.clone(), value).is_none() {
            self.order.push_back(key);
        }
        while self.map.len() > self.capacity {
            let oldest = self.order.pop_front().expect("order tracks every resident key");
            if self.map.remove(&oldest).is_some() {
                self.stats.evictions += 1;
            }
        }
    }

    /// Drop every entry whose version is older than `version`,
    /// returning how many were dropped. Run after each applied
    /// mutation batch (compactions included): superseded entries can
    /// never hit again, so no stale entry survives to occupy capacity.
    pub fn invalidate_older_than(&mut self, version: GraphVersion) -> usize {
        let before = self.map.len();
        self.map.retain(|k, _| k.version >= version);
        self.order.retain(|k| k.version >= version);
        let dropped = before - self.map.len();
        self.stats.invalidated += dropped as u64;
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::query::Query;

    fn key(src: u32, v: u64) -> QueryKey {
        Query::Sssp { source: src }.key(GraphVersion(v))
    }

    fn val(d: u32) -> Arc<QueryOutput> {
        Arc::new(QueryOutput::Distances(vec![d]))
    }

    #[test]
    fn hit_on_repeat_miss_after_version_bump() {
        let mut c = ResultCache::new(8);
        assert!(c.get(&key(1, 0)).is_none(), "cold cache misses");
        c.insert(key(1, 0), val(7));
        let got = c.get(&key(1, 0)).expect("repeat query hits");
        assert_eq!(*got, QueryOutput::Distances(vec![7]));
        // Same query at the next version is a different key: miss.
        assert!(c.get(&key(1, 1)).is_none());
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 3, evictions: 0, invalidated: 0 });
    }

    #[test]
    fn invalidation_drops_only_older_versions() {
        let mut c = ResultCache::new(8);
        c.insert(key(1, 0), val(1));
        c.insert(key(2, 0), val(2));
        c.insert(key(3, 1), val(3));
        assert_eq!(c.invalidate_older_than(GraphVersion(1)), 2);
        assert_eq!(c.len(), 1);
        assert!(c.get(&key(3, 1)).is_some());
        assert!(c.get(&key(1, 0)).is_none(), "no stale entry survives");
        assert_eq!(c.stats().invalidated, 2);
    }

    #[test]
    fn fifo_eviction_respects_capacity() {
        let mut c = ResultCache::new(2);
        c.insert(key(1, 0), val(1));
        c.insert(key(2, 0), val(2));
        c.insert(key(3, 0), val(3));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1, 0)).is_none(), "oldest insert evicted first");
        assert!(c.get(&key(2, 0)).is_some() && c.get(&key(3, 0)).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn reinsert_refreshes_without_growth() {
        let mut c = ResultCache::new(2);
        c.insert(key(1, 0), val(1));
        c.insert(key(1, 0), val(9));
        assert_eq!(c.len(), 1);
        assert_eq!(*c.get(&key(1, 0)).unwrap(), QueryOutput::Distances(vec![9]));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = ResultCache::new(0);
        c.insert(key(1, 0), val(1));
        assert!(c.is_empty());
        assert!(c.get(&key(1, 0)).is_none());
    }
}
