//! Log-bucketed latency histograms with mergeable percentile queries.
//!
//! The hdrhistogram-style layout the noria benchmark drivers report
//! tail latency with (SNIPPETS.md Snippet 3), built in-tree because no
//! crates are available offline: values are u64 *ticks* (the serve
//! path records nanoseconds) bucketed as a power-of-two major bucket ×
//! [`SUB_BUCKETS`] linear sub-buckets, giving ≤ 1/16 (6.25%) relative
//! error at any magnitude for a few KiB of counts — small enough that
//! every worker keeps its own histogram and the collector
//! [`LatencyHistogram::merge`]s them, no locks on the record path.
//!
//! Percentile semantics: [`LatencyHistogram::percentile`]`(q)` returns
//! the smallest bucket upper bound `v` such that at least
//! `ceil(q · count)` recorded samples are `<= v` — an upper bound, so
//! "p99 = v" never understates the tail. Values below
//! [`SUB_BUCKETS`] land in exact singleton buckets, which the
//! hand-computed fixtures in the tests rely on.

use crate::util::json::Json;

/// Linear sub-buckets per power-of-two major bucket (resolution
/// 1/SUB_BUCKETS). Values `< SUB_BUCKETS` get exact singleton buckets.
pub const SUB_BUCKETS: u64 = 16;

/// log2(SUB_BUCKETS).
const SUB_BITS: u32 = SUB_BUCKETS.trailing_zeros();

/// Bucket count covering all of u64: majors 4..=63 contribute 16 subs
/// each on top of the 16 exact low buckets.
const BUCKETS: usize = (SUB_BUCKETS + (64 - SUB_BITS as u64) * SUB_BUCKETS) as usize;

/// Index of the bucket holding `v`.
fn bucket_of(v: u64) -> usize {
    if v < SUB_BUCKETS {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS;
    let sub = (v >> shift) & (SUB_BUCKETS - 1);
    ((msb - SUB_BITS + 1) as u64 * SUB_BUCKETS + sub) as usize
}

/// Largest value bucket `i` can hold (the value [`percentile`]
/// reports for it).
///
/// [`percentile`]: LatencyHistogram::percentile
fn bucket_upper(i: usize) -> u64 {
    let i = i as u64;
    if i < SUB_BUCKETS {
        return i;
    }
    let major = i / SUB_BUCKETS - 1 + SUB_BITS as u64;
    let sub = i % SUB_BUCKETS;
    let shift = (major - SUB_BITS as u64) as u32;
    // The topmost bucket's exclusive upper bound is 2^64, which the
    // shift wraps to 0; wrapping_sub turns that into u64::MAX — the
    // correct inclusive bound — without a debug-build underflow panic.
    ((SUB_BUCKETS + sub + 1) << shift).wrapping_sub(1)
}

/// Mergeable log-bucketed histogram over u64 ticks (see module docs).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    /// Samples rejected by [`Self::record_secs`] (negative or
    /// non-finite seconds) — counted, never silently swallowed.
    dropped: u64,
    sum: f64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, dropped: 0, sum: 0.0, max: 0 }
    }

    /// Record one sample (any u64; `u64::MAX` lands in the top bucket,
    /// no overflow).
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.max = self.max.max(v);
    }

    /// Record a latency in seconds as nanosecond ticks. Negative or
    /// non-finite inputs are counted in [`Self::dropped`] instead of
    /// poisoning the buckets; absurdly large finite values saturate to
    /// the top bucket.
    pub fn record_secs(&mut self, s: f64) {
        if !s.is_finite() || s < 0.0 {
            self.dropped += 1;
            return;
        }
        let ns = s * 1e9;
        self.record(if ns >= u64::MAX as f64 { u64::MAX } else { ns as u64 });
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Samples rejected by [`Self::record_secs`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Largest recorded sample (exact, not bucket-rounded; 0 when
    /// empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of recorded samples (`NaN` when empty — the JSON emitter
    /// turns that into `null`).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Smallest bucket upper bound covering at least `ceil(q · count)`
    /// samples (`q` clamped to [0, 1]); `None` when empty.
    pub fn percentile(&self, q: f64) -> Option<u64> {
        if self.total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(bucket_upper(i));
            }
        }
        unreachable!("cumulative count reaches total")
    }

    /// [`Self::percentile`] in seconds (ticks are nanoseconds); `NaN`
    /// when empty, so the JSON emitter writes `null` instead of a
    /// made-up zero.
    pub fn percentile_secs(&self, q: f64) -> f64 {
        self.percentile(q).map_or(f64::NAN, |ns| ns as f64 / 1e9)
    }

    /// Fold another histogram into this one (per-worker histograms →
    /// one report).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.dropped += other.dropped;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Latency summary for BENCH artifacts: count, dropped, mean and
    /// p50/p90/p99/max in seconds. Non-finite values (empty histogram)
    /// serialize as `null` — [`Json::Num`]'s contract — so downstream
    /// parsers see an explicit absence, never a fake 0.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.total as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("mean_s", Json::Num(self.mean() / 1e9)),
            ("p50_s", Json::Num(self.percentile_secs(0.50))),
            ("p90_s", Json::Num(self.percentile_secs(0.90))),
            ("p99_s", Json::Num(self.percentile_secs(0.99))),
            ("max_s", Json::Num(self.max as f64 / 1e9)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_exact_below_sixteen_and_bounded_above() {
        for v in 0..SUB_BUCKETS {
            assert_eq!(bucket_upper(bucket_of(v)), v, "singleton bucket for {v}");
        }
        for v in [16u64, 100, 1000, 1 << 20, u64::MAX / 3, u64::MAX] {
            let up = bucket_upper(bucket_of(v));
            assert!(up >= v, "{v}: upper {up}");
            assert!(up as f64 <= v as f64 * (1.0 + 1.0 / SUB_BUCKETS as f64), "{v}: upper {up} too loose");
        }
        // Bucket uppers are strictly increasing (percentile walk is
        // well-ordered).
        for i in 1..BUCKETS {
            assert!(bucket_upper(i) > bucket_upper(i - 1), "bucket {i}");
        }
    }

    #[test]
    fn percentiles_match_hand_computed_fixtures() {
        // 16 samples, values 0..=15 (all in exact buckets): rank(q) =
        // ceil(16q), so p50 -> rank 8 -> value 7, p100 -> 15.
        let mut h = LatencyHistogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), Some(0));
        assert_eq!(h.percentile(0.5), Some(7));
        assert_eq!(h.percentile(0.75), Some(11));
        assert_eq!(h.percentile(1.0), Some(15));
        assert_eq!(h.count(), 16);
        assert_eq!(h.max(), 15);
        assert!((h.mean() - 7.5).abs() < 1e-12);

        // Tail fixture: [5, 5, 5, 1000]. p50 = 5 exactly; p99 falls in
        // 1000's bucket [992, 1024) whose upper bound is 1023.
        let mut t = LatencyHistogram::new();
        for v in [5u64, 5, 5, 1000] {
            t.record(v);
        }
        assert_eq!(t.percentile(0.5), Some(5));
        assert_eq!(t.percentile(0.99), Some(1023));
        assert_eq!(t.max(), 1000, "max is exact, not bucket-rounded");
    }

    #[test]
    fn merge_equals_combined_recording() {
        let vals: Vec<u64> = (0..500u64).map(|i| i * i % 10_007).collect();
        let mut whole = LatencyHistogram::new();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for (i, &v) in vals.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.max(), whole.max());
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(q), whole.percentile(q), "q={q}");
        }
    }

    #[test]
    fn non_finite_and_negative_seconds_are_dropped_not_recorded() {
        let mut h = LatencyHistogram::new();
        h.record_secs(f64::NAN);
        h.record_secs(f64::INFINITY);
        h.record_secs(f64::NEG_INFINITY);
        h.record_secs(-1.0);
        assert_eq!((h.count(), h.dropped()), (0, 4));
        h.record_secs(1e-6);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(1.0), Some(bucket_upper(bucket_of(1000))));
        // Overflow: huge finite seconds saturate into the top bucket.
        h.record_secs(1e300);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(1.0), Some(bucket_upper(bucket_of(u64::MAX))));
    }

    #[test]
    fn json_emits_null_for_empty_and_numbers_otherwise() {
        let h = LatencyHistogram::new();
        let s = h.to_json().to_string();
        assert!(s.contains("\"p50_s\":null"), "{s}");
        assert!(s.contains("\"mean_s\":null"), "{s}");
        assert!(s.contains("\"count\":0"), "{s}");

        let mut h = LatencyHistogram::new();
        h.record_secs(0.001);
        h.record_secs(f64::NAN);
        let s = h.to_json().to_string();
        assert!(s.contains("\"count\":1") && s.contains("\"dropped\":1"), "{s}");
        assert!(!s.contains("\"p50_s\":null"), "{s}");
        assert!(!s.contains("\"p99_s\":null"), "{s}");
    }
}
