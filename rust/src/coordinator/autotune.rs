//! Automatic δ selection — the paper's §V future work made concrete.
//!
//! "Further work must be done to determine what buffer size to use,
//! dependent on both the graph's topology and the number of threads on
//! the system." (§V) — and §IV-C notes the topology analysis "can be
//! precomputed, giving a potential way to determine when to buffer in
//! practice."
//!
//! The rule implemented here distills the paper's findings plus our
//! measurements (EXPERIMENTS.md Figs 2–4, 6):
//!
//! 1. **Diagonal locality gate** (§IV-C): if the fraction of edges
//!    internal to their partition block exceeds ~0.5 (Web-like), threads
//!    mostly consume their own updates and buffering cannot relieve
//!    contention → run asynchronous.
//! 2. **Sparse-update gate** (§IV-D): algorithms where few vertices
//!    change per round (SSSP/BFS/CC) make every update precious → use
//!    the smallest line-aligned buffer, or async on high-diameter
//!    graphs (Road) where information flow is already slow.
//! 3. **δ ∝ per-thread range** (Figs 3–4): dense-update workloads want a
//!    δ that shrinks as thread count grows; half the per-thread range,
//!    snapped to a power of two in the paper's sweep [16, 32768],
//!    brackets the measured best-δ trajectory (2048 → 256 from 7 to 112
//!    threads on kron@14).
//!
//! Validation: `daig experiment autotune` reports the regret of the rule
//! against an exhaustive sweep — 0% on every gated workload (road, web,
//! urand-SSSP), and the recommendation matches or beats plain
//! asynchronous execution on 8 of 10 suite workloads.

use crate::engine::controller;
use crate::engine::ExecutionMode;
use crate::graph::{properties, Csr};
use crate::partition::blocked;

use super::Algo;

/// Topology threshold above which buffering is predicted useless (Web
/// measures ~0.88, all buffer-friendly graphs < 0.05; the gate sits far
/// from both). Shared with the online adaptive controller
/// ([`crate::engine::controller`]), which seeds from this same rule.
pub const LOCALITY_GATE: f64 = controller::LOCALITY_GATE;

/// Diameter threshold for the Road-like "already slow information flow"
/// case (§IV-D).
pub const DIAMETER_GATE: usize = 64;

/// A δ recommendation with its reasoning (surfaced in the CLI).
#[derive(Debug, Clone, PartialEq)]
pub struct Recommendation {
    pub mode: ExecutionMode,
    /// Measured diagonal locality that drove the decision.
    pub locality: f64,
    /// Human-readable justification.
    pub reason: String,
}

/// Whether an algorithm updates most vertices every round (PageRank) or
/// only a frontier (SSSP/BFS/CC) — the §IV-D distinction.
pub fn dense_updates(algo: Algo) -> bool {
    matches!(algo, Algo::PageRank)
}

/// Recommend an execution mode for `algo` on `g` with `threads` threads.
pub fn recommend(g: &Csr, algo: Algo, threads: usize) -> Recommendation {
    let locality = properties::diagonal_locality(g, threads.max(2));
    if locality > LOCALITY_GATE {
        return Recommendation {
            mode: ExecutionMode::Asynchronous,
            locality,
            reason: format!(
                "diagonal locality {locality:.2} > {LOCALITY_GATE}: threads consume their own \
                 updates (web-like); buffering cannot relieve contention (§IV-C)"
            ),
        };
    }
    if !dense_updates(algo) {
        let diam = properties::effective_diameter(g, 4, 0xA070);
        if diam > DIAMETER_GATE {
            return Recommendation {
                mode: ExecutionMode::Asynchronous,
                locality,
                reason: format!(
                    "sparse updates + effective diameter {diam} > {DIAMETER_GATE}: information \
                     flow is already slow (road-like); delaying hurts (§IV-D)"
                ),
            };
        }
        return Recommendation {
            mode: ExecutionMode::Delayed(16),
            locality,
            reason: "sparse updates: every update matters, use the minimum line-aligned buffer (§IV-D)".into(),
        };
    }
    // Dense updates: δ ≈ the per-thread range, snapped to the paper's
    // power-of-two sweep and clamped to [16, 32768]. The measured best-δ
    // trajectory (EXPERIMENTS.md Fig 4: 2048→512→512→256→256 for ranges
    // ≈2340→146) brackets range/2 — buffer about half a block's worth,
    // publishing once or twice per round, which shrinks automatically as
    // thread count grows (the paper's Figs 3–4 trend). The formula lives
    // in `engine::controller` so the online adaptive mode seeds from the
    // identical rule.
    let range = blocked::partition(g, threads).max_len();
    let delta = controller::dense_rule_delta(range);
    Recommendation {
        mode: ExecutionMode::Delayed(delta),
        locality,
        reason: format!(
            "dense updates, locality {locality:.2}, per-thread range {range}: δ ≈ range/2 \
             snapped to 2^k (Figs 3–4 trajectory)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;

    #[test]
    fn web_gets_async() {
        let g = GapGraph::Web.generate(11, 0);
        let r = recommend(&g, Algo::PageRank, 32);
        assert_eq!(r.mode, ExecutionMode::Asynchronous);
        assert!(r.locality > LOCALITY_GATE);
    }

    #[test]
    fn kron_pagerank_gets_buffer_shrinking_with_threads() {
        let g = GapGraph::Kron.generate(13, 0);
        let low = recommend(&g, Algo::PageRank, 8);
        let high = recommend(&g, Algo::PageRank, 112);
        let (ExecutionMode::Delayed(d_low), ExecutionMode::Delayed(d_high)) = (low.mode, high.mode) else {
            panic!("expected Delayed for kron PR: {low:?} {high:?}");
        };
        assert!(d_low > d_high, "δ must shrink with threads: {d_low} vs {d_high}");
        assert!(d_low >= 16 && d_high >= 16);
    }

    #[test]
    fn road_sssp_gets_async() {
        // Scale 13+ so the grid's effective diameter clears the gate
        // (experiments run at scale 14).
        let g = GapGraph::Road.generate(13, 0);
        let r = recommend(&g, Algo::Sssp, 112);
        assert_eq!(r.mode, ExecutionMode::Asynchronous, "{}", r.reason);
    }

    #[test]
    fn kron_sssp_gets_minimal_buffer() {
        let g = GapGraph::Kron.generate(11, 0);
        let r = recommend(&g, Algo::Sssp, 32);
        assert_eq!(r.mode, ExecutionMode::Delayed(16));
    }

    #[test]
    fn offline_rule_and_controller_seed_agree() {
        // The adaptive controller must start exactly where the offline
        // rule would have pointed (single source of truth).
        let g = GapGraph::Urand.generate(12, 0);
        let threads = 16;
        let rec = recommend(&g, Algo::PageRank, threads);
        let ExecutionMode::Delayed(d) = rec.mode else {
            panic!("urand PR should buffer: {rec:?}");
        };
        let range = blocked::partition(&g, threads).max_len();
        assert_eq!(d, controller::dense_rule_delta(range));
        assert_eq!(controller::seed_delta(rec.locality, range, 1 << 20), d, "controller seeds from the same rule");
        // And the §IV-C gate sends both to asynchronous together.
        assert_eq!(controller::seed_delta(LOCALITY_GATE + 0.1, range, 1 << 20), 0);
    }

    #[test]
    fn deltas_are_line_multiples() {
        for scale in [10u32, 12, 14] {
            let g = GapGraph::Urand.generate(scale, 0);
            for t in [4usize, 16, 64] {
                if let ExecutionMode::Delayed(d) = recommend(&g, Algo::PageRank, t).mode {
                    assert_eq!(d % crate::VALUES_PER_LINE, 0, "δ={d}");
                }
            }
        }
    }
}
