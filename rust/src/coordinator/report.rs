//! Result emission: console text + `results/<id>.csv` + `results/<id>.md`.

use std::fs;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::table::Table;

/// Sink for experiment tables.
pub struct Report {
    /// Output directory; `None` = console only.
    dir: Option<PathBuf>,
    /// Quiet mode suppresses console output (tests).
    quiet: bool,
}

impl Report {
    /// Report into `dir` (created if missing).
    pub fn to_dir(dir: &str) -> Result<Self> {
        fs::create_dir_all(dir).with_context(|| format!("create {dir}"))?;
        Ok(Self { dir: Some(PathBuf::from(dir)), quiet: false })
    }

    /// Console-only report.
    pub fn console() -> Self {
        Self { dir: None, quiet: false }
    }

    /// Silent report (integration tests).
    pub fn sink() -> Self {
        Self { dir: None, quiet: true }
    }

    /// Quiet file report.
    pub fn quiet_dir(dir: &str) -> Result<Self> {
        let mut r = Self::to_dir(dir)?;
        r.quiet = true;
        Ok(r)
    }

    /// Emit one table under an artifact id (e.g. "table1", "fig2").
    pub fn emit(&self, id: &str, t: &Table) -> Result<()> {
        if !self.quiet {
            println!("{}", t.to_text());
        }
        if let Some(dir) = &self.dir {
            fs::write(dir.join(format!("{id}.csv")), t.to_csv())?;
            fs::write(dir.join(format!("{id}.md")), t.to_markdown())?;
        }
        Ok(())
    }

    /// Emit free-form notes alongside an artifact.
    pub fn note(&self, id: &str, text: &str) -> Result<()> {
        if !self.quiet {
            println!("{text}");
        }
        if let Some(dir) = &self.dir {
            fs::write(dir.join(format!("{id}.txt")), text)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("daig-report-test");
        let _ = std::fs::remove_dir_all(&dir);
        let r = Report::quiet_dir(dir.to_str().unwrap()).unwrap();
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]);
        r.emit("table9", &t).unwrap();
        r.note("table9", "hello").unwrap();
        assert!(dir.join("table9.csv").exists());
        assert!(dir.join("table9.md").exists());
        assert!(dir.join("table9.txt").exists());
    }

    #[test]
    fn sink_swallows() {
        let r = Report::sink();
        let t = Table::new("t", &["a"]);
        r.emit("x", &t).unwrap();
    }
}
