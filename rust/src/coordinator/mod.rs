//! Experiment orchestration: everything needed to regenerate the paper's
//! tables and figures from the command line.
//!
//! [`Workload`] names an (algorithm, graph) pair at a scale;
//! [`sweep`] runs mode/δ/thread grids on the simulator; [`experiments`]
//! maps each paper artifact (Table I … Fig. 6) to a driver; [`report`]
//! renders the results as aligned text, CSV, and markdown.

pub mod autotune;
pub mod experiments;
pub mod report;
pub mod sweep;

use anyhow::{bail, Result};

use crate::algorithms::{bfs, cc, pagerank, sssp};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{EngineConfig, RunResult};
use crate::graph::gap::GapGraph;
use crate::graph::{Csr, GraphStore};

/// The iterative algorithms the coordinator can drive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    PageRank,
    Sssp,
    Cc,
    Bfs,
}

impl Algo {
    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Algo::PageRank => "pagerank",
            Algo::Sssp => "sssp",
            Algo::Cc => "cc",
            Algo::Bfs => "bfs",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pagerank" | "pr" => Some(Algo::PageRank),
            "sssp" | "bf" => Some(Algo::Sssp),
            "cc" => Some(Algo::Cc),
            "bfs" => Some(Algo::Bfs),
            _ => None,
        }
    }

    /// Whether the algorithm needs edge weights.
    pub fn weighted(self) -> bool {
        matches!(self, Algo::Sssp)
    }
}

/// A named workload: algorithm × GAP-analog graph × scale.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub algo: Algo,
    pub graph: GapGraph,
    /// log2 of the vertex count target.
    pub scale: u32,
    /// Edges per vertex (ignored by Road).
    pub edge_factor: usize,
}

impl Workload {
    /// Generate the graph (weighted iff the algorithm requires it).
    pub fn build_graph(&self) -> Csr {
        if self.algo.weighted() {
            self.graph.generate_weighted(self.scale, self.edge_factor)
        } else {
            self.graph.generate(self.scale, self.edge_factor)
        }
    }
}

/// Run a workload on the simulator; returns the run and its metrics.
/// Generic over [`GraphStore`], so overlays sweep through unchanged.
pub fn run_sim<G: GraphStore>(g: &G, algo: Algo, ecfg: &EngineConfig, machine: &Machine) -> SimRun {
    match algo {
        Algo::PageRank => pagerank::run_sim(g, ecfg, &pagerank::PrConfig::default(), machine).1,
        Algo::Sssp => sssp::run_sim(g, sssp::default_source(g), ecfg, machine).1,
        Algo::Cc => cc::run_sim(g, ecfg, machine).1,
        Algo::Bfs => bfs::run_sim(g, sssp::default_source(g), ecfg, machine).1,
    }
}

/// Run a workload on the native threaded engine.
pub fn run_native<G: GraphStore>(g: &G, algo: Algo, ecfg: &EngineConfig) -> RunResult {
    match algo {
        Algo::PageRank => pagerank::run_native(g, ecfg, &pagerank::PrConfig::default()).run,
        Algo::Sssp => sssp::run_native(g, sssp::default_source(g), ecfg).run,
        Algo::Cc => cc::run_native(g, ecfg).run,
        Algo::Bfs => bfs::run_native(g, sssp::default_source(g), ecfg).run,
    }
}

/// Parse a machine preset name.
pub fn machine_from_name(s: &str) -> Result<Machine> {
    match s.to_ascii_lowercase().as_str() {
        "haswell" | "haswell32" => Ok(Machine::haswell()),
        "cascadelake" | "cascadelake112" | "clx" => Ok(Machine::cascade_lake()),
        other => bail!("unknown machine '{other}' (haswell | cascadelake)"),
    }
}

/// The paper's δ sweep: powers of two, 16 … 32768 elements (§IV), capped
/// at `max` (δ beyond the per-thread range behaves as synchronous).
pub fn delta_sweep(max: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 16usize;
    while d <= 32_768 && d <= max {
        out.push(d);
        d *= 2;
    }
    if out.is_empty() {
        out.push(16);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names() {
        for a in [Algo::PageRank, Algo::Sssp, Algo::Cc, Algo::Bfs] {
            assert_eq!(Algo::from_name(a.name()), Some(a));
        }
        assert_eq!(Algo::from_name("pr"), Some(Algo::PageRank));
        assert!(Algo::from_name("x").is_none());
    }

    #[test]
    fn workload_builds_weighted_for_sssp() {
        let w = Workload { algo: Algo::Sssp, graph: GapGraph::Kron, scale: 7, edge_factor: 4 };
        assert!(w.build_graph().is_weighted());
        let w = Workload { algo: Algo::PageRank, ..w };
        assert!(!w.build_graph().is_weighted());
    }

    #[test]
    fn delta_sweep_shape() {
        assert_eq!(delta_sweep(100), vec![16, 32, 64]);
        assert_eq!(delta_sweep(8), vec![16]); // never empty
        assert!(delta_sweep(1 << 20).contains(&32_768));
    }

    #[test]
    fn machines_parse() {
        assert_eq!(machine_from_name("haswell").unwrap().threads, 32);
        assert_eq!(machine_from_name("clx").unwrap().threads, 112);
        assert!(machine_from_name("zen").is_err());
    }
}
