//! Mode/δ/thread/schedule sweeps on the simulator — the inner loop of
//! every figure driver.

use crate::algorithms::{pagerank, sssp};
use crate::engine::sim::cost::Machine;
use crate::engine::sim::SimRun;
use crate::engine::{EngineConfig, ExecutionMode, SchedulePolicy};
use crate::graph::{Csr, GraphStore, VersionedGraph, VertexId};
use crate::partition::blocked;

use super::{delta_sweep, run_sim, Algo};

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub mode: ExecutionMode,
    /// Which vertices each round swept.
    pub schedule: SchedulePolicy,
    pub rounds: usize,
    /// Total simulated seconds.
    pub time_s: f64,
    /// Average simulated seconds per round (Table I column).
    pub avg_round_s: f64,
    pub invalidations: u64,
    pub flushes: u64,
    /// Total vertex updates across all rounds (dense = rounds × n).
    pub active_total: u64,
    /// Chunks executed away from their owner (zero without stealing).
    pub steals: u64,
    /// Median final-round δ under [`ExecutionMode::Adaptive`] (`None`
    /// for static modes).
    pub final_delta: Option<usize>,
}

/// Sweep sync + async + the paper's δ grid at a fixed thread count,
/// dense-scheduled (the paper's configuration).
pub fn modes<G: GraphStore>(g: &G, algo: Algo, threads: usize, machine: &Machine) -> Vec<SweepPoint> {
    modes_scheduled(g, algo, threads, machine, SchedulePolicy::Dense)
}

/// Mode sweep under an explicit schedule policy.
pub fn modes_scheduled<G: GraphStore>(
    g: &G,
    algo: Algo,
    threads: usize,
    machine: &Machine,
    schedule: SchedulePolicy,
) -> Vec<SweepPoint> {
    modes_base(g, algo, machine, &EngineConfig::new(threads, ExecutionMode::Synchronous).with_schedule(schedule))
}

/// Mode sweep preserving every non-mode dimension of `base` (schedule,
/// stealing, partitioner, thread count).
pub fn modes_base<G: GraphStore>(g: &G, algo: Algo, machine: &Machine, base: &EngineConfig) -> Vec<SweepPoint> {
    let max_range = blocked::partition(g, base.threads).max_len();
    let mut list = vec![ExecutionMode::Synchronous, ExecutionMode::Asynchronous];
    list.extend(delta_sweep(max_range).into_iter().map(ExecutionMode::Delayed));
    list.into_iter()
        .map(|mode| {
            let mut c = base.clone();
            c.mode = mode;
            point_config(g, algo, machine, &c)
        })
        .collect()
}

/// Sweep all three schedule policies at one fixed execution mode.
pub fn schedules<G: GraphStore>(
    g: &G,
    algo: Algo,
    threads: usize,
    machine: &Machine,
    mode: ExecutionMode,
) -> Vec<SweepPoint> {
    SchedulePolicy::ALL.iter().map(|&s| point_scheduled(g, algo, threads, machine, mode, s)).collect()
}

/// Run one configuration (dense schedule).
pub fn point<G: GraphStore>(g: &G, algo: Algo, threads: usize, machine: &Machine, mode: ExecutionMode) -> SweepPoint {
    point_scheduled(g, algo, threads, machine, mode, SchedulePolicy::Dense)
}

/// Run one fully specified configuration.
pub fn point_scheduled<G: GraphStore>(
    g: &G,
    algo: Algo,
    threads: usize,
    machine: &Machine,
    mode: ExecutionMode,
    schedule: SchedulePolicy,
) -> SweepPoint {
    point_config(g, algo, machine, &EngineConfig::new(threads, mode).with_schedule(schedule))
}

/// Run one explicit engine configuration.
pub fn point_config<G: GraphStore>(g: &G, algo: Algo, machine: &Machine, ecfg: &EngineConfig) -> SweepPoint {
    let sim = run_sim(g, algo, ecfg, machine);
    SweepPoint {
        mode: ecfg.mode,
        schedule: ecfg.schedule,
        rounds: sim.result.num_rounds(),
        time_s: sim.result.total_time(),
        avg_round_s: sim.result.avg_round_time(),
        invalidations: sim.metrics.invalidations,
        flushes: sim.result.total_flushes(),
        active_total: sim.result.total_active(),
        steals: sim.result.total_steals(),
        final_delta: sim.result.final_delta_median(),
    }
}

/// Online-vs-offline δ: run [`ExecutionMode::Adaptive`] under `base`,
/// then the full static mode sweep (sync + async + the δ grid) under the
/// same base, and report `(adaptive, best_static, regret)` where
/// `best_static` is the fastest static point of the whole sweep — the
/// choices an oracle with perfect offline knowledge picks among — and
/// `regret = adaptive.time_s / best_static.time_s − 1` (≤ 0 means the
/// controller beat every static choice).
pub fn adaptive_regret<G: GraphStore>(
    g: &G,
    algo: Algo,
    machine: &Machine,
    base: &EngineConfig,
) -> (SweepPoint, SweepPoint, f64) {
    let mut acfg = base.clone();
    acfg.mode = ExecutionMode::Adaptive;
    let adaptive = point_config(g, algo, machine, &acfg);
    let statics = modes_base(g, algo, machine, base);
    let best = statics
        .into_iter()
        .min_by(|a, b| a.time_s.partial_cmp(&b.time_s).unwrap())
        .expect("modes_base always yields points");
    let regret = adaptive.time_s / best.time_s - 1.0;
    (adaptive, best, regret)
}

/// One point of a batched multi-query throughput sweep
/// ([`batch_throughput`]).
#[derive(Debug, Clone)]
pub struct BatchPoint {
    /// Queries batched into the run (lane count).
    pub k: usize,
    pub mode: ExecutionMode,
    pub schedule: SchedulePolicy,
    pub stealing: bool,
    pub rounds: usize,
    /// Total simulated seconds for all `k` queries.
    pub time_s: f64,
    /// The serving headline: `k / time_s`.
    pub queries_per_s: f64,
    pub invalidations: u64,
    pub flushes: u64,
    pub steals: u64,
}

/// Batched multi-query throughput on the simulator: run `algo`
/// (SSSP: multi-source; PageRank: multi-teleport personalized) at each
/// lane count in `ks` under `base`, reporting queries/sec. Query sets
/// are the deterministic top-degree hubs, nested so the k=1 point is a
/// prefix of every larger batch. Panics for algorithms without a
/// batched variant (CC/BFS).
pub fn batch_throughput<G: GraphStore>(
    g: &G,
    algo: Algo,
    machine: &Machine,
    base: &EngineConfig,
    ks: &[usize],
) -> Vec<BatchPoint> {
    ks.iter()
        .map(|&k| {
            let sim: SimRun = match algo {
                Algo::Sssp => {
                    let sources = sssp::default_sources(g, k);
                    sssp::run_sim_batch(g, &sources, base, machine).1
                }
                Algo::PageRank => {
                    let teleports = pagerank::default_teleports(g, k);
                    pagerank::run_sim_batch(g, &teleports, base, &pagerank::PrConfig::default(), machine).1
                }
                other => panic!("{other:?} has no batched lane variant"),
            };
            let time_s = sim.result.total_time();
            BatchPoint {
                k,
                mode: base.mode,
                schedule: base.schedule,
                stealing: base.stealing,
                rounds: sim.result.num_rounds(),
                time_s,
                queries_per_s: if time_s > 0.0 { k as f64 / time_s } else { 0.0 },
                invalidations: sim.metrics.invalidations,
                flushes: sim.result.total_flushes(),
                steals: sim.result.total_steals(),
            }
        })
        .collect()
}

/// One point of a serving-throughput sweep ([`serve_throughput`]).
#[derive(Debug, Clone)]
pub struct ServePoint {
    /// Lane-group width the server packed toward.
    pub k: usize,
    /// Queries answered (engine + cache).
    pub served: u64,
    /// Of `served`, answered from the result cache.
    pub cached: u64,
    /// Submits rejected by backpressure (closed loop retries them).
    pub rejected: u64,
    /// Wall-clock seconds for the whole load run.
    pub elapsed_s: f64,
    /// The serving headline: served / elapsed.
    pub queries_per_s: f64,
    /// Client-observed median latency, seconds.
    pub p50_s: f64,
    /// Client-observed tail latency, seconds.
    pub p99_s: f64,
}

/// Serving throughput across lane widths: for each `k` in `ks`, start a
/// [`QueryServer`](crate::serve::QueryServer) over a fresh overlay of
/// `g`, drive it closed-loop (`2k` clients, so every group can fill)
/// with `queries` mixed SSSP/PPR queries deterministic in `seed`, and
/// report wall-clock queries/sec with the p50/p99 SLO columns. `g` must
/// be weighted (the mixed stream includes SSSP). This is the native
/// wall-clock analog of [`batch_throughput`]: the simulator has no
/// always-on server, so serving numbers are real-thread numbers.
pub fn serve_throughput(g: &Csr, base: &EngineConfig, ks: &[usize], queries: usize, seed: u64) -> Vec<ServePoint> {
    use crate::serve::{loadgen, LoadSpec, QueryServer, ServeConfig};
    assert!(g.is_weighted(), "serve_throughput needs a weighted graph (the query mix includes SSSP)");
    ks.iter()
        .map(|&k| {
            let server = QueryServer::start(VersionedGraph::new(g.clone()), ServeConfig::new(k, base.clone()));
            let report = loadgen::run(&server, g.num_vertices(), &LoadSpec::closed(2 * k, queries, seed));
            server.shutdown();
            ServePoint {
                k,
                served: report.served,
                cached: report.cached,
                rejected: report.rejected,
                elapsed_s: report.elapsed_s,
                queries_per_s: report.qps,
                p50_s: report.hist.percentile_secs(0.50),
                p99_s: report.hist.percentile_secs(0.99),
            }
        })
        .collect()
}

/// One point of the sharded-serving sweep ([`shard_scaling`]): one
/// shard count × one execution mode over a loopback cluster.
#[derive(Debug, Clone)]
pub struct ShardPoint {
    /// Worker shards in the cluster.
    pub shards: usize,
    /// Execution mode — which also sets the halo message δ
    /// ([`crate::shard::halo_delta`]).
    pub mode: ExecutionMode,
    /// Jobs run to convergence.
    pub jobs: usize,
    /// Global rounds summed over the jobs.
    pub rounds: u64,
    /// Wall-clock seconds over the whole job stream.
    pub elapsed_s: f64,
    /// The sharded-serving headline: jobs / elapsed.
    pub jobs_per_s: f64,
    /// Halo messages shipped by all shards over all jobs.
    pub halo_msgs: u64,
    /// Halo entries (vertex lane groups) those messages carried.
    pub halo_entries: u64,
    /// Entries per message — the δ-amortization evidence: async (δ=0)
    /// pins this at 1, sync batches a whole round per message, delayed
    /// δ lands in between.
    pub entries_per_msg: f64,
}

/// Sharded serving over the deterministic loopback cluster
/// ([`crate::shard::with_cluster`]): for every shard count × mode, run
/// the same mixed SSSP/PPR single-query job stream (deterministic in
/// `seed`, drawn by [`crate::serve::loadgen::next_query`]) and report
/// wall-clock job throughput plus halo-traffic totals. The interesting
/// column is `entries_per_msg` — the paper's delay-buffer amortization
/// lifted to the message layer (`BENCH_shard.json` plots it). `g` must
/// be weighted (the stream includes SSSP). Like [`serve_throughput`],
/// this is native wall clock, not the simulator.
pub fn shard_scaling(
    g: &Csr,
    base: &EngineConfig,
    shard_counts: &[usize],
    modes: &[ExecutionMode],
    queries: usize,
    seed: u64,
) -> Vec<ShardPoint> {
    use crate::serve::{loadgen, Query};
    use crate::shard::{with_cluster, JobClass};
    use crate::util::rng::SplitMix64;
    assert!(g.is_weighted(), "shard_scaling needs a weighted graph (the job mix includes SSSP)");
    let mut out = Vec::new();
    for &shards in shard_counts {
        for &mode in modes {
            let mut ecfg = base.clone();
            ecfg.mode = mode;
            // Same query stream at every point: the comparison is
            // cluster shape and δ policy, never workload.
            let mut rng = SplitMix64::new(seed);
            let classes: Vec<JobClass> = (0..queries)
                .map(|_| match loadgen::next_query(&mut rng, g.num_vertices(), 0.25) {
                    Query::Sssp { source } => JobClass::Sssp { sources: vec![source] },
                    Query::Ppr { teleports } => {
                        JobClass::Ppr { teleports: vec![teleports], damping: 0.85, epsilon: 1e-3 }
                    }
                })
                .collect();
            let (rounds, msgs, entries, elapsed_s) = with_cluster(g, shards, &ecfg, |router| {
                let t0 = std::time::Instant::now();
                let (mut rounds, mut msgs, mut entries) = (0u64, 0u64, 0u64);
                for class in &classes {
                    let res = router.run_job(class).expect("loopback cluster job cannot fail");
                    rounds += u64::from(res.rounds);
                    msgs += res.halo_msgs;
                    entries += res.halo_entries;
                }
                (rounds, msgs, entries, t0.elapsed().as_secs_f64())
            });
            out.push(ShardPoint {
                shards,
                mode,
                jobs: queries,
                rounds,
                elapsed_s,
                jobs_per_s: queries as f64 / elapsed_s.max(1e-9),
                halo_msgs: msgs,
                halo_entries: entries,
                entries_per_msg: entries as f64 / (msgs as f64).max(1.0),
            });
        }
    }
    out
}

/// One cell of the [`mutation_latency`] grid: update-to-fresh-result
/// latency of incremental recomputation vs full recomputation after an
/// edge-mutation batch, at one mode × schedule.
#[derive(Debug, Clone)]
pub struct MutationPoint {
    pub mode: ExecutionMode,
    pub schedule: SchedulePolicy,
    /// Rounds / simulated seconds of the from-scratch run on the
    /// mutated graph.
    pub full_rounds: usize,
    pub full_time_s: f64,
    /// Rounds / simulated seconds of the warm-started run (previous
    /// values + dirty frontier from the algorithm's `resume_seed`).
    pub resumed_rounds: usize,
    pub resumed_time_s: f64,
    /// `full_time_s / resumed_time_s` (> 1 means incremental wins).
    pub speedup: f64,
}

/// Incremental-recomputation latency sweep (DESIGN.md §10): converge
/// `algo` on `g`, apply a random batch mutating `frac` of the edges
/// (deterministic in `seed`), then measure the mutated-graph
/// recomputation both from scratch and warm-started via the algorithm's
/// `resume_seed`, for every static mode plus the adaptive controller
/// under each schedule policy. Only SSSP and PageRank are resumable;
/// panics otherwise.
pub fn mutation_latency(
    g: &Csr,
    algo: Algo,
    threads: usize,
    machine: &Machine,
    frac: f64,
    seed: u64,
) -> Vec<MutationPoint> {
    assert!(
        matches!(algo, Algo::Sssp | Algo::PageRank),
        "mutation latency needs a resumable algorithm (sssp | pagerank), got {algo:?}"
    );
    // SSSP must keep the pre-mutation source: mutations can change which
    // vertex has the highest out-degree, and the resumed run's values
    // only make sense for the query they answer.
    let source = sssp::default_source(g);
    let mut vg = VersionedGraph::new(g.clone());
    let batch = vg.random_batch(frac, seed);
    vg.apply_batch(&batch).expect("random_batch yields a valid batch");

    fn one<G: GraphStore>(g: &G, algo: Algo, source: VertexId, ecfg: &EngineConfig, machine: &Machine) -> SimRun {
        match algo {
            Algo::Sssp => sssp::run_sim(g, source, ecfg, machine).1,
            _ => pagerank::run_sim(g, ecfg, &pagerank::PrConfig::default(), machine).1,
        }
    }

    let modes =
        [ExecutionMode::Synchronous, ExecutionMode::Asynchronous, ExecutionMode::Delayed(64), ExecutionMode::Adaptive];
    let mut out = Vec::new();
    for mode in modes {
        for &schedule in SchedulePolicy::ALL.iter() {
            let base = EngineConfig::new(threads, mode).with_schedule(schedule);
            // The state an online system holds when the batch arrives.
            let cold = one(g, algo, source, &base, machine);
            let full = one(&vg, algo, source, &base, machine);
            let rseed = match algo {
                Algo::Sssp => sssp::resume_seed(&vg, source, &cold.result, &batch),
                _ => pagerank::resume_seed(&vg, &cold.result, &batch),
            };
            let resumed = one(&vg, algo, source, &base.clone().with_resume(rseed), machine);
            let full_time_s = full.result.total_time();
            let resumed_time_s = resumed.result.total_time();
            out.push(MutationPoint {
                mode,
                schedule,
                full_rounds: full.result.num_rounds(),
                full_time_s,
                resumed_rounds: resumed.result.num_rounds(),
                resumed_time_s,
                speedup: if resumed_time_s > 0.0 { full_time_s / resumed_time_s } else { f64::INFINITY },
            });
        }
    }
    out
}

/// The straggler-recovery pair: one configuration run statically and with
/// intra-round work stealing.
pub fn steal_pair<G: GraphStore>(
    g: &G,
    algo: Algo,
    threads: usize,
    machine: &Machine,
    mode: ExecutionMode,
    schedule: SchedulePolicy,
) -> (SweepPoint, SweepPoint) {
    let base = EngineConfig::new(threads, mode).with_schedule(schedule);
    (point_config(g, algo, machine, &base), point_config(g, algo, machine, &base.clone().with_stealing()))
}

/// The best (lowest total time) delayed point of a sweep, if any.
pub fn best_delayed(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points
        .iter()
        .filter(|p| matches!(p.mode, ExecutionMode::Delayed(_)))
        .min_by(|a, b| a.time_s.partial_cmp(&b.time_s).unwrap())
}

/// The synchronous / asynchronous points of a sweep.
pub fn find_mode<'a>(points: &'a [SweepPoint], mode: ExecutionMode) -> Option<&'a SweepPoint> {
    points.iter().find(|p| p.mode == mode)
}

/// The point of a schedule sweep with the given policy.
pub fn find_schedule<'a>(points: &'a [SweepPoint], schedule: SchedulePolicy) -> Option<&'a SweepPoint> {
    points.iter().find(|p| p.schedule == schedule)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;

    #[test]
    fn sweep_covers_modes() {
        let g = GapGraph::Kron.generate(9, 8);
        let pts = modes(&g, Algo::PageRank, 8, &Machine::haswell());
        assert!(pts.len() >= 3);
        assert!(find_mode(&pts, ExecutionMode::Synchronous).is_some());
        assert!(find_mode(&pts, ExecutionMode::Asynchronous).is_some());
        let best = best_delayed(&pts).unwrap();
        assert!(matches!(best.mode, ExecutionMode::Delayed(_)));
        // All runs converged on the same algorithm => same-ish rounds.
        for p in &pts {
            assert!(p.rounds > 0 && p.time_s > 0.0);
            assert_eq!(p.schedule, SchedulePolicy::Dense);
            assert_eq!(p.active_total, p.rounds as u64 * g.num_vertices() as u64);
        }
    }

    #[test]
    fn sync_has_most_rounds() {
        let g = GapGraph::Road.generate(10, 0);
        let pts = modes(&g, Algo::PageRank, 8, &Machine::haswell());
        let sync = find_mode(&pts, ExecutionMode::Synchronous).unwrap().rounds;
        let asyn = find_mode(&pts, ExecutionMode::Asynchronous).unwrap().rounds;
        assert!(asyn <= sync, "async {asyn} vs sync {sync}");
    }

    #[test]
    fn steal_pair_reports_stealing_dimension() {
        let g = GapGraph::Kron.generate(9, 8);
        let m = Machine::haswell();
        let (st, dy) = steal_pair(&g, Algo::Cc, 8, &m, ExecutionMode::Delayed(64), SchedulePolicy::Frontier);
        assert_eq!(st.steals, 0, "static run must not steal");
        assert_eq!(st.mode, dy.mode);
        assert_eq!(st.schedule, dy.schedule);
        assert!(dy.rounds > 0 && dy.time_s > 0.0);
    }

    #[test]
    fn adaptive_regret_reports_both_points() {
        let g = GapGraph::Kron.generate(9, 8);
        let base = EngineConfig::new(8, ExecutionMode::Synchronous);
        let (ap, best, regret) = adaptive_regret(&g, Algo::PageRank, &Machine::haswell(), &base);
        assert_eq!(ap.mode, ExecutionMode::Adaptive);
        assert!(ap.final_delta.is_some(), "adaptive point carries its final δ");
        assert!(best.final_delta.is_none(), "static points carry no δ trace");
        assert!(ap.rounds > 0 && best.rounds > 0);
        assert!((ap.time_s / best.time_s - 1.0 - regret).abs() < 1e-12);
        // Determinism: the sim makes regret reproducible.
        let (ap2, _, regret2) = adaptive_regret(&g, Algo::PageRank, &Machine::haswell(), &base);
        assert_eq!(ap.time_s, ap2.time_s);
        assert_eq!(regret, regret2);
    }

    #[test]
    fn batch_throughput_scales_queries_per_second() {
        // The tentpole's acceptance shape at sweep level: delayed-mode
        // batched SSSP on kron must serve ≥2x the queries/sec at k=8
        // than at k=1 (one flushed line carries 8 queries' updates).
        let g = GapGraph::Kron.generate_weighted(9, 8);
        let base = EngineConfig::new(8, ExecutionMode::Delayed(64));
        let pts = batch_throughput(&g, Algo::Sssp, &Machine::haswell(), &base, &[1, 8]);
        assert_eq!(pts.len(), 2);
        assert_eq!((pts[0].k, pts[1].k), (1, 8));
        assert!(pts[0].rounds > 0 && pts[1].rounds > 0);
        assert!(
            pts[1].queries_per_s >= 2.0 * pts[0].queries_per_s,
            "k=8 {} q/s vs k=1 {} q/s",
            pts[1].queries_per_s,
            pts[0].queries_per_s
        );
        // PageRank batching goes through the same driver.
        let pr = batch_throughput(&g, Algo::PageRank, &Machine::haswell(), &base, &[4]);
        assert_eq!(pr[0].k, 4);
        assert!(pr[0].queries_per_s > 0.0);
    }

    #[test]
    fn serve_throughput_reports_per_k_points() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let base = EngineConfig::new(2, ExecutionMode::Asynchronous);
        let pts = serve_throughput(&g, &base, &[1, 4], 12, 7);
        assert_eq!((pts[0].k, pts[1].k), (1, 4));
        for p in &pts {
            assert_eq!(p.served, 12, "closed loop serves every query at k={}", p.k);
            assert!(p.queries_per_s > 0.0 && p.elapsed_s > 0.0);
            assert!(p.p99_s >= p.p50_s, "percentiles are monotone");
        }
    }

    #[test]
    fn mutation_latency_reports_incremental_wins() {
        let g = GapGraph::Kron.generate_weighted(8, 8);
        let pts = mutation_latency(&g, Algo::Sssp, 4, &Machine::haswell(), 0.01, 0xFACE);
        assert_eq!(pts.len(), 4 * SchedulePolicy::ALL.len());
        for p in &pts {
            assert!(p.full_rounds > 0 && p.resumed_rounds > 0, "{:?}/{:?}", p.mode, p.schedule);
            assert!(p.full_time_s > 0.0 && p.resumed_time_s > 0.0);
            assert!((p.speedup - p.full_time_s / p.resumed_time_s).abs() < 1e-12);
        }
        // Sparse-scheduled cells must show the incremental win: the warm
        // start re-sweeps only the mutation cone instead of the graph.
        let sparse_wins = pts
            .iter()
            .filter(|p| p.schedule == SchedulePolicy::Frontier)
            .all(|p| p.resumed_time_s < p.full_time_s);
        assert!(sparse_wins, "frontier-scheduled resume must beat full recompute: {pts:?}");
    }

    #[test]
    fn schedule_sweep_frontier_does_less_work() {
        let g = GapGraph::Road.generate(9, 0);
        let pts = schedules(&g, Algo::Cc, 8, &Machine::haswell(), ExecutionMode::Synchronous);
        assert_eq!(pts.len(), 3);
        let dense = find_schedule(&pts, SchedulePolicy::Dense).unwrap();
        let frontier = find_schedule(&pts, SchedulePolicy::Frontier).unwrap();
        assert!(
            frontier.active_total < dense.active_total,
            "frontier {} vs dense {}",
            frontier.active_total,
            dense.active_total
        );
        assert!(frontier.time_s < dense.time_s, "frontier {} vs dense {}", frontier.time_s, dense.time_s);
    }
}
