//! Mode/δ/thread sweeps on the simulator — the inner loop of every
//! figure driver.

use crate::engine::sim::cost::Machine;
use crate::engine::{EngineConfig, ExecutionMode};
use crate::graph::Csr;
use crate::partition::blocked;

use super::{delta_sweep, run_sim, Algo};

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub mode: ExecutionMode,
    pub rounds: usize,
    /// Total simulated seconds.
    pub time_s: f64,
    /// Average simulated seconds per round (Table I column).
    pub avg_round_s: f64,
    pub invalidations: u64,
    pub flushes: u64,
}

/// Sweep sync + async + the paper's δ grid at a fixed thread count.
pub fn modes(g: &Csr, algo: Algo, threads: usize, machine: &Machine) -> Vec<SweepPoint> {
    let max_range = blocked::partition(g, threads).max_len();
    let mut out = Vec::new();
    let mut list = vec![ExecutionMode::Synchronous, ExecutionMode::Asynchronous];
    list.extend(delta_sweep(max_range).into_iter().map(ExecutionMode::Delayed));
    for mode in list {
        out.push(point(g, algo, threads, machine, mode));
    }
    out
}

/// Run one configuration.
pub fn point(g: &Csr, algo: Algo, threads: usize, machine: &Machine, mode: ExecutionMode) -> SweepPoint {
    let sim = run_sim(g, algo, &EngineConfig::new(threads, mode), machine);
    SweepPoint {
        mode,
        rounds: sim.result.num_rounds(),
        time_s: sim.result.total_time(),
        avg_round_s: sim.result.avg_round_time(),
        invalidations: sim.metrics.invalidations,
        flushes: sim.result.total_flushes(),
    }
}

/// The best (lowest total time) delayed point of a sweep, if any.
pub fn best_delayed(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points
        .iter()
        .filter(|p| matches!(p.mode, ExecutionMode::Delayed(_)))
        .min_by(|a, b| a.time_s.partial_cmp(&b.time_s).unwrap())
}

/// The synchronous / asynchronous points of a sweep.
pub fn find_mode<'a>(points: &'a [SweepPoint], mode: ExecutionMode) -> Option<&'a SweepPoint> {
    points.iter().find(|p| p.mode == mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gap::GapGraph;

    #[test]
    fn sweep_covers_modes() {
        let g = GapGraph::Kron.generate(9, 8);
        let pts = modes(&g, Algo::PageRank, 8, &Machine::haswell());
        assert!(pts.len() >= 3);
        assert!(find_mode(&pts, ExecutionMode::Synchronous).is_some());
        assert!(find_mode(&pts, ExecutionMode::Asynchronous).is_some());
        let best = best_delayed(&pts).unwrap();
        assert!(matches!(best.mode, ExecutionMode::Delayed(_)));
        // All runs converged on the same algorithm => same-ish rounds.
        for p in &pts {
            assert!(p.rounds > 0 && p.time_s > 0.0);
        }
    }

    #[test]
    fn sync_has_most_rounds() {
        let g = GapGraph::Road.generate(10, 0);
        let pts = modes(&g, Algo::PageRank, 8, &Machine::haswell());
        let sync = find_mode(&pts, ExecutionMode::Synchronous).unwrap().rounds;
        let asyn = find_mode(&pts, ExecutionMode::Asynchronous).unwrap().rounds;
        assert!(asyn <= sync, "async {asyn} vs sync {sync}");
    }
}
